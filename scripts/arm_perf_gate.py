#!/usr/bin/env python3
"""Arm the perf gate from a CI-measured bench artifact.

The authoring environments for this repo have no rust toolchain, so honest
bench numbers can only come from the CI ``perf-gate`` lane, which runs the
full micro suite and uploads ``BENCH_micro`` (containing BENCH_micro.json,
BENCH_micro_tmax.json, BENCH_diff.md) on every push. While the committed
``BENCH_micro.json`` baseline is empty, ``perf-guard`` fails-closed by
design.

To arm the gate:

1. Download the ``BENCH_micro`` artifact from the latest main-branch CI run.
2. ``python3 scripts/arm_perf_gate.py /path/to/BENCH_micro.json \\
       [/path/to/BENCH_micro_tmax.json]``
3. Commit the rewritten repo-root ``BENCH_micro.json`` (and, when the tmax
   twin was given, the informational ``BENCH_micro_tmax.json``), and paste
   the printed speedup + drift tables into docs/PERF.md.

``--check`` runs the same validation against the given artifact(s) without
writing anything — the CI perf-gate lane invokes it on its freshly measured
files so the script itself cannot rot.

The script refuses artifacts that are empty, schema-mismatched, or missing
the gated hot paths, so a truncated or filtered run cannot silently become
the baseline. It also validates the recorded SIMD dispatch path
(``cpu_features.dispatch``, written by the bench suite since the SIMD
kernels landed): the gated and tmax artifacts must agree with each other,
and re-arming refuses an artifact whose dispatch differs from the
committed armed baseline's — a baseline measured on an AVX2 runner must
never be compared against scalar-dispatch runs (or vice versa). Re-arming
across instruction sets requires deleting/renaming the committed baseline
first, which makes the switch an explicit, reviewable act.
"""

import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
TARGET = REPO_ROOT / "BENCH_micro.json"
TARGET_TMAX = REPO_ROOT / "BENCH_micro_tmax.json"
SCHEMA = "splitpoint-micro-bench/v1"

# Hot paths the gate tracks; a baseline missing any of these is not a full
# run and must not be committed (targets documented in docs/PERF.md).
REQUIRED = [
    "voxelizer/scatter_20k_pts",
    "codec/encode_sparse",
    "codec/encode_sparse_delta",
    "runtime/conv_stage",
    "runtime/bev_head",
    "pipeline/stream_16_frames",
    "run_frame/vfe",
]

# Benches added after the gate was first armed. A *fresh* full run is
# expected to carry them (their absence prints a warning), but a committed
# baseline measured before they existed stays valid — promoting a name from
# OPTIONAL to REQUIRED is a deliberate act done together with re-arming.
OPTIONAL = [
    "codec/encode_sparse_v3_f16",
    "codec/encode_sparse_v3_int8",
]

# (bench, minimum speedup_vs_legacy) floors from the ROADMAP; advisory —
# printed as OK/LOW, never blocking the arming itself.
SPEEDUP_FLOORS = [
    ("voxelizer/scatter_20k_pts", 1.3),
    ("codec/encode_sparse", 1.3),
    ("pipeline/stream_16_frames", 1.2),
    ("runtime/conv_stage", 1.15),
    ("runtime/bev_head", 1.15),
]


def fail(msg: str) -> "sys.NoReturn":
    print(f"error: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: pathlib.Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read artifact {path}: {e}")


def dispatch_of(data: dict) -> "str | None":
    """The SIMD dispatch path an artifact was measured under
    (``cpu_features.dispatch``: "scalar", "avx2", "neon"), or None for
    artifacts written before the field existed."""
    features = data.get("cpu_features")
    if isinstance(features, dict):
        d = features.get("dispatch")
        if isinstance(d, str) and d:
            return d
    return None


def validate(data: dict, src: pathlib.Path, *, gated: bool) -> None:
    """Reject empty/partial/mis-threaded artifacts. `gated` artifacts must
    be the threads=1 run; informational (tmax) twins may carry any thread
    count (a 1-core runner legitimately measures max == 1). Fresh
    artifacts must record their SIMD dispatch path."""
    if data.get("schema") != SCHEMA:
        fail(f"{src}: schema mismatch: got {data.get('schema')!r}, want {SCHEMA!r}")
    baseline = data.get("baseline") or {}
    current = data.get("current") or {}
    if not baseline or not current:
        fail(f"{src}: empty baseline/current section — not a full measured run")
    missing = [k for k in REQUIRED if k not in baseline]
    if missing:
        fail(
            f"{src}: baseline is missing gated hot paths (filtered or truncated "
            "run?): " + ", ".join(missing)
        )
    # newer benches: warn-only, so older armed baselines keep validating
    missing_optional = [k for k in OPTIONAL if k not in current]
    if missing_optional:
        print(
            f"warning: {src}: run lacks newer (optional) benches: "
            + ", ".join(missing_optional),
            file=sys.stderr,
        )
    if dispatch_of(data) is None:
        fail(
            f"{src}: no cpu_features.dispatch recorded — re-run the micro suite "
            "with --json (the bench writes it since the SIMD kernels landed); "
            "a baseline without a recorded instruction set cannot be compared "
            "across runners"
        )
    if gated:
        threads = data.get("threads")
        if threads not in (None, 1):
            fail(
                f"{src}: gated baseline must be the threads=1 run, artifact says "
                f"threads={threads}"
            )


def tracked_stat(entry: dict) -> "float | None":
    """Mirror of bench::regression: p50 preferred, mean fallback."""
    for key in ("p50_ms", "mean_ms"):
        v = entry.get(key)
        if isinstance(v, (int, float)) and v > 0:
            return float(v)
    return None


def drift_table(old: dict, new: dict) -> "list[str]":
    """Markdown drift table (docs/PERF.md) of new-vs-committed p50s, worst
    drift first. Empty when the committed baseline was never armed."""
    old_base = old.get("baseline") or {}
    new_cur = new.get("current") or {}
    rows = []
    for name in sorted(set(old_base) & set(new_cur)):
        was = tracked_stat(old_base[name])
        now = tracked_stat(new_cur[name])
        if was is None or now is None:
            continue
        rows.append((name, was, now, (now - was) / was * 100.0))
    if not rows:
        return []
    rows.sort(key=lambda r: -abs(r[3]))
    lines = [
        "| bench | committed p50 ms | new p50 ms | drift |",
        "|---|---|---|---|",
    ]
    for name, was, now, pct in rows:
        lines.append(f"| {name} | {was:.3f} | {now:.3f} | {pct:+.1f}% |")
    return lines


def main() -> None:
    argv = sys.argv[1:]
    check_only = "--check" in argv
    argv = [a for a in argv if a != "--check"]
    if len(argv) not in (1, 2):
        fail(
            f"usage: {sys.argv[0]} [--check] <BENCH_micro.json> "
            "[<BENCH_micro_tmax.json>]"
        )
    src = pathlib.Path(argv[0])
    data = load(src)
    validate(data, src, gated=True)

    tmax_src = pathlib.Path(argv[1]) if len(argv) == 2 else None
    tmax_data = None
    if tmax_src is not None:
        tmax_data = load(tmax_src)
        validate(tmax_data, tmax_src, gated=False)
        if dispatch_of(tmax_data) != dispatch_of(data):
            fail(
                f"dispatch mismatch between artifacts: {src} was measured with "
                f"{dispatch_of(data)!r} but {tmax_src} with "
                f"{dispatch_of(tmax_data)!r} — these are not from the same "
                "runner/run and must not be committed together"
            )

    if check_only:
        checked = [str(src)] + ([str(tmax_src)] if tmax_src else [])
        print(
            f"check ok: {', '.join(checked)} — full runs, schema + hot paths "
            f"valid, dispatch {dispatch_of(data)!r}"
        )
        return

    # Never arm across instruction sets: a baseline measured under AVX2
    # dispatch is systematically faster than a scalar-dispatch run of the
    # same code, so comparing them would report phantom regressions (or
    # mask real ones). Switching runners is fine — but it must be explicit:
    # delete/rename the committed baseline first, then arm fresh.
    if TARGET.exists():
        committed = load(TARGET)
        committed_dispatch = dispatch_of(committed)
        if (
            committed.get("status") == "armed"
            and committed_dispatch is not None
            and committed_dispatch != dispatch_of(data)
        ):
            fail(
                f"refusing to re-arm: committed baseline was measured with "
                f"dispatch {committed_dispatch!r} but {src} reports "
                f"{dispatch_of(data)!r}; baselines from different instruction "
                "sets are not comparable — if the runner fleet changed, remove "
                f"{TARGET.relative_to(REPO_ROOT)} first and arm from scratch"
            )

    # drift of the fresh run against whatever baseline is committed today
    # (meaningful once armed; silent on the first arming)
    drift = drift_table(load(TARGET) if TARGET.exists() else {}, data)

    data["status"] = "armed"
    data.pop("note", None)
    TARGET.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    print(f"armed: wrote {TARGET.relative_to(REPO_ROOT)} from {src}")

    if tmax_data is not None:
        tmax_data["status"] = "informational"
        tmax_data.pop("note", None)
        TARGET_TMAX.write_text(json.dumps(tmax_data, indent=2, sort_keys=True) + "\n")
        print(
            f"promoted threads=max twin: wrote "
            f"{TARGET_TMAX.relative_to(REPO_ROOT)} from {tmax_src} "
            "(informational — never gated; runner core counts vary)"
        )

    vs_legacy = data.get("speedup_vs_legacy") or {}
    if vs_legacy:
        print("\nspeedup_vs_legacy (paste into docs/PERF.md):\n")
        print("| bench | speedup vs legacy | floor | verdict |")
        print("|---|---|---|---|")
        floors = dict(SPEEDUP_FLOORS)
        for name in sorted(vs_legacy):
            ratio = vs_legacy[name]
            floor = floors.get(name)
            verdict = "—" if floor is None else ("OK" if ratio >= floor else "LOW")
            floor_s = f"≥{floor}×" if floor is not None else "—"
            print(f"| {name} | {ratio:.2f}× | {floor_s} | {verdict} |")

    if drift:
        print("\ndrift vs previously committed baseline (paste into docs/PERF.md):\n")
        print("\n".join(drift))

    print("\nnext: git add BENCH_micro.json", end="")
    if tmax_data is not None:
        print(" BENCH_micro_tmax.json", end="")
    print(" && commit — the perf-gate lane is armed.")


if __name__ == "__main__":
    main()
