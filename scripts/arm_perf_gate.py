#!/usr/bin/env python3
"""Arm the perf gate from a CI-measured bench artifact.

The authoring environments for this repo have no rust toolchain, so honest
bench numbers can only come from the CI ``perf-gate`` lane, which runs the
full micro suite and uploads ``BENCH_micro`` (containing BENCH_micro.json,
BENCH_micro_tmax.json, BENCH_diff.md) on every push. While the committed
``BENCH_micro.json`` baseline is empty, ``perf-guard`` fails-closed by
design.

To arm the gate:

1. Download the ``BENCH_micro`` artifact from the latest main-branch CI run
   (threads=1 file).
2. ``python3 scripts/arm_perf_gate.py /path/to/downloaded/BENCH_micro.json``
3. Commit the rewritten repo-root ``BENCH_micro.json``, and paste the
   printed speedup table into docs/PERF.md.

The script refuses artifacts that are empty, schema-mismatched, or missing
the gated hot paths, so a truncated or filtered run cannot silently become
the baseline.
"""

import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
TARGET = REPO_ROOT / "BENCH_micro.json"
SCHEMA = "splitpoint-micro-bench/v1"

# Hot paths the gate tracks; a baseline missing any of these is not a full
# run and must not be committed (targets documented in docs/PERF.md).
REQUIRED = [
    "voxelizer/scatter_20k_pts",
    "codec/encode_sparse",
    "codec/encode_sparse_delta",
    "runtime/conv_stage",
    "runtime/bev_head",
    "pipeline/stream_16_frames",
    "run_frame/vfe",
]

# (bench, minimum speedup_vs_legacy) floors from the ROADMAP; advisory —
# printed as OK/LOW, never blocking the arming itself.
SPEEDUP_FLOORS = [
    ("voxelizer/scatter_20k_pts", 1.3),
    ("codec/encode_sparse", 1.3),
    ("pipeline/stream_16_frames", 1.2),
    ("runtime/conv_stage", 1.15),
    ("runtime/bev_head", 1.15),
]


def fail(msg: str) -> "sys.NoReturn":
    print(f"error: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} <downloaded BENCH_micro.json>")
    src = pathlib.Path(sys.argv[1])
    try:
        data = json.loads(src.read_text())
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read artifact {src}: {e}")

    if data.get("schema") != SCHEMA:
        fail(f"schema mismatch: got {data.get('schema')!r}, want {SCHEMA!r}")
    baseline = data.get("baseline") or {}
    current = data.get("current") or {}
    if not baseline or not current:
        fail("artifact has an empty baseline/current section — not a full measured run")
    missing = [k for k in REQUIRED if k not in baseline]
    if missing:
        fail(
            "baseline is missing gated hot paths (filtered or truncated run?): "
            + ", ".join(missing)
        )
    threads = data.get("threads")
    if threads not in (None, 1):
        fail(f"gated baseline must be the threads=1 run, artifact says threads={threads}")

    data["status"] = "armed"
    data.pop("note", None)
    TARGET.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    print(f"armed: wrote {TARGET.relative_to(REPO_ROOT)} from {src}")

    vs_legacy = data.get("speedup_vs_legacy") or {}
    if vs_legacy:
        print("\nspeedup_vs_legacy (paste into docs/PERF.md):\n")
        print("| bench | speedup vs legacy | floor | verdict |")
        print("|---|---|---|---|")
        floors = dict(SPEEDUP_FLOORS)
        for name in sorted(vs_legacy):
            ratio = vs_legacy[name]
            floor = floors.get(name)
            verdict = "—" if floor is None else ("OK" if ratio >= floor else "LOW")
            floor_s = f"≥{floor}×" if floor is not None else "—"
            print(f"| {name} | {ratio:.2f}× | {floor_s} | {verdict} |")
    print("\nnext: git add BENCH_micro.json && commit — the perf-gate lane is armed.")


if __name__ == "__main__":
    main()
