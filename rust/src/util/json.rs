//! Minimal-but-complete JSON parser and serializer.
//!
//! Substrate module (DESIGN.md §3, offline-toolchain substitutions): the
//! build environment has no `serde`/`serde_json`, so the artifact manifest
//! and config files are handled by this ~400-line implementation. Supports
//! the full JSON grammar (RFC 8259) minus `\u` surrogate pairs outside the
//! BMP being validated pairwise (they are passed through as-is).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys keep a stable (sorted) order so that
/// serialization is deterministic — manifests hash reproducibly.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: `value.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }
}

// ------------------------------------------------------------------ parse

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("raw control char in string")),
                c => {
                    // re-assemble multi-byte utf-8 (input is a &str, so valid)
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// -------------------------------------------------------------- serialize

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write(self, f, None, 0)
    }
}

impl Value {
    /// Pretty-printed with 1-space indent (matches python `json.dumps(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        write(self, &mut s, Some(1), 0).unwrap();
        s
    }
}

fn write(
    v: &Value,
    f: &mut dyn fmt::Write,
    indent: Option<usize>,
    depth: usize,
) -> fmt::Result {
    let (nl, pad, pad2) = match indent {
        Some(n) => (
            "\n",
            " ".repeat(n * (depth + 1)),
            " ".repeat(n * depth),
        ),
        None => ("", String::new(), String::new()),
    };
    match v {
        Value::Null => f.write_str("null"),
        Value::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Value::Str(s) => write_escaped(s, f),
        Value::Arr(a) => {
            if a.is_empty() {
                return f.write_str("[]");
            }
            f.write_str("[")?;
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                    if indent.is_none() {
                        f.write_str(" ")?;
                    }
                }
                f.write_str(nl)?;
                f.write_str(&pad)?;
                write(item, f, indent, depth + 1)?;
            }
            f.write_str(nl)?;
            f.write_str(&pad2)?;
            f.write_str("]")
        }
        Value::Obj(m) => {
            if m.is_empty() {
                return f.write_str("{}");
            }
            f.write_str("{")?;
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                    if indent.is_none() {
                        f.write_str(" ")?;
                    }
                }
                f.write_str(nl)?;
                f.write_str(&pad)?;
                write_escaped(k, f)?;
                f.write_str(": ")?;
                write(item, f, indent, depth + 1)?;
            }
            f.write_str(nl)?;
            f.write_str(&pad2)?;
            f.write_str("}")
        }
    }
}

fn write_escaped(s: &str, f: &mut dyn fmt::Write) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_str("\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\x\""] {
            assert!(parse(src).is_err(), "{src}");
        }
    }

    #[test]
    fn unicode_roundtrip() {
        let v = parse("\"π ≈ 3.14159 — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "π ≈ 3.14159 — ok");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str().unwrap(), "é");
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"modules": [{"name": "vfe", "shape": [16, 128, 128, 4]}]}"#)
            .unwrap();
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn usize_vec() {
        let v = parse("[16, 128, 128, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![16, 128, 128, 4]);
        assert_eq!(parse("[1.5]").unwrap().as_usize_vec(), None);
    }
}
