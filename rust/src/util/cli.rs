//! Tiny declarative CLI parser (clap substitute, offline build).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Parse a `--threads` value: a positive integer, or `max` / `auto` / `0`
/// for all available cores (resolved by [`crate::runtime::pool::resolve_threads`],
/// the single source of truth). `None` (flag absent) means 1 — the
/// single-threaded kernels, bit-identical to every other thread count.
pub fn parse_threads(v: Option<&str>) -> Result<usize> {
    match v {
        None => Ok(1),
        Some("max") | Some("auto") | Some("0") => Ok(crate::runtime::pool::resolve_threads(0)),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => bail!("--threads: want a positive integer, 'max', or 'auto'; got '{s}'"),
        },
    }
}

/// Parse a `--simd` value into a [`crate::runtime::simd::SimdMode`]:
/// `auto` (or the flag absent) probes the CPU, `scalar` forces the
/// fallback kernels, `forced` errors out unless a vector path exists.
pub fn parse_simd(v: Option<&str>) -> Result<crate::runtime::simd::SimdMode> {
    use crate::runtime::simd::SimdMode;
    match v {
        None | Some("auto") => Ok(SimdMode::Auto),
        Some("scalar") => Ok(SimdMode::Scalar),
        Some("forced") => Ok(SimdMode::Forced),
        Some(s) => bail!("--simd: want auto, scalar, or forced; got '{s}'"),
    }
}

/// Split a `kind:arg` CLI spec (`kitti:/data/scans`, `replay:f.bin`) into
/// `(kind, Some(arg))`, or `(spec, None)` when there is no `:`. Shared by
/// `--source` parsing and any future spec-valued flags.
pub fn split_spec(spec: &str) -> (&str, Option<&str>) {
    match spec.split_once(':') {
        Some((kind, arg)) => (kind, Some(arg)),
        None => (spec, None),
    }
}

/// One option's declaration (help text only; parsing is permissive).
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// One subcommand's declaration.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Application CLI description.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
    pub global_opts: Vec<OptSpec>,
}

impl Cli {
    /// Parse argv (without the binary name).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.help(args.subcommand.as_deref()));
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let val = match val {
                    Some(v) => v,
                    None => {
                        // value-taking if the next token isn't another flag
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => {
                                it.next().unwrap().clone()
                            }
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.insert(key, val);
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                if !self.commands.iter().any(|c| c.name == a.as_str()) {
                    bail!(
                        "unknown command '{a}'; try `{} --help`",
                        self.bin
                    );
                }
                args.subcommand = Some(a.clone());
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Generated help text.
    pub fn help(&self, command: Option<&str>) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        if let Some(cmd) = command.and_then(|c| self.commands.iter().find(|x| x.name == c)) {
            let _ = writeln!(s, "{} {} — {}\n", self.bin, cmd.name, cmd.help);
            let _ = writeln!(s, "options:");
            for o in cmd.opts.iter().chain(&self.global_opts) {
                let v = o.value.map(|v| format!(" <{v}>")).unwrap_or_default();
                let _ = writeln!(s, "  --{}{v:<18} {}", o.name, o.help);
            }
            return s;
        }
        let _ = writeln!(s, "{} — {}\n", self.bin, self.about);
        let _ = writeln!(s, "commands:");
        for c in &self.commands {
            let _ = writeln!(s, "  {:<14} {}", c.name, c.help);
        }
        let _ = writeln!(s, "\nglobal options:");
        for o in &self.global_opts {
            let v = o.value.map(|v| format!(" <{v}>")).unwrap_or_default();
            let _ = writeln!(s, "  --{}{v:<18} {}", o.name, o.help);
        }
        let _ = writeln!(s, "\nrun `{} <command> --help` for command options", self.bin);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "t",
            about: "test",
            commands: vec![
                CommandSpec {
                    name: "run",
                    help: "run it",
                    opts: vec![],
                },
                CommandSpec {
                    name: "sweep",
                    help: "sweep it",
                    opts: vec![],
                },
            ],
            global_opts: vec![],
        }
    }

    fn parse(args: &[&str]) -> Result<Args> {
        cli().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["run", "--frames", "10", "--split=conv1", "--realtime"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("frames"), Some("10"));
        assert_eq!(a.get("split"), Some("conv1"));
        assert_eq!(a.get("realtime"), Some("true"));
        assert_eq!(a.get_parse::<usize>("frames").unwrap(), Some(10));
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(parse(&["frob"]).is_err());
    }

    #[test]
    fn positional_after_command() {
        let a = parse(&["run", "file1", "file2"]).unwrap();
        assert_eq!(a.positional, ["file1", "file2"]);
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse(&["run", "--frames", "ten"]).unwrap();
        let e = a.get_parse::<usize>("frames").unwrap_err().to_string();
        assert!(e.contains("frames"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["run", "--offset=-3.5"]).unwrap();
        assert_eq!(a.get_parse::<f64>("offset").unwrap(), Some(-3.5));
    }

    #[test]
    fn split_spec_splits_on_first_colon() {
        assert_eq!(split_spec("synthetic"), ("synthetic", None));
        assert_eq!(split_spec("kitti:/data/scans"), ("kitti", Some("/data/scans")));
        assert_eq!(split_spec("replay:a:b.bin"), ("replay", Some("a:b.bin")));
    }

    #[test]
    fn threads_parses_counts_and_max() {
        assert_eq!(parse_threads(None).unwrap(), 1);
        assert_eq!(parse_threads(Some("3")).unwrap(), 3);
        let all = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(parse_threads(Some("max")).unwrap(), all);
        assert_eq!(parse_threads(Some("auto")).unwrap(), all);
        assert_eq!(parse_threads(Some("0")).unwrap(), all);
        assert!(parse_threads(Some("-2")).is_err());
        assert!(parse_threads(Some("many")).is_err());
    }

    #[test]
    fn simd_parses_modes_and_rejects_typos() {
        use crate::runtime::simd::SimdMode;
        assert_eq!(parse_simd(None).unwrap(), SimdMode::Auto);
        assert_eq!(parse_simd(Some("auto")).unwrap(), SimdMode::Auto);
        assert_eq!(parse_simd(Some("scalar")).unwrap(), SimdMode::Scalar);
        assert_eq!(parse_simd(Some("forced")).unwrap(), SimdMode::Forced);
        let e = parse_simd(Some("avx512")).unwrap_err().to_string();
        assert!(e.contains("avx512"));
    }
}
