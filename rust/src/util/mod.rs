//! Shared substrates: JSON, PRNG, CLI parsing, small helpers.

pub mod cli;
pub mod json;
pub mod rng;
