//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! xoshiro256++ seeded through SplitMix64 — the same generator family the
//! `rand_xoshiro` crate ships. Everything in the workload path (scene
//! generation, property tests) goes through this so runs are reproducible
//! from a single u64 seed.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed, per Vigna's recommendation.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random pick from a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Fork a child generator (stream-split for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
