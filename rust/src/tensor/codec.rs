//! Wire codec: how intermediate tensors cross the edge→server link.
//!
//! This is the byte-accounting substrate behind the paper's Fig 8/9. The
//! occupancy masks carried through the 3D backbone (spconv semantics, see
//! DESIGN.md §3) let feature volumes be encoded sparsely — exactly the
//! mechanism that makes the paper's VFE transfer (1.18 MB) smaller than the
//! raw cloud (1.84 MB) while in-network transfers balloon (7.2 / 29 MB).
//!
//! Formats:
//!   * `DenseF32`    — raw row-major f32 payload
//!   * `SparseF32`   — active-site index + per-site channel values
//!   * `MaskBitset`  — 1 bit per site (occupancy masks reconstruct exactly)
//!   * `DenseQ8` / `SparseQ8` — int8 affine-quantized variants (the paper's
//!     §VI future-work compression; ablated in the bench suite)
//!
//! `encode_auto` picks the smallest exact format; quantized formats are
//! opt-in because they are lossy.
//!
//! **Wire version 2** (the default framing) delta + run-length encodes the
//! sorted sparse site index: occupied sites on real scans are
//! near-contiguous (points fill surfaces, so runs along the fastest grid
//! axis are long), so instead of 4 bytes per site the index is a varint
//! run list — `(gap-from-previous, run_length)` pairs — that costs a
//! couple of bytes per *run* (paper §VI compression direction). Version 1
//! packets (raw little-endian u32 per site) still decode; see
//! [`Packet::encode_versioned_into`].
//!
//! **Wire version 3** keeps the v2 site index and adds lossy sparse value
//! payloads, selected per session by [`WirePrecision`]: `SparseF16`
//! (IEEE-754 binary16, round-to-nearest-even) and `SparseQ8C` (symmetric
//! int8 with one scale per channel, computed in a single pass over the
//! occupied-site index). Both conversions are pure integer/IEEE
//! arithmetic — no FMA, ties-to-even — so quantize→dequantize is
//! bit-reproducible across architectures. An f32 sender keeps shipping
//! byte-identical version-2 packets ([`Packet::encode_wire`] only emits
//! the version-3 byte when a lossy precision is selected); v1/v2 frames
//! always decode.
//!
//! Perf contract (see docs/PERF.md): packets hold `Arc<Tensor>` so frame
//! assembly never deep-copies; format choice and sparse emission run off
//! the tensor's cached occupied-site index (no rescans of dense grids);
//! `encode_into` writes into a caller-owned, exactly-presized buffer so a
//! steady-state encode performs no allocation beyond the first frame.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Tensor;

const MAGIC: u32 = 0x5350_5754; // "SPWT"

/// Default wire framing: delta/varint run-length site indices. Version 1
/// (raw u32 indices) remains decodable for old senders.
pub const WIRE_VERSION: u8 = 2;

/// Quantized framing: the v2 site index plus f16 / per-channel-int8
/// sparse value payloads. Only emitted when the sender selects a lossy
/// [`WirePrecision`]; v1 and v2 packets still decode.
pub const WIRE_VERSION_V3: u8 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    DenseF32 = 0,
    SparseF32 = 1,
    MaskBitset = 2,
    DenseQ8 = 3,
    SparseQ8 = 4,
    /// v3: sparse values as IEEE-754 binary16 (round-to-nearest-even)
    SparseF16 = 5,
    /// v3: sparse values as symmetric int8 with one f32 scale per channel
    SparseQ8C = 6,
}

impl Format {
    fn from_u8(b: u8) -> Result<Format> {
        Ok(match b {
            0 => Format::DenseF32,
            1 => Format::SparseF32,
            2 => Format::MaskBitset,
            3 => Format::DenseQ8,
            4 => Format::SparseQ8,
            5 => Format::SparseF16,
            6 => Format::SparseQ8C,
            _ => bail!("unknown wire format {b}"),
        })
    }

    pub fn lossy(self) -> bool {
        matches!(
            self,
            Format::DenseQ8 | Format::SparseQ8 | Format::SparseF16 | Format::SparseQ8C
        )
    }

    /// Formats that require the version-3 framing (a v1/v2 packet carrying
    /// one is corrupt).
    fn needs_v3(self) -> bool {
        matches!(self, Format::SparseF16 | Format::SparseQ8C)
    }
}

/// Wire value precision for sparse feature payloads — the `--wire` knob,
/// carried in [`crate::config::SystemConfig::wire`]. `F32` is the pinned
/// default: it ships byte-identical version-2 packets. `F16`/`Int8`
/// switch the sender to the version-3 framing, quantizing non-mask sparse
/// values (masks reconstruct exactly under every precision; the dense
/// fallback stays f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WirePrecision {
    /// Exact f32 payloads, version-2 framing (byte-identical to a sender
    /// without the knob).
    #[default]
    F32,
    /// IEEE-754 binary16 payloads (round-to-nearest-even), version 3.
    F16,
    /// Symmetric int8 with a per-channel scale (ties-to-even), version 3.
    Int8,
}

impl WirePrecision {
    /// Parse the `--wire` CLI / config value.
    pub fn parse(s: &str) -> Result<WirePrecision> {
        Ok(match s {
            "f32" => WirePrecision::F32,
            "f16" => WirePrecision::F16,
            "int8" => WirePrecision::Int8,
            _ => bail!("unknown wire precision '{s}' (want f32, f16, or int8)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::F16 => "f16",
            WirePrecision::Int8 => "int8",
        }
    }

    /// The framing version this precision ships.
    pub fn wire_version(self) -> u8 {
        match self {
            WirePrecision::F32 => WIRE_VERSION,
            WirePrecision::F16 | WirePrecision::Int8 => WIRE_VERSION_V3,
        }
    }

    pub fn lossy(self) -> bool {
        !matches!(self, WirePrecision::F32)
    }
}

/// Encoding policy, part of the coordinator config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Smallest *exact* encoding (dense vs sparse vs bitset).
    #[default]
    Auto,
    /// Force dense f32 (what the paper's unmodified implementation ships).
    Dense,
    /// Smallest encoding allowing int8 quantization (paper §VI extension).
    AutoQuantized,
}

// ------------------------------------------------------------- primitives

/// Byte writer over a caller-owned buffer (reused across frames).
struct Writer<'a> {
    buf: &'a mut Vec<u8>,
}

impl Writer<'_> {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
    /// Append `n` zero bytes, returning their start offset.
    fn zeros(&mut self, n: usize) -> usize {
        let start = self.buf.len();
        self.buf.resize(start + n, 0);
        start
    }
    /// Set bit `bit` (LSB-first) inside the region starting at `start`.
    fn set_bit(&mut self, start: usize, bit: usize) {
        self.buf[start + bit / 8] |= 1 << (bit % 8);
    }
    /// LEB128 unsigned varint (7 bits per byte, high bit = continue).
    fn varint(&mut self, mut v: u32) {
        while v >= 0x80 {
            self.buf.push((v as u8 & 0x7f) | 0x80);
            v >>= 7;
        }
        self.buf.push(v as u8);
    }
}

/// Encoded length of one LEB128 varint.
fn varint_len(mut v: u32) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("wire truncated at {} (+{n})", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
    fn varint(&mut self) -> Result<u32> {
        let mut v: u32 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            // the 5th byte holds only 4 usable bits; anything above would
            // be silently truncated by the shift — corrupt input, bail
            if shift >= 32 || (shift == 28 && (b & 0x7f) > 0x0f) {
                bail!("varint overflows 32 bits at {}", self.pos);
            }
            v |= ((b & 0x7f) as u32) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }
}

// ----------------------------------------------- delta/RLE site index (v2)

/// Walk an ascending site list as maximal runs of consecutive indices,
/// calling `f(gap_from_cursor, run_len)` per run (cursor = one past the
/// previous run's end, starting at 0).
fn for_each_site_run(sites: &[u32], mut f: impl FnMut(u32, u32)) {
    let mut cursor: u32 = 0;
    let mut i = 0usize;
    while i < sites.len() {
        let start = sites[i];
        let mut len: u32 = 1;
        while i + (len as usize) < sites.len() && sites[i + len as usize] == start + len {
            len += 1;
        }
        f(start - cursor, len);
        cursor = start + len;
        i += len as usize;
    }
}

/// Exact byte cost of the site-index block at `version` plus the v2 run
/// count, in a **single walk** — the one source of truth for index
/// sizing (v1: 4-byte count + raw u32 per site; the v1 run count is 0,
/// it has no run framing).
fn site_index_cost(sites: &[u32], version: u8) -> (usize, u32) {
    if version < 2 {
        return (4 + sites.len() * 4, 0);
    }
    let mut runs: u32 = 0;
    let mut run_bytes = 0usize;
    for_each_site_run(sites, |gap, len| {
        runs += 1;
        run_bytes += varint_len(gap) + varint_len(len - 1);
    });
    (
        varint_len(sites.len() as u32) + varint_len(runs) + run_bytes,
        runs,
    )
}

/// v2 site-index block: varint site count, varint run count, then per run
/// `(varint gap-from-cursor, varint run_len - 1)`. Ascending by
/// construction, so decoders always seed the occupied-site cache.
/// `n_runs` comes from the tensor's [`plan`] so emission is a single walk.
fn encode_site_index(w: &mut Writer, sites: &[u32], n_runs: u32) {
    w.varint(sites.len() as u32);
    w.varint(n_runs);
    let mut emitted: u32 = 0;
    for_each_site_run(sites, |gap, len| {
        w.varint(gap);
        w.varint(len - 1);
        emitted += 1;
    });
    debug_assert_eq!(emitted, n_runs, "plan's run count drifted from emission");
}

fn decode_site_index(r: &mut Reader, spatial: usize) -> Result<Vec<usize>> {
    let n = r.varint()? as usize;
    if n > spatial {
        bail!("sparse count {n} exceeds {spatial} sites");
    }
    let n_runs = r.varint()? as usize;
    if n_runs > n {
        bail!("sparse run count {n_runs} exceeds site count {n}");
    }
    let mut idx: Vec<usize> = Vec::with_capacity(n);
    let mut cursor: u64 = 0;
    for _ in 0..n_runs {
        let gap = r.varint()? as u64;
        let len = r.varint()? as u64 + 1;
        let start = cursor + gap;
        let end = start + len;
        if end > spatial as u64 || idx.len() + len as usize > n {
            bail!("sparse run [{start}, {end}) out of range");
        }
        for s in start..end {
            idx.push(s as usize);
        }
        cursor = end;
    }
    if idx.len() != n {
        bail!("sparse runs cover {} of {n} sites", idx.len());
    }
    Ok(idx)
}

// ------------------------------------------------- f16 / int8 conversion

/// f32 → IEEE-754 binary16 bits with round-to-nearest-even. Pure integer
/// bit arithmetic: identical output on every architecture (the
/// cross-platform determinism the CI accuracy gate relies on). Overflow
/// saturates to ±Inf exactly as hardware conversion would; NaN becomes a
/// quiet NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf stays Inf; NaN collapses to a quiet NaN
        return sign | if abs > 0x7f80_0000 { 0x7e00 } else { 0x7c00 };
    }
    // re-bias the exponent from 127 to 15
    let exp = (abs >> 23) as i32 - 112;
    let man = abs & 0x007f_ffff;
    if exp >= 0x1f {
        return sign | 0x7c00; // >= 2^16: past the largest finite f16
    }
    if exp <= 0 {
        // subnormal (or underflow-to-zero) output
        if exp < -10 {
            return sign; // < 2^-25 rounds to zero even on a tie
        }
        let m = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - exp) as u32;
        let out = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let out = if rem > half || (rem == half && out & 1 == 1) {
            out + 1 // may carry into the smallest normal — correct bits
        } else {
            out
        };
        return sign | out as u16;
    }
    // normal: drop 13 mantissa bits, rounding ties to even; a mantissa
    // carry rolls into the exponent (and into Inf at the top) by itself
    let mut out = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && out & 1 == 1) {
        out += 1;
    }
    sign | out as u16
}

/// IEEE-754 binary16 bits → f32 (exact: every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = u32::from(h & 0x3ff);
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: value = m * 2^-24; normalize into f32
            let p = 31 - m.leading_zeros(); // top set bit, 0..=9
            sign | ((p + 103) << 23) | ((m << (23 - p)) & 0x007f_ffff)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((u32::from(e) + 112) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Per-channel symmetric int8 scales for [`Format::SparseQ8C`]: channel
/// abs-max over the occupied-site index (one pass, no dense rescan)
/// divided by 127, with all-zero channels pinned to scale 1.0 so
/// dequantization never divides by zero.
fn channel_scales(t: &Tensor) -> Vec<f32> {
    let c = t.channels().max(1);
    let mut maxes = vec![0.0f32; c];
    let data = t.data();
    for &s in t.site_index() {
        let site = &data[s as usize * c..(s as usize + 1) * c];
        for (m, &x) in maxes.iter_mut().zip(site) {
            let a = x.abs();
            if a > *m {
                *m = a;
            }
        }
    }
    for m in &mut maxes {
        *m = if *m == 0.0 { 1.0 } else { *m / 127.0 };
    }
    maxes
}

/// Quantize one value against a channel scale: plain IEEE division, then
/// `round_ties_even` — no FMA anywhere on this path, so the emitted byte
/// is identical across x86_64 and aarch64.
fn quantize_i8(x: f32, scale: f32) -> u8 {
    (x / scale).round_ties_even().clamp(-127.0, 127.0) as i8 as u8
}

// ---------------------------------------------------------- single tensor

/// Masks are single-channel tensors whose non-zero values are all exactly
/// 1 — checked over the occupied-site index only, never the dense buffer.
fn is_mask(t: &Tensor) -> bool {
    t.channels() == 1
        && t.site_index()
            .iter()
            .all(|&s| t.data()[s as usize] == 1.0)
}

/// Payload bytes `fmt` needs given a precomputed sparse index cost —
/// shared by [`plan`] and [`payload_size`] so wire sizes have one source
/// of truth.
fn format_payload(t: &Tensor, fmt: Format, index_bytes: usize, value_count: usize) -> usize {
    match fmt {
        Format::DenseF32 => t.size_bytes(),
        Format::SparseF32 => index_bytes + value_count * 4,
        Format::MaskBitset => t.spatial().div_ceil(8),
        Format::DenseQ8 => 8 + t.numel(),
        Format::SparseQ8 => 8 + index_bytes + value_count,
        Format::SparseF16 => index_bytes + value_count * 2,
        // one f32 scale per channel, then index + 1 byte per value
        Format::SparseQ8C => 4 * t.channels().max(1) + index_bytes + value_count,
    }
}

/// Size in bytes `fmt` would need for this tensor at the current wire
/// version (without header). Reporting/analysis helper; the encoder's hot
/// path computes this through the single-walk (private) `plan`.
pub fn payload_size(t: &Tensor, fmt: Format) -> usize {
    let sites = t.site_index();
    let (index_bytes, _) = site_index_cost(sites, WIRE_VERSION);
    format_payload(t, fmt, index_bytes, sites.len() * t.channels())
}

/// Per-tensor encode plan: smallest format at the framing `version`
/// actually being written (v1 costs 4 bytes/site of index where v2 costs
/// a few bytes per run, so the dense/sparse crossover point differs),
/// its exact payload size, and the v2 run count so emission doesn't
/// re-count. Computed in a **single walk** over the cached site index —
/// the index cost is shared by both sparse candidates, keeping the wire
/// hot path at one sizing walk per tensor per pass.
#[derive(Debug, Clone, Copy)]
struct TensorPlan {
    fmt: Format,
    payload: usize,
    n_runs: u32,
}

fn plan(t: &Tensor, policy: Policy, version: u8, precision: WirePrecision) -> TensorPlan {
    if policy == Policy::Dense {
        // no format choice to make — don't walk the site index at all
        // (Dense stays exact f32 under every precision; `--wire` only
        // quantizes the sparse feature payloads)
        return TensorPlan {
            fmt: Format::DenseF32,
            payload: t.size_bytes(),
            n_runs: 0,
        };
    }
    let sites = t.site_index();
    let (index_bytes, n_runs) = site_index_cost(sites, version);
    let values = sites.len() * t.channels();
    let size_of = |fmt: Format| format_payload(t, fmt, index_bytes, values);
    let best_of = |candidates: &[Format]| -> Format {
        let mut best = Format::DenseF32;
        for &f in candidates {
            if size_of(f) < size_of(best) {
                best = f;
            }
        }
        best
    };
    // the precision's lossy sparse candidate — version-3 framing only
    let quant = match precision {
        _ if version < WIRE_VERSION_V3 => None,
        WirePrecision::F32 => None,
        WirePrecision::F16 => Some(Format::SparseF16),
        WirePrecision::Int8 => Some(Format::SparseQ8C),
    };
    let fmt = if is_mask(t) {
        // masks quantize to themselves under every precision; bitset is
        // already 1 bit — keep the exact candidates
        best_of(&[Format::SparseF32, Format::MaskBitset])
    } else {
        let mut candidates = [Format::SparseF32; 4];
        let mut n = 1;
        if policy == Policy::AutoQuantized {
            candidates[n] = Format::DenseQ8;
            candidates[n + 1] = Format::SparseQ8;
            n += 2;
        }
        if let Some(q) = quant {
            candidates[n] = q;
            n += 1;
        }
        best_of(&candidates[..n])
    };
    TensorPlan {
        fmt,
        payload: size_of(fmt),
        n_runs,
    }
}

fn quant_params(t: &Tensor) -> (f32, f32) {
    // symmetric affine: x ≈ scale * q, q ∈ [-127, 127]
    let m = t.abs_max();
    let scale = if m == 0.0 { 1.0 } else { m / 127.0 };
    (scale, 0.0)
}

fn encode_tensor(w: &mut Writer, name: &str, t: &Tensor, plan: TensorPlan, version: u8) {
    let fmt = plan.fmt;
    w.u8(name.len() as u8);
    w.bytes(name.as_bytes());
    w.u8(fmt as u8);
    w.u8(t.shape().len() as u8);
    for &d in t.shape() {
        w.u32(d as u32);
    }
    match fmt {
        Format::DenseF32 => {
            for &x in t.data() {
                w.f32(x);
            }
        }
        Format::SparseF32 | Format::SparseQ8 => {
            // single pass over the occupied-site index — no dense rescan
            let sites = t.site_index();
            let c = t.channels().max(1);
            let (scale, _) = quant_params(t);
            if version >= 2 {
                // v2: quant params, then the delta/varint run-length index
                if fmt == Format::SparseQ8 {
                    w.f32(scale);
                    w.f32(0.0);
                }
                encode_site_index(w, sites, plan.n_runs);
            } else {
                // v1 framing: u32 count, quant params, raw u32 indices
                w.u32(sites.len() as u32);
                if fmt == Format::SparseQ8 {
                    w.f32(scale);
                    w.f32(0.0);
                }
                for &s in sites {
                    w.u32(s);
                }
            }
            let data = t.data();
            for &s in sites {
                let site = &data[s as usize * c..(s as usize + 1) * c];
                for &x in site {
                    if fmt == Format::SparseQ8 {
                        w.u8(((x / scale).round().clamp(-127.0, 127.0)) as i8 as u8);
                    } else {
                        w.f32(x);
                    }
                }
            }
        }
        Format::MaskBitset => {
            // set bits straight from the site index into a zeroed region
            let start = w.zeros(t.spatial().div_ceil(8));
            for &s in t.site_index() {
                w.set_bit(start, s as usize);
            }
        }
        Format::DenseQ8 => {
            let (scale, _) = quant_params(t);
            w.f32(scale);
            w.f32(0.0);
            for &x in t.data() {
                w.u8(((x / scale).round().clamp(-127.0, 127.0)) as i8 as u8);
            }
        }
        Format::SparseF16 => {
            // v3: delta/varint index, then IEEE half bits per value.
            // Conversion is pure integer round-to-nearest-even — identical
            // bytes on every target.
            let sites = t.site_index();
            let c = t.channels().max(1);
            encode_site_index(w, sites, plan.n_runs);
            let data = t.data();
            for &s in sites {
                let site = &data[s as usize * c..(s as usize + 1) * c];
                for &x in site {
                    w.u16(f32_to_f16_bits(x));
                }
            }
        }
        Format::SparseQ8C => {
            // v3: per-channel scales (one pass over the site index), then
            // the delta/varint index, then one i8 per value. Ties round to
            // even so x86_64 and aarch64 emit identical bytes.
            let sites = t.site_index();
            let c = t.channels().max(1);
            let scales = channel_scales(t);
            for &s in &scales {
                w.f32(s);
            }
            encode_site_index(w, sites, plan.n_runs);
            let data = t.data();
            for &s in sites {
                let base = s as usize * c;
                for (ch, &scale) in scales.iter().enumerate() {
                    w.u8(quantize_i8(data[base + ch], scale));
                }
            }
        }
    }
}

fn decode_tensor(r: &mut Reader, version: u8) -> Result<(String, Tensor)> {
    let nlen = r.u8()? as usize;
    let name = String::from_utf8(r.take(nlen)?.to_vec()).context("tensor name")?;
    let fmt = Format::from_u8(r.u8()?)?;
    if fmt.needs_v3() && version < WIRE_VERSION_V3 {
        bail!(
            "format {:?} requires wire version {WIRE_VERSION_V3} (frame says {version})",
            fmt
        );
    }
    let ndim = r.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u32()? as usize);
    }
    let numel: usize = shape.iter().product();
    let channels = shape.last().copied().unwrap_or(1).max(1);
    let spatial = numel / channels;

    let tensor = match fmt {
        Format::DenseF32 => {
            let mut v = Vec::with_capacity(numel);
            for _ in 0..numel {
                v.push(r.f32()?);
            }
            Tensor::from_vec(&shape, v)?
        }
        Format::SparseF32 | Format::SparseQ8 => {
            let (idx, ascending, scale) = if version >= 2 {
                let (scale, _) = if fmt == Format::SparseQ8 {
                    (r.f32()?, r.f32()?)
                } else {
                    (1.0, 0.0)
                };
                // runs are ascending by construction
                (decode_site_index(r, spatial)?, true, scale)
            } else {
                let n = r.u32()? as usize;
                if n > spatial {
                    bail!("sparse count {n} exceeds {spatial} sites");
                }
                let (scale, _) = if fmt == Format::SparseQ8 {
                    (r.f32()?, r.f32()?)
                } else {
                    (1.0, 0.0)
                };
                let mut idx = Vec::with_capacity(n);
                let mut ascending = true;
                let mut prev: i64 = -1;
                for _ in 0..n {
                    let i = r.u32()? as usize;
                    if i >= spatial {
                        bail!("sparse index {i} out of {spatial}");
                    }
                    if (i as i64) <= prev {
                        ascending = false; // foreign encoder; don't seed cache
                    }
                    prev = i as i64;
                    idx.push(i);
                }
                (idx, ascending, scale)
            };
            let mut v = vec![0.0f32; numel];
            // decode values and rebuild the occupied-site index in the
            // same pass, so downstream consumers never rescan the grid
            let mut sites: Vec<u32> = Vec::with_capacity(idx.len());
            for &i in &idx {
                let mut nonzero = false;
                for ch in 0..channels {
                    let x = if fmt == Format::SparseQ8 {
                        (r.u8()? as i8) as f32 * scale
                    } else {
                        r.f32()?
                    };
                    nonzero |= x != 0.0;
                    v[i * channels + ch] = x;
                }
                if nonzero {
                    sites.push(i as u32);
                }
            }
            if ascending {
                Tensor::from_vec_with_sites(&shape, v, sites)?
            } else {
                Tensor::from_vec(&shape, v)?
            }
        }
        Format::MaskBitset => {
            let nbytes = numel.div_ceil(8);
            let bytes = r.take(nbytes)?;
            let mut sites: Vec<u32> = Vec::new();
            let v: Vec<f32> = (0..numel)
                .map(|i| {
                    let bit = (bytes[i / 8] >> (i % 8)) & 1;
                    if bit == 1 {
                        sites.push(i as u32);
                    }
                    f32::from(bit)
                })
                .collect();
            if channels == 1 {
                Tensor::from_vec_with_sites(&shape, v, sites)?
            } else {
                Tensor::from_vec(&shape, v)?
            }
        }
        Format::DenseQ8 => {
            let scale = r.f32()?;
            let _zp = r.f32()?;
            let mut v = Vec::with_capacity(numel);
            for _ in 0..numel {
                v.push((r.u8()? as i8) as f32 * scale);
            }
            Tensor::from_vec(&shape, v)?
        }
        Format::SparseF16 => {
            let idx = decode_site_index(r, spatial)?;
            let mut v = vec![0.0f32; numel];
            let mut sites: Vec<u32> = Vec::with_capacity(idx.len());
            for &i in &idx {
                let mut nonzero = false;
                for ch in 0..channels {
                    let x = f16_bits_to_f32(r.u16()?);
                    nonzero |= x != 0.0;
                    v[i * channels + ch] = x;
                }
                if nonzero {
                    sites.push(i as u32);
                }
            }
            Tensor::from_vec_with_sites(&shape, v, sites)?
        }
        Format::SparseQ8C => {
            let mut scales = Vec::with_capacity(channels);
            for _ in 0..channels {
                scales.push(r.f32()?);
            }
            let idx = decode_site_index(r, spatial)?;
            let mut v = vec![0.0f32; numel];
            let mut sites: Vec<u32> = Vec::with_capacity(idx.len());
            for &i in &idx {
                let mut nonzero = false;
                for (ch, &scale) in scales.iter().enumerate() {
                    let x = (r.u8()? as i8) as f32 * scale;
                    nonzero |= x != 0.0;
                    v[i * channels + ch] = x;
                }
                if nonzero {
                    sites.push(i as u32);
                }
            }
            Tensor::from_vec_with_sites(&shape, v, sites)?
        }
    };
    Ok((name, tensor))
}

// ----------------------------------------------------------------- packet

/// A named bundle of tensors crossing the link (one split boundary's live
/// set, or the final predictions coming back). Tensors are shared by
/// refcount — assembling a packet from a frame store never deep-copies.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    pub tensors: Vec<(String, Arc<Tensor>)>,
}

impl Packet {
    /// Build from owned tensors (tests, decoders, one-off callers).
    pub fn new(tensors: Vec<(String, Tensor)>) -> Packet {
        Packet {
            tensors: tensors
                .into_iter()
                .map(|(n, t)| (n, Arc::new(t)))
                .collect(),
        }
    }

    /// Build from shared tensors (the zero-copy frame hot path).
    pub fn from_shared(tensors: Vec<(String, Arc<Tensor>)>) -> Packet {
        Packet { tensors }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t.as_ref())
    }

    pub fn encode(&self, policy: Policy) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(policy, &mut buf);
        buf
    }

    /// Encode into a caller-owned buffer, cleared and presized to the
    /// exact encoded length (steady-state reuse allocates nothing once the
    /// buffer has grown to the working-set size). Writes the current
    /// [`WIRE_VERSION`] framing at exact f32 precision.
    pub fn encode_into(&self, policy: Policy, buf: &mut Vec<u8>) {
        self.encode_with(policy, WIRE_VERSION, WirePrecision::F32, buf);
    }

    /// Encode at a wire precision: f32 ships the byte-identical
    /// [`WIRE_VERSION`] (v2) frame, f16/int8 ship [`WIRE_VERSION_V3`]
    /// frames whose sparse payloads are quantized. The session hot path
    /// goes through here; `--wire f32` therefore cannot change a single
    /// bit on the link.
    pub fn encode_wire(&self, policy: Policy, precision: WirePrecision) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_wire_into(policy, precision, &mut buf);
        buf
    }

    /// [`Packet::encode_wire`] into a caller-owned (pooled) buffer.
    pub fn encode_wire_into(&self, policy: Policy, precision: WirePrecision, buf: &mut Vec<u8>) {
        self.encode_with(policy, precision.wire_version(), precision, buf);
    }

    /// [`Packet::encode_into`] with an explicit wire version: 1 = legacy
    /// raw-u32 site indices, 2 = delta/varint run-length, 3 = v2 index +
    /// quantized payload support. Decoders accept all three; new senders
    /// use the default (or [`Packet::encode_wire_into`] when a precision
    /// is configured). Public for cross-version tests, the
    /// `codec/encode_sparse_delta@legacy` bench twin, and senders that
    /// must interoperate with older peers — an unknown version (e.g. from
    /// a future peer's handshake) is a recoverable error, not a panic.
    /// Encoding *at* version 3 through this entry point keeps exact f32
    /// payloads: the version byte governs framing, the precision governs
    /// loss, and this method never makes a lossy choice on its own.
    pub fn encode_versioned_into(
        &self,
        policy: Policy,
        version: u8,
        buf: &mut Vec<u8>,
    ) -> Result<()> {
        if !(1..=WIRE_VERSION_V3).contains(&version) {
            bail!("unsupported encode version {version} (supported: 1..={WIRE_VERSION_V3})");
        }
        self.encode_with(policy, version, WirePrecision::F32, buf);
        Ok(())
    }

    fn encode_with(&self, policy: Policy, version: u8, precision: WirePrecision, buf: &mut Vec<u8>) {
        buf.clear();
        let exact = self.size_with(policy, version, precision);
        buf.reserve(exact);
        {
            let mut w = Writer { buf: &mut *buf };
            w.u32(MAGIC);
            w.u8(version);
            w.u32(self.tensors.len() as u32);
            for (name, t) in &self.tensors {
                encode_tensor(&mut w, name, t, plan(t, policy, version, precision), version);
            }
        }
        debug_assert_eq!(buf.len(), exact, "encoded_size drifted from encoder");
    }

    pub fn decode(bytes: &[u8]) -> Result<Packet> {
        let mut r = Reader::new(bytes);
        if r.u32()? != MAGIC {
            bail!("bad wire magic");
        }
        let version = r.u8()?;
        if !(1..=WIRE_VERSION_V3).contains(&version) {
            bail!("unsupported wire version {version}");
        }
        let n = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let (name, t) = decode_tensor(&mut r, version)?;
            tensors.push((name, Arc::new(t)));
        }
        if !r.done() {
            bail!("trailing bytes in wire packet");
        }
        Ok(Packet { tensors })
    }

    /// Encoded size without building the buffer (bench fast-path; also the
    /// exact presize for `encode_into`).
    pub fn encoded_size(&self, policy: Policy) -> usize {
        self.encoded_size_versioned(policy, WIRE_VERSION)
    }

    /// [`Packet::encoded_size`] at an explicit framing version (1 = legacy
    /// flat index, 2 = delta run-list, 3 = quantization-capable framing at
    /// exact f32). Costing versions side by side from one packet is how
    /// the session reports live v1-vs-v2 wire savings without encoding
    /// twice.
    pub fn encoded_size_versioned(&self, policy: Policy, version: u8) -> usize {
        self.size_with(policy, version, WirePrecision::F32)
    }

    /// Exact byte count [`Packet::encode_wire_into`] will produce for this
    /// precision (v3 quantized-payload costing included).
    pub fn encoded_size_wire(&self, policy: Policy, precision: WirePrecision) -> usize {
        self.size_with(policy, precision.wire_version(), precision)
    }

    fn size_with(&self, policy: Policy, version: u8, precision: WirePrecision) -> usize {
        let mut total = 4 + 1 + 4;
        for (name, t) in &self.tensors {
            total += 1 + name.len() + 1 + 1 + 4 * t.shape().len();
            total += plan(t, policy, version, precision).payload;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn masked_tensor(rng: &mut Rng, shape: &[usize], occupancy: f64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let c = t.channels();
        let spatial = t.spatial();
        for s in 0..spatial {
            if rng.chance(occupancy) {
                for ch in 0..c {
                    t.data_mut()[s * c + ch] = rng.normal() as f32;
                }
            }
        }
        t
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(1);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 3], 1.0);
        let p = Packet::new(vec![("x".into(), t.clone())]);
        let back = Packet::decode(&p.encode(Policy::Dense)).unwrap();
        assert_eq!(back.get("x").unwrap(), &t);
    }

    #[test]
    fn sparse_roundtrip_exact() {
        let mut rng = Rng::new(2);
        let t = masked_tensor(&mut rng, &[8, 16, 16, 8], 0.1);
        let p = Packet::new(vec![("f".into(), t.clone())]);
        let bytes = p.encode(Policy::Auto);
        assert!(bytes.len() < t.size_bytes() / 2, "sparse should win at 10%");
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back.get("f").unwrap(), &t);
        // decode rebuilds the occupied-site index in the same pass
        assert_eq!(back.get("f").unwrap().site_index(), t.site_index());
    }

    #[test]
    fn mask_bitset_roundtrip() {
        let mut rng = Rng::new(3);
        let mut m = Tensor::zeros(&[8, 16, 16, 1]);
        for x in m.data_mut() {
            *x = f32::from(rng.chance(0.3));
        }
        let p = Packet::new(vec![("m".into(), m.clone())]);
        let bytes = p.encode(Policy::Auto);
        // bitset: 2048 bits = 256 bytes + header
        assert!(bytes.len() < 400, "mask should bitset-encode, got {}", bytes.len());
        assert_eq!(Packet::decode(&bytes).unwrap().get("m").unwrap(), &m);
    }

    #[test]
    fn quantized_bounded_error() {
        let mut rng = Rng::new(4);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 16], 0.5);
        let p = Packet::new(vec![("q".into(), t.clone())]);
        let back = Packet::decode(&p.encode(Policy::AutoQuantized)).unwrap();
        let q = back.get("q").unwrap();
        let step = t.abs_max() / 127.0;
        assert!(t.max_abs_diff(q).unwrap() <= step * 0.5 + 1e-6);
    }

    #[test]
    fn auto_picks_dense_when_full() {
        let mut rng = Rng::new(5);
        let t = masked_tensor(&mut rng, &[4, 4, 4, 2], 1.0);
        let p = Packet::new(vec![("d".into(), t.clone())]);
        // sparse would cost indices on top of every value: dense must win
        assert!(p.encode(Policy::Auto).len() <= p.encode(Policy::Dense).len() + 16);
    }

    #[test]
    fn encoded_size_matches_actual() {
        let mut rng = Rng::new(6);
        for occ in [0.0, 0.05, 0.5, 1.0] {
            let t = masked_tensor(&mut rng, &[4, 8, 8, 4], occ);
            let m = {
                let mut m = Tensor::zeros(&[4, 8, 8, 1]);
                for x in m.data_mut() {
                    *x = f32::from(rng.chance(occ));
                }
                m
            };
            let p = Packet::new(vec![("f".into(), t), ("m".into(), m)]);
            for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
                assert_eq!(p.encode(policy).len(), p.encoded_size(policy), "{policy:?}");
            }
        }
    }

    #[test]
    fn multi_tensor_order_preserved() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![3.0, 4.0, 5.0]).unwrap();
        let p = Packet::new(vec![("a".into(), a), ("b".into(), b)]);
        let back = Packet::decode(&p.encode(Policy::Auto)).unwrap();
        assert_eq!(back.tensors[0].0, "a");
        assert_eq!(back.tensors[1].0, "b");
    }

    #[test]
    fn rejects_corrupt() {
        let t = Tensor::zeros(&[4, 4]);
        let p = Packet::new(vec![("x".into(), t)]);
        let mut bytes = p.encode(Policy::Dense);
        bytes[0] ^= 0xff;
        assert!(Packet::decode(&bytes).is_err());
        let p2 = Packet::new(vec![("y".into(), Tensor::zeros(&[2]))]);
        let good = p2.encode(Policy::Dense);
        assert!(Packet::decode(&good[..good.len() - 1]).is_err());
    }

    #[test]
    fn shared_and_owned_packets_encode_identically() {
        let mut rng = Rng::new(7);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 4], 0.2);
        let owned = Packet::new(vec![("t".into(), t.clone())]);
        let shared = Packet::from_shared(vec![("t".into(), Arc::new(t))]);
        for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
            assert_eq!(owned.encode(policy), shared.encode(policy), "{policy:?}");
        }
    }

    #[test]
    fn varint_roundtrips_across_widths() {
        for v in [0u32, 1, 127, 128, 129, 16383, 16384, 1 << 21, u32::MAX] {
            let mut buf = Vec::new();
            Writer { buf: &mut buf }.varint(v);
            assert_eq!(buf.len(), varint_len(v));
            let mut r = Reader::new(&buf);
            assert_eq!(r.varint().unwrap(), v, "varint {v}");
            assert!(r.done());
        }
    }

    #[test]
    fn site_runs_partition_the_index() {
        let sites = [0u32, 1, 2, 7, 9, 10, 500];
        let mut runs: Vec<(u32, u32)> = Vec::new();
        for_each_site_run(&sites, |gap, len| runs.push((gap, len)));
        // cursor: 0 -> 3 -> 8 -> 11 -> 501
        assert_eq!(runs, [(0, 3), (4, 1), (1, 2), (489, 1)]);
        let (v2_bytes, n_runs) = site_index_cost(&sites, 2);
        assert_eq!(n_runs, 4);
        let (v1_bytes, v1_runs) = site_index_cost(&sites, 1);
        assert_eq!((v1_bytes, v1_runs), (4 + sites.len() * 4, 0));
        assert!(v2_bytes < v1_bytes, "delta framing beats v1: {v2_bytes} vs {v1_bytes}");
    }

    #[test]
    fn delta_index_roundtrip_property() {
        // occupancies from empty to full, mixing long runs and singletons
        let mut rng = Rng::new(9);
        for occ in [0.0, 0.01, 0.1, 0.5, 0.95, 1.0] {
            let t = masked_tensor(&mut rng, &[8, 16, 16, 4], occ);
            let m = {
                let mut m = Tensor::zeros(&[8, 16, 16, 1]);
                for x in m.data_mut() {
                    *x = f32::from(rng.chance(occ));
                }
                m
            };
            let p = Packet::new(vec![("f".into(), t.clone()), ("m".into(), m.clone())]);
            for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
                let bytes = p.encode(policy);
                assert_eq!(bytes.len(), p.encoded_size(policy), "{policy:?} occ {occ}");
                let back = Packet::decode(&bytes).unwrap();
                if policy == Policy::AutoQuantized {
                    continue; // lossy; covered by quantized_bounded_error
                }
                assert_eq!(back.get("f").unwrap(), &t, "{policy:?} occ {occ}");
                assert_eq!(back.get("m").unwrap(), &m);
                // the rebuilt site cache is exact
                assert_eq!(back.get("f").unwrap().site_index(), t.site_index());
            }
        }
    }

    #[test]
    fn v1_framing_still_decodes_and_v2_is_smaller_on_runs() {
        // near-contiguous occupancy, like the fastest axis of a real scan
        let mut t = Tensor::zeros(&[4, 8, 32, 2]);
        for s in 0..(4 * 8 * 32) {
            if s % 40 < 25 {
                t.data_mut()[s * 2] = 1.5;
                t.data_mut()[s * 2 + 1] = -0.5;
            }
        }
        let p = Packet::new(vec![("t".into(), t.clone())]);
        let mut v1 = Vec::new();
        p.encode_versioned_into(Policy::Auto, 1, &mut v1).unwrap();
        let v2 = p.encode(Policy::Auto);
        // unknown versions are a recoverable error, not a panic
        assert!(p
            .encode_versioned_into(Policy::Auto, 4, &mut Vec::new())
            .is_err());
        assert_eq!(Packet::decode(&v1).unwrap().get("t").unwrap(), &t);
        assert_eq!(Packet::decode(&v2).unwrap().get("t").unwrap(), &t);
        assert!(
            v2.len() < v1.len(),
            "delta framing should shrink run-heavy indices: v2 {} vs v1 {}",
            v2.len(),
            v1.len()
        );
        // v1 decode also seeds the (ascending) site cache
        assert_eq!(
            Packet::decode(&v1).unwrap().get("t").unwrap().site_index(),
            t.site_index()
        );
    }

    #[test]
    fn rejects_bad_version_and_bad_runs() {
        let t = Tensor::from_vec(&[4], vec![1.0, 0.0, 2.0, 0.0]).unwrap();
        let p = Packet::new(vec![("t".into(), t)]);
        let mut bytes = p.encode(Policy::Dense);
        bytes[4] = 9; // version byte
        assert!(Packet::decode(&bytes).is_err());
        // truncating inside a sparse v2 index errors instead of panicking
        let sparse = {
            let mut t = Tensor::zeros(&[64, 1]);
            t.data_mut()[3] = 1.0;
            t.data_mut()[60] = 2.0;
            Packet::new(vec![("s".into(), t)]).encode(Policy::Auto)
        };
        for cut in 6..sparse.len() {
            let _ = Packet::decode(&sparse[..cut]); // must not panic
        }
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut rng = Rng::new(8);
        let mut buf = Vec::new();
        for occ in [0.8, 0.1, 0.0, 0.4] {
            let t = masked_tensor(&mut rng, &[4, 8, 8, 2], occ);
            let p = Packet::new(vec![("t".into(), t.clone())]);
            p.encode_into(Policy::Auto, &mut buf);
            assert_eq!(buf, p.encode(Policy::Auto));
            let back = Packet::decode(&buf).unwrap();
            assert_eq!(back.get("t").unwrap(), &t);
        }
    }

    // ------------------------------------------------------------- wire v3

    #[test]
    fn f16_conversion_known_vectors() {
        // hand-checked IEEE binary16 round-to-nearest-even vectors
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // tie rounds to even → Inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(2049.0), 0x6800); // tie → even (down)
        assert_eq!(f32_to_f16_bits(2051.0), 0x6802); // tie → even (up)
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000); // underflow tie → 0
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001); // smallest subnormal
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-14)), 0x0400); // smallest normal
        let nan = f32_to_f16_bits(f32::NAN);
        assert_eq!(nan & 0x7c00, 0x7c00);
        assert_ne!(nan & 0x03ff, 0, "NaN must stay NaN");
    }

    #[test]
    fn f16_bits_roundtrip_all_patterns() {
        // every f16 bit pattern except NaNs survives f16→f32→f16 exactly
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(x), h, "pattern {h:#06x}");
        }
    }

    #[test]
    fn int8_ties_round_to_even() {
        // scale 1.0: halfway values must go to the even neighbour on every
        // target (this is the cross-platform determinism pin)
        assert_eq!(quantize_i8(0.5, 1.0) as i8, 0);
        assert_eq!(quantize_i8(1.5, 1.0) as i8, 2);
        assert_eq!(quantize_i8(2.5, 1.0) as i8, 2);
        assert_eq!(quantize_i8(-0.5, 1.0) as i8, 0);
        assert_eq!(quantize_i8(-1.5, 1.0) as i8, -2);
        assert_eq!(quantize_i8(200.0, 1.0) as i8, 127);
        assert_eq!(quantize_i8(-200.0, 1.0) as i8, -127);
    }

    #[test]
    fn v3_f16_roundtrip_bounded_error() {
        let mut rng = Rng::new(21);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 8], 0.3);
        let p = Packet::new(vec![("t".into(), t.clone())]);
        let bytes = p.encode_wire(Policy::Auto, WirePrecision::F16);
        assert_eq!(bytes[4], WIRE_VERSION_V3);
        assert_eq!(bytes.len(), p.encoded_size_wire(Policy::Auto, WirePrecision::F16));
        let back = Packet::decode(&bytes).unwrap();
        let bt = back.get("t").unwrap();
        assert_eq!(bt.shape(), t.shape());
        for (a, b) in t.data().iter().zip(bt.data()) {
            // f16 has 11 significand bits → relative error ≤ 2^-11
            assert!((a - b).abs() <= a.abs() * 4.9e-4 + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn v3_int8_roundtrip_per_channel_scales() {
        // channel 1 is 100× larger than channel 0; a per-channel scale
        // keeps channel 0's error small where a global scale would not
        let mut t = Tensor::zeros(&[16, 2]);
        let mut rng = Rng::new(5);
        for s in 0..16 {
            if rng.chance(0.6) {
                t.data_mut()[s * 2] = rng.normal() as f32 * 0.01;
                t.data_mut()[s * 2 + 1] = rng.normal() as f32;
            }
        }
        let p = Packet::new(vec![("t".into(), t.clone())]);
        let bytes = p.encode_wire(Policy::Auto, WirePrecision::Int8);
        assert_eq!(bytes[4], WIRE_VERSION_V3);
        assert_eq!(bytes.len(), p.encoded_size_wire(Policy::Auto, WirePrecision::Int8));
        let back = Packet::decode(&bytes).unwrap();
        let bt = back.get("t").unwrap();
        let scales = channel_scales(&t);
        for s in 0..16 {
            for ch in 0..2 {
                let a = t.data()[s * 2 + ch];
                let b = bt.data()[s * 2 + ch];
                assert!(
                    (a - b).abs() <= scales[ch] * 0.5 + 1e-9,
                    "site {s} ch {ch}: {a} vs {b} (scale {})",
                    scales[ch]
                );
            }
        }
    }

    #[test]
    fn wire_f32_is_byte_identical_to_v2() {
        // the pin: `--wire f32` must not change a single bit on the link
        let mut rng = Rng::new(33);
        for occ in [0.0, 0.15, 0.7] {
            let t = masked_tensor(&mut rng, &[4, 8, 8, 4], occ);
            let p = Packet::new(vec![("t".into(), t)]);
            for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
                let v2 = p.encode(policy);
                let wire = p.encode_wire(policy, WirePrecision::F32);
                assert_eq!(v2, wire);
                assert_eq!(
                    p.encoded_size_wire(policy, WirePrecision::F32),
                    p.encoded_size(policy)
                );
            }
        }
    }

    #[test]
    fn v3_sizes_are_exact_for_all_precisions() {
        let mut rng = Rng::new(13);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 6], 0.25);
        let mask = {
            let mut m = Tensor::zeros(&[4, 8, 8, 1]);
            for s in 0..m.spatial() {
                if rng.chance(0.25) {
                    m.data_mut()[s] = 1.0;
                }
            }
            m
        };
        let p = Packet::new(vec![("feat".into(), t), ("mask".into(), mask)]);
        for prec in [WirePrecision::F32, WirePrecision::F16, WirePrecision::Int8] {
            let bytes = p.encode_wire(Policy::Auto, prec);
            assert_eq!(
                bytes.len(),
                p.encoded_size_wire(Policy::Auto, prec),
                "{prec:?}"
            );
            Packet::decode(&bytes).unwrap();
        }
        // lossy precisions must actually shrink the frame
        let f32b = p.encoded_size_wire(Policy::Auto, WirePrecision::F32);
        let f16b = p.encoded_size_wire(Policy::Auto, WirePrecision::F16);
        let i8b = p.encoded_size_wire(Policy::Auto, WirePrecision::Int8);
        assert!(f16b < f32b, "f16 {f16b} vs f32 {f32b}");
        assert!(i8b < f16b, "int8 {i8b} vs f16 {f16b}");
    }

    #[test]
    fn v3_masks_stay_exact_under_quantization() {
        // occupancy masks reconstruct exactly at every precision — the
        // bitset is already 1 bit and never goes through a lossy format
        let mut m = Tensor::zeros(&[128, 1]);
        for s in [0usize, 1, 2, 63, 100] {
            m.data_mut()[s] = 1.0;
        }
        let p = Packet::new(vec![("mask".into(), m.clone())]);
        for prec in [WirePrecision::F16, WirePrecision::Int8] {
            let back = Packet::decode(&p.encode_wire(Policy::Auto, prec)).unwrap();
            assert_eq!(back.get("mask").unwrap(), &m, "{prec:?}");
        }
    }

    #[test]
    fn v3_formats_rejected_under_v2_framing() {
        // a corrupt/hostile frame claiming v2 but carrying a v3 format
        // byte errors instead of misdecoding
        let mut rng = Rng::new(17);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 2], 0.3);
        let p = Packet::new(vec![("t".into(), t)]);
        let mut bytes = p.encode_wire(Policy::Auto, WirePrecision::F16);
        assert_eq!(bytes[4], WIRE_VERSION_V3);
        bytes[4] = WIRE_VERSION; // lie about the version
        assert!(Packet::decode(&bytes).is_err());
    }

    #[test]
    fn v3_truncation_never_panics() {
        let mut rng = Rng::new(29);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 3], 0.4);
        let p = Packet::new(vec![("t".into(), t)]);
        for prec in [WirePrecision::F16, WirePrecision::Int8] {
            let bytes = p.encode_wire(Policy::Auto, prec);
            for cut in 0..bytes.len() {
                let _ = Packet::decode(&bytes[..cut]); // must not panic
            }
        }
    }

    #[test]
    fn v3_framing_with_f32_precision_is_lossless() {
        // encode_versioned_into at version 3 keeps exact payloads — the
        // version byte governs framing, not loss
        let mut rng = Rng::new(41);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 4], 0.3);
        let p = Packet::new(vec![("t".into(), t.clone())]);
        let mut v3 = Vec::new();
        p.encode_versioned_into(Policy::Auto, WIRE_VERSION_V3, &mut v3)
            .unwrap();
        assert_eq!(v3[4], WIRE_VERSION_V3);
        assert_eq!(Packet::decode(&v3).unwrap().get("t").unwrap(), &t);
    }

    #[test]
    fn v3_quantized_encode_is_deterministic() {
        // same tensor → same bytes, every time (retransmit dedup relies on
        // bit-identical re-encodes)
        let mut rng = Rng::new(55);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 5], 0.35);
        let p = Packet::new(vec![("t".into(), t)]);
        for prec in [WirePrecision::F16, WirePrecision::Int8] {
            let a = p.encode_wire(Policy::Auto, prec);
            let b = p.encode_wire(Policy::Auto, prec);
            assert_eq!(a, b, "{prec:?}");
        }
    }

    #[test]
    fn wire_precision_parses() {
        assert_eq!(WirePrecision::parse("f32").unwrap(), WirePrecision::F32);
        assert_eq!(WirePrecision::parse("f16").unwrap(), WirePrecision::F16);
        assert_eq!(WirePrecision::parse("int8").unwrap(), WirePrecision::Int8);
        assert!(WirePrecision::parse("bf16").is_err());
        assert_eq!(WirePrecision::F32.wire_version(), WIRE_VERSION);
        assert_eq!(WirePrecision::F16.wire_version(), WIRE_VERSION_V3);
        assert_eq!(WirePrecision::Int8.wire_version(), WIRE_VERSION_V3);
    }
}
