//! Wire codec: how intermediate tensors cross the edge→server link.
//!
//! This is the byte-accounting substrate behind the paper's Fig 8/9. The
//! occupancy masks carried through the 3D backbone (spconv semantics, see
//! DESIGN.md §3) let feature volumes be encoded sparsely — exactly the
//! mechanism that makes the paper's VFE transfer (1.18 MB) smaller than the
//! raw cloud (1.84 MB) while in-network transfers balloon (7.2 / 29 MB).
//!
//! Formats:
//!   * `DenseF32`    — raw row-major f32 payload
//!   * `SparseF32`   — active-site indices (u32) + per-site channel values
//!   * `MaskBitset`  — 1 bit per site (occupancy masks reconstruct exactly)
//!   * `DenseQ8` / `SparseQ8` — int8 affine-quantized variants (the paper's
//!     §VI future-work compression; ablated in the bench suite)
//!
//! `encode_auto` picks the smallest exact format; quantized formats are
//! opt-in because they are lossy.
//!
//! Perf contract (see docs/PERF.md): packets hold `Arc<Tensor>` so frame
//! assembly never deep-copies; format choice and sparse emission run off
//! the tensor's cached occupied-site index (no rescans of dense grids);
//! `encode_into` writes into a caller-owned, exactly-presized buffer so a
//! steady-state encode performs no allocation beyond the first frame.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Tensor;

const MAGIC: u32 = 0x5350_5754; // "SPWT"

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    DenseF32 = 0,
    SparseF32 = 1,
    MaskBitset = 2,
    DenseQ8 = 3,
    SparseQ8 = 4,
}

impl Format {
    fn from_u8(b: u8) -> Result<Format> {
        Ok(match b {
            0 => Format::DenseF32,
            1 => Format::SparseF32,
            2 => Format::MaskBitset,
            3 => Format::DenseQ8,
            4 => Format::SparseQ8,
            _ => bail!("unknown wire format {b}"),
        })
    }

    pub fn lossy(self) -> bool {
        matches!(self, Format::DenseQ8 | Format::SparseQ8)
    }
}

/// Encoding policy, part of the coordinator config.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Smallest *exact* encoding (dense vs sparse vs bitset).
    #[default]
    Auto,
    /// Force dense f32 (what the paper's unmodified implementation ships).
    Dense,
    /// Smallest encoding allowing int8 quantization (paper §VI extension).
    AutoQuantized,
}

// ------------------------------------------------------------- primitives

/// Byte writer over a caller-owned buffer (reused across frames).
struct Writer<'a> {
    buf: &'a mut Vec<u8>,
}

impl Writer<'_> {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
    /// Append `n` zero bytes, returning their start offset.
    fn zeros(&mut self, n: usize) -> usize {
        let start = self.buf.len();
        self.buf.resize(start + n, 0);
        start
    }
    /// Set bit `bit` (LSB-first) inside the region starting at `start`.
    fn set_bit(&mut self, start: usize, bit: usize) {
        self.buf[start + bit / 8] |= 1 << (bit % 8);
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("wire truncated at {} (+{n})", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> bool {
        self.pos == self.b.len()
    }
}

// ---------------------------------------------------------- single tensor

/// Masks are single-channel tensors whose non-zero values are all exactly
/// 1 — checked over the occupied-site index only, never the dense buffer.
fn is_mask(t: &Tensor) -> bool {
    t.channels() == 1
        && t.site_index()
            .iter()
            .all(|&s| t.data()[s as usize] == 1.0)
}

fn sparse_bytes(sites: usize, channels: usize, quantized: bool) -> usize {
    let per_value = if quantized { 1 } else { 4 };
    4 + sites * (4 + channels * per_value) + if quantized { 8 } else { 0 }
}

/// Size in bytes each format would need for this tensor (without header).
pub fn payload_size(t: &Tensor, fmt: Format) -> usize {
    match fmt {
        Format::DenseF32 => t.size_bytes(),
        Format::SparseF32 => sparse_bytes(t.site_index().len(), t.channels(), false),
        Format::MaskBitset => t.spatial().div_ceil(8),
        Format::DenseQ8 => 8 + t.numel(),
        Format::SparseQ8 => sparse_bytes(t.site_index().len(), t.channels(), true),
    }
}

fn choose(t: &Tensor, policy: Policy) -> Format {
    match policy {
        Policy::Dense => Format::DenseF32,
        Policy::Auto => {
            let mut best = Format::DenseF32;
            if payload_size(t, Format::SparseF32) < payload_size(t, best) {
                best = Format::SparseF32;
            }
            if is_mask(t) && payload_size(t, Format::MaskBitset) < payload_size(t, best) {
                best = Format::MaskBitset;
            }
            best
        }
        Policy::AutoQuantized => {
            if is_mask(t) {
                // masks quantize to themselves; bitset is already 1 bit
                return choose(t, Policy::Auto);
            }
            let mut best = Format::DenseF32;
            for f in [Format::SparseF32, Format::DenseQ8, Format::SparseQ8] {
                if payload_size(t, f) < payload_size(t, best) {
                    best = f;
                }
            }
            best
        }
    }
}

fn quant_params(t: &Tensor) -> (f32, f32) {
    // symmetric affine: x ≈ scale * q, q ∈ [-127, 127]
    let m = t.abs_max();
    let scale = if m == 0.0 { 1.0 } else { m / 127.0 };
    (scale, 0.0)
}

fn encode_tensor(w: &mut Writer, name: &str, t: &Tensor, fmt: Format) {
    w.u8(name.len() as u8);
    w.bytes(name.as_bytes());
    w.u8(fmt as u8);
    w.u8(t.shape().len() as u8);
    for &d in t.shape() {
        w.u32(d as u32);
    }
    match fmt {
        Format::DenseF32 => {
            for &x in t.data() {
                w.f32(x);
            }
        }
        Format::SparseF32 | Format::SparseQ8 => {
            // single pass over the occupied-site index — no dense rescan
            let sites = t.site_index();
            let c = t.channels().max(1);
            w.u32(sites.len() as u32);
            let (scale, _) = quant_params(t);
            if fmt == Format::SparseQ8 {
                w.f32(scale);
                w.f32(0.0);
            }
            for &s in sites {
                w.u32(s);
            }
            let data = t.data();
            for &s in sites {
                let site = &data[s as usize * c..(s as usize + 1) * c];
                for &x in site {
                    if fmt == Format::SparseQ8 {
                        w.u8(((x / scale).round().clamp(-127.0, 127.0)) as i8 as u8);
                    } else {
                        w.f32(x);
                    }
                }
            }
        }
        Format::MaskBitset => {
            // set bits straight from the site index into a zeroed region
            let start = w.zeros(t.spatial().div_ceil(8));
            for &s in t.site_index() {
                w.set_bit(start, s as usize);
            }
        }
        Format::DenseQ8 => {
            let (scale, _) = quant_params(t);
            w.f32(scale);
            w.f32(0.0);
            for &x in t.data() {
                w.u8(((x / scale).round().clamp(-127.0, 127.0)) as i8 as u8);
            }
        }
    }
}

fn decode_tensor(r: &mut Reader) -> Result<(String, Tensor)> {
    let nlen = r.u8()? as usize;
    let name = String::from_utf8(r.take(nlen)?.to_vec()).context("tensor name")?;
    let fmt = Format::from_u8(r.u8()?)?;
    let ndim = r.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u32()? as usize);
    }
    let numel: usize = shape.iter().product();
    let channels = shape.last().copied().unwrap_or(1).max(1);
    let spatial = numel / channels;

    let tensor = match fmt {
        Format::DenseF32 => {
            let mut v = Vec::with_capacity(numel);
            for _ in 0..numel {
                v.push(r.f32()?);
            }
            Tensor::from_vec(&shape, v)?
        }
        Format::SparseF32 | Format::SparseQ8 => {
            let n = r.u32()? as usize;
            if n > spatial {
                bail!("sparse count {n} exceeds {spatial} sites");
            }
            let (scale, _) = if fmt == Format::SparseQ8 {
                (r.f32()?, r.f32()?)
            } else {
                (1.0, 0.0)
            };
            let mut idx = Vec::with_capacity(n);
            let mut ascending = true;
            let mut prev: i64 = -1;
            for _ in 0..n {
                let i = r.u32()? as usize;
                if i >= spatial {
                    bail!("sparse index {i} out of {spatial}");
                }
                if (i as i64) <= prev {
                    ascending = false; // foreign encoder; don't seed cache
                }
                prev = i as i64;
                idx.push(i);
            }
            let mut v = vec![0.0f32; numel];
            // decode values and rebuild the occupied-site index in the
            // same pass, so downstream consumers never rescan the grid
            let mut sites: Vec<u32> = Vec::with_capacity(n);
            for &i in &idx {
                let mut nonzero = false;
                for ch in 0..channels {
                    let x = if fmt == Format::SparseQ8 {
                        (r.u8()? as i8) as f32 * scale
                    } else {
                        r.f32()?
                    };
                    nonzero |= x != 0.0;
                    v[i * channels + ch] = x;
                }
                if nonzero {
                    sites.push(i as u32);
                }
            }
            if ascending {
                Tensor::from_vec_with_sites(&shape, v, sites)?
            } else {
                Tensor::from_vec(&shape, v)?
            }
        }
        Format::MaskBitset => {
            let nbytes = numel.div_ceil(8);
            let bytes = r.take(nbytes)?;
            let mut sites: Vec<u32> = Vec::new();
            let v: Vec<f32> = (0..numel)
                .map(|i| {
                    let bit = (bytes[i / 8] >> (i % 8)) & 1;
                    if bit == 1 {
                        sites.push(i as u32);
                    }
                    f32::from(bit)
                })
                .collect();
            if channels == 1 {
                Tensor::from_vec_with_sites(&shape, v, sites)?
            } else {
                Tensor::from_vec(&shape, v)?
            }
        }
        Format::DenseQ8 => {
            let scale = r.f32()?;
            let _zp = r.f32()?;
            let mut v = Vec::with_capacity(numel);
            for _ in 0..numel {
                v.push((r.u8()? as i8) as f32 * scale);
            }
            Tensor::from_vec(&shape, v)?
        }
    };
    Ok((name, tensor))
}

// ----------------------------------------------------------------- packet

/// A named bundle of tensors crossing the link (one split boundary's live
/// set, or the final predictions coming back). Tensors are shared by
/// refcount — assembling a packet from a frame store never deep-copies.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    pub tensors: Vec<(String, Arc<Tensor>)>,
}

impl Packet {
    /// Build from owned tensors (tests, decoders, one-off callers).
    pub fn new(tensors: Vec<(String, Tensor)>) -> Packet {
        Packet {
            tensors: tensors
                .into_iter()
                .map(|(n, t)| (n, Arc::new(t)))
                .collect(),
        }
    }

    /// Build from shared tensors (the zero-copy frame hot path).
    pub fn from_shared(tensors: Vec<(String, Arc<Tensor>)>) -> Packet {
        Packet { tensors }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t.as_ref())
    }

    pub fn encode(&self, policy: Policy) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(policy, &mut buf);
        buf
    }

    /// Encode into a caller-owned buffer, cleared and presized to the
    /// exact encoded length (steady-state reuse allocates nothing once the
    /// buffer has grown to the working-set size).
    pub fn encode_into(&self, policy: Policy, buf: &mut Vec<u8>) {
        buf.clear();
        let exact = self.encoded_size(policy);
        buf.reserve(exact);
        {
            let mut w = Writer { buf: &mut *buf };
            w.u32(MAGIC);
            w.u8(1); // version
            w.u32(self.tensors.len() as u32);
            for (name, t) in &self.tensors {
                let fmt = choose(t, policy);
                encode_tensor(&mut w, name, t, fmt);
            }
        }
        debug_assert_eq!(buf.len(), exact, "encoded_size drifted from encoder");
    }

    pub fn decode(bytes: &[u8]) -> Result<Packet> {
        let mut r = Reader::new(bytes);
        if r.u32()? != MAGIC {
            bail!("bad wire magic");
        }
        if r.u8()? != 1 {
            bail!("unsupported wire version");
        }
        let n = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let (name, t) = decode_tensor(&mut r)?;
            tensors.push((name, Arc::new(t)));
        }
        if !r.done() {
            bail!("trailing bytes in wire packet");
        }
        Ok(Packet { tensors })
    }

    /// Encoded size without building the buffer (bench fast-path; also the
    /// exact presize for `encode_into`).
    pub fn encoded_size(&self, policy: Policy) -> usize {
        let mut total = 4 + 1 + 4;
        for (name, t) in &self.tensors {
            let fmt = choose(t, policy);
            total += 1 + name.len() + 1 + 1 + 4 * t.shape().len();
            total += payload_size(t, fmt);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn masked_tensor(rng: &mut Rng, shape: &[usize], occupancy: f64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let c = t.channels();
        let spatial = t.spatial();
        for s in 0..spatial {
            if rng.chance(occupancy) {
                for ch in 0..c {
                    t.data_mut()[s * c + ch] = rng.normal() as f32;
                }
            }
        }
        t
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(1);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 3], 1.0);
        let p = Packet::new(vec![("x".into(), t.clone())]);
        let back = Packet::decode(&p.encode(Policy::Dense)).unwrap();
        assert_eq!(back.get("x").unwrap(), &t);
    }

    #[test]
    fn sparse_roundtrip_exact() {
        let mut rng = Rng::new(2);
        let t = masked_tensor(&mut rng, &[8, 16, 16, 8], 0.1);
        let p = Packet::new(vec![("f".into(), t.clone())]);
        let bytes = p.encode(Policy::Auto);
        assert!(bytes.len() < t.size_bytes() / 2, "sparse should win at 10%");
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back.get("f").unwrap(), &t);
        // decode rebuilds the occupied-site index in the same pass
        assert_eq!(back.get("f").unwrap().site_index(), t.site_index());
    }

    #[test]
    fn mask_bitset_roundtrip() {
        let mut rng = Rng::new(3);
        let mut m = Tensor::zeros(&[8, 16, 16, 1]);
        for x in m.data_mut() {
            *x = f32::from(rng.chance(0.3));
        }
        let p = Packet::new(vec![("m".into(), m.clone())]);
        let bytes = p.encode(Policy::Auto);
        // bitset: 2048 bits = 256 bytes + header
        assert!(bytes.len() < 400, "mask should bitset-encode, got {}", bytes.len());
        assert_eq!(Packet::decode(&bytes).unwrap().get("m").unwrap(), &m);
    }

    #[test]
    fn quantized_bounded_error() {
        let mut rng = Rng::new(4);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 16], 0.5);
        let p = Packet::new(vec![("q".into(), t.clone())]);
        let back = Packet::decode(&p.encode(Policy::AutoQuantized)).unwrap();
        let q = back.get("q").unwrap();
        let step = t.abs_max() / 127.0;
        assert!(t.max_abs_diff(q).unwrap() <= step * 0.5 + 1e-6);
    }

    #[test]
    fn auto_picks_dense_when_full() {
        let mut rng = Rng::new(5);
        let t = masked_tensor(&mut rng, &[4, 4, 4, 2], 1.0);
        let p = Packet::new(vec![("d".into(), t.clone())]);
        // sparse would cost indices on top of every value: dense must win
        assert!(p.encode(Policy::Auto).len() <= p.encode(Policy::Dense).len() + 16);
    }

    #[test]
    fn encoded_size_matches_actual() {
        let mut rng = Rng::new(6);
        for occ in [0.0, 0.05, 0.5, 1.0] {
            let t = masked_tensor(&mut rng, &[4, 8, 8, 4], occ);
            let m = {
                let mut m = Tensor::zeros(&[4, 8, 8, 1]);
                for x in m.data_mut() {
                    *x = f32::from(rng.chance(occ));
                }
                m
            };
            let p = Packet::new(vec![("f".into(), t), ("m".into(), m)]);
            for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
                assert_eq!(p.encode(policy).len(), p.encoded_size(policy), "{policy:?}");
            }
        }
    }

    #[test]
    fn multi_tensor_order_preserved() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![3.0, 4.0, 5.0]).unwrap();
        let p = Packet::new(vec![("a".into(), a), ("b".into(), b)]);
        let back = Packet::decode(&p.encode(Policy::Auto)).unwrap();
        assert_eq!(back.tensors[0].0, "a");
        assert_eq!(back.tensors[1].0, "b");
    }

    #[test]
    fn rejects_corrupt() {
        let t = Tensor::zeros(&[4, 4]);
        let p = Packet::new(vec![("x".into(), t)]);
        let mut bytes = p.encode(Policy::Dense);
        bytes[0] ^= 0xff;
        assert!(Packet::decode(&bytes).is_err());
        let p2 = Packet::new(vec![("y".into(), Tensor::zeros(&[2]))]);
        let good = p2.encode(Policy::Dense);
        assert!(Packet::decode(&good[..good.len() - 1]).is_err());
    }

    #[test]
    fn shared_and_owned_packets_encode_identically() {
        let mut rng = Rng::new(7);
        let t = masked_tensor(&mut rng, &[4, 8, 8, 4], 0.2);
        let owned = Packet::new(vec![("t".into(), t.clone())]);
        let shared = Packet::from_shared(vec![("t".into(), Arc::new(t))]);
        for policy in [Policy::Auto, Policy::Dense, Policy::AutoQuantized] {
            assert_eq!(owned.encode(policy), shared.encode(policy), "{policy:?}");
        }
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut rng = Rng::new(8);
        let mut buf = Vec::new();
        for occ in [0.8, 0.1, 0.0, 0.4] {
            let t = masked_tensor(&mut rng, &[4, 8, 8, 2], occ);
            let p = Packet::new(vec![("t".into(), t.clone())]);
            p.encode_into(Policy::Auto, &mut buf);
            assert_eq!(buf, p.encode(Policy::Auto));
            let back = Packet::decode(&buf).unwrap();
            assert_eq!(back.get("t").unwrap(), &t);
        }
    }
}
