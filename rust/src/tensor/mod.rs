//! Dense f32 tensor type shared by every rust-side stage.
//!
//! Deliberately minimal: the heavy math lives in the AOT'd XLA modules (or
//! the in-crate reference executor); rust only voxelizes, routes, encodes
//! and post-processes. Layout is row-major (last dim fastest), matching
//! XLA's default `{n-1, ..., 1, 0}` layout so literals copy straight
//! through.
//!
//! Tensors carry a lazily-built **occupied-site index** (ascending flat
//! site indices whose channel vector is non-zero). The voxelizer seeds it
//! during the scatter pass and the sparse wire codec decodes straight into
//! it, so the per-frame hot path never rescans a dense grid to find the
//! active set. Any mutable access invalidates the index.

pub mod codec;

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

/// A dense row-major f32 tensor with a cached occupied-site index.
#[derive(Debug, Clone)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
    /// Ascending flat *site* indices (sites = all dims but the channel
    /// dim) with at least one non-zero channel. Lazy; see module docs.
    sites: OnceLock<Arc<Vec<u32>>>,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

fn compute_sites(channels: usize, data: &[f32]) -> Vec<u32> {
    let c = channels.max(1);
    data.chunks_exact(c)
        .enumerate()
        .filter(|(_, site)| site.iter().any(|&x| x != 0.0))
        .map(|(i, _)| i as u32)
        .collect()
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
            sites: OnceLock::new(),
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
            sites: OnceLock::new(),
        })
    }

    /// Like [`Tensor::from_vec`] but with the occupied-site index already
    /// known (ascending, exact). Producers that walk their active set
    /// anyway (voxelizer scatter, sparse decode, the reference executor)
    /// seed the cache so consumers never rescan the dense buffer.
    pub fn from_vec_with_sites(
        shape: &[usize],
        data: Vec<f32>,
        sites: Vec<u32>,
    ) -> Result<Tensor> {
        let t = Tensor::from_vec(shape, data)?;
        debug_assert_eq!(
            sites,
            compute_sites(t.channels(), t.data()),
            "seeded site index is not the exact active set"
        );
        let _ = t.sites.set(Arc::new(sites));
        Ok(t)
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
            sites: OnceLock::new(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.sites.take(); // mutation invalidates the site index
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of "spatial" sites when the last dim is channels.
    pub fn spatial(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    /// Channel count (last dim; 1 for rank-0/1 tensors).
    pub fn channels(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    /// Flat index for a multi-index. Debug-checked.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut f = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {idx:?} out of shape {:?} at {i}", self.shape);
            f = f * d + x;
        }
        f
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let f = self.flat(idx);
        self.sites.take();
        self.data[f] = v;
    }

    /// The occupied-site index: ascending flat site indices with any
    /// non-zero channel. Computed once and cached; seeded by producers
    /// that already know the active set.
    pub fn site_index(&self) -> &[u32] {
        self.sites
            .get_or_init(|| Arc::new(compute_sites(self.channels(), &self.data)))
            .as_slice()
    }

    /// Shared handle to the site index (pool recycling keeps it alive
    /// while the buffer is being cleared).
    pub fn site_index_arc(&self) -> Arc<Vec<u32>> {
        self.site_index();
        self.sites.get().expect("initialized above").clone()
    }

    /// Seed the site index on an already-built tensor (no-op if a cache
    /// exists). `sites` must be the exact ascending active set.
    pub(crate) fn seed_sites(&self, sites: Vec<u32>) {
        debug_assert_eq!(
            sites,
            compute_sites(self.channels(), &self.data),
            "seeded site index is not the exact active set"
        );
        let _ = self.sites.set(Arc::new(sites));
    }

    /// Max |x| over the tensor (codec calibration).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Fraction of spatial sites with any non-zero channel.
    pub fn occupancy(&self) -> f64 {
        let spatial = self.spatial();
        if spatial == 0 || self.data.is_empty() {
            return 0.0;
        }
        self.site_index().len() as f64 / spatial as f64
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        self.sites.take(); // channel dim may have changed
        Ok(self)
    }

    /// Symmetric allclose: |a - b| <= atol + rtol * max(|a|, |b|), so
    /// `a.allclose(b) == b.allclose(a)` for every (rtol, atol).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= atol + rtol * a.abs().max(b.abs()))
    }

    /// Largest absolute elementwise difference (∞-norm); None on shape mismatch.
    pub fn max_abs_diff(&self, other: &Tensor) -> Option<f32> {
        if self.shape != other.shape {
            return None;
        }
        Some(
            self.data
                .iter()
                .zip(&other.data)
                .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs())),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_indexing_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.data()[12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
    }

    #[test]
    fn occupancy_counts_sites_not_elements() {
        let mut t = Tensor::zeros(&[2, 2, 2]); // 4 sites, 2 channels
        t.set(&[0, 0, 1], 5.0);
        t.set(&[1, 1, 0], -1.0);
        assert!((t.occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let r = t.clone().reshaped(&[3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.clone().reshaped(&[4, 2]).is_err());
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.0, 2.0 + 1e-6]).unwrap();
        assert!(a.allclose(&b, 1e-4, 1e-4));
        assert!(a.max_abs_diff(&b).unwrap() < 1e-5);
        let c = Tensor::zeros(&[3]);
        assert_eq!(a.max_abs_diff(&c), None);
    }

    #[test]
    fn allclose_is_symmetric() {
        // regression: rtol used to scale only |b|, making the relation
        // asymmetric around zero on one side
        let a = Tensor::from_vec(&[1], vec![100.0]).unwrap();
        let b = Tensor::from_vec(&[1], vec![100.0 + 5e-3]).unwrap();
        assert_eq!(a.allclose(&b, 1e-4, 0.0), b.allclose(&a, 1e-4, 0.0));
        let z = Tensor::from_vec(&[1], vec![0.0]).unwrap();
        let s = Tensor::from_vec(&[1], vec![1e-3]).unwrap();
        assert_eq!(z.allclose(&s, 1e-2, 0.0), s.allclose(&z, 1e-2, 0.0));
    }

    #[test]
    fn site_index_tracks_mutation() {
        let mut t = Tensor::zeros(&[2, 2, 3]); // 4 sites, 3 channels
        assert!(t.site_index().is_empty());
        t.set(&[1, 0, 2], 4.0);
        assert_eq!(t.site_index(), &[2]);
        t.set(&[0, 1, 0], -1.0);
        assert_eq!(t.site_index(), &[1, 2]);
        t.data_mut().fill(0.0);
        assert!(t.site_index().is_empty());
    }

    #[test]
    fn seeded_site_index_is_used() {
        let t =
            Tensor::from_vec_with_sites(&[2, 2], vec![0.0, 0.0, 1.0, 0.5], vec![1]).unwrap();
        assert_eq!(t.site_index(), &[1]);
        assert!((t.occupancy() - 0.5).abs() < 1e-12);
        // clones share the cached index
        let c = t.clone();
        assert_eq!(c.site_index(), &[1]);
    }
}
