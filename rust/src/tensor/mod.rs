//! Dense f32 tensor type shared by every rust-side stage.
//!
//! Deliberately minimal: the heavy math lives in the AOT'd XLA modules;
//! rust only voxelizes, routes, encodes and post-processes. Layout is
//! row-major (last dim fastest), matching XLA's default
//! `{n-1, ..., 1, 0}` layout so literals copy straight through.

pub mod codec;

use anyhow::{bail, Result};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of "spatial" sites when the last dim is channels.
    pub fn spatial(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    /// Channel count (last dim; 1 for rank-0/1 tensors).
    pub fn channels(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    /// Flat index for a multi-index. Debug-checked.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut f = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {idx:?} out of shape {:?} at {i}", self.shape);
            f = f * d + x;
        }
        f
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let f = self.flat(idx);
        self.data[f] = v;
    }

    /// Max |x| over the tensor (codec calibration).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Fraction of spatial sites with any non-zero channel.
    pub fn occupancy(&self) -> f64 {
        let c = self.channels();
        if self.data.is_empty() {
            return 0.0;
        }
        let occ = self
            .data
            .chunks_exact(c.max(1))
            .filter(|site| site.iter().any(|&x| x != 0.0))
            .count();
        occ as f64 / self.spatial() as f64
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Largest absolute elementwise difference (∞-norm); None on shape mismatch.
    pub fn max_abs_diff(&self, other: &Tensor) -> Option<f32> {
        if self.shape != other.shape {
            return None;
        }
        Some(
            self.data
                .iter()
                .zip(&other.data)
                .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs())),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_indexing_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.data()[1 * 12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
    }

    #[test]
    fn occupancy_counts_sites_not_elements() {
        let mut t = Tensor::zeros(&[2, 2, 2]); // 4 sites, 2 channels
        t.set(&[0, 0, 1], 5.0);
        t.set(&[1, 1, 0], -1.0);
        assert!((t.occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        let r = t.clone().reshaped(&[3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.clone().reshaped(&[4, 2]).is_err());
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.0, 2.0 + 1e-6]).unwrap();
        assert!(a.allclose(&b, 1e-4, 1e-4));
        assert!(a.max_abs_diff(&b).unwrap() < 1e-5);
        let c = Tensor::zeros(&[3]);
        assert_eq!(a.max_abs_diff(&c), None);
    }
}
