//! System configuration: device profiles, link model, coordinator policy.
//!
//! JSON on disk (see `util::json`); presets encode the paper's testbed.
//! Calibration (EXPERIMENTS.md §Calibration): per-module edge factors are
//! fitted to the paper's Table I profile (322 ms edge-only with the
//! published module shares), the server is the paper-implied 5.4x faster,
//! and the link bandwidth is anchored on one Fig 9 point (conv2: 313 ms).
//! Every other number in Figs 6–9 is then a *prediction*.

use std::path::Path;

use anyhow::{Context, Result};

use crate::tensor::codec::{Policy, WirePrecision};
use crate::util::json::{self, Value};

/// Compute profile of one device tier.
///
/// Both halves execute real XLA compute on this host; measured wall time is
/// scaled onto the virtual clock to model the device (DESIGN.md §3,
/// hardware substitution). `module_factors` hold per-module multipliers —
/// necessary because relative module costs differ across substrates (the
/// paper's Jetson GPU runs sparse convolutions far cheaper, relative to its
/// RoI head, than this host's dense single-core convs; the paper's own
/// Table I pins the target profile). A module without an override uses
/// `slowdown`.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// default virtual-time multiplier over measured host time
    pub slowdown: f64,
    /// per-module multiplier overrides (module name -> factor)
    pub module_factors: std::collections::BTreeMap<String, f64>,
}

impl DeviceProfile {
    pub fn host() -> DeviceProfile {
        DeviceProfile {
            name: "host".into(),
            slowdown: 1.0,
            module_factors: Default::default(),
        }
    }

    pub fn uniform(name: &str, slowdown: f64) -> DeviceProfile {
        DeviceProfile {
            name: name.into(),
            slowdown,
            module_factors: Default::default(),
        }
    }

    /// Virtual-time multiplier for one module.
    pub fn factor_for(&self, module: &str) -> f64 {
        self.module_factors
            .get(module)
            .copied()
            .unwrap_or(self.slowdown)
    }
}

/// Network link between edge device and edge server.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkConfig {
    /// payload bandwidth in bytes/second
    pub bandwidth_bps: f64,
    /// one-way latency in seconds
    pub rtt_one_way: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        // Calibrated against the paper's conv2 transfer point (Fig 8/9:
        // 29 MB in 313 ms on their testbed -> our conv2 live set, ~0.64 MB
        // on the scaled grid, in the same 313 ms). One fitted constant;
        // every other transfer time is then a prediction. See
        // EXPERIMENTS.md §Calibration.
        LinkConfig {
            bandwidth_bps: 2.50e6,
            rtt_one_way: 0.0002,
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub edge: DeviceProfile,
    pub server: DeviceProfile,
    pub link: LinkConfig,
    pub codec: Policy,
    /// uplink payload precision: f32 ships byte-identical v2 frames,
    /// f16/int8 ship lossy v3 quantized frames (`--wire`)
    pub wire: WirePrecision,
    /// default split point by name ("vfe", "conv1", …, "raw", "edge_only")
    pub split: String,
    /// batcher: max frames per batch and max wait before flushing
    pub batch_max: usize,
    pub batch_wait_ms: f64,
    pub score_threshold: f32,
    pub nms_iou: f32,
    /// run with real sleeps + TCP instead of the virtual clock
    pub realtime: bool,
}

/// Per-module Jetson Orin Nano factors, calibrated so the simulated edge
/// device reproduces the paper's Table I exactly (322 ms edge-only with the
/// published module shares): factor = jetson_target_ms / host_measured_ms,
/// snapshot from `splitpoint calibrate` on the reference box. The server is
/// the same profile scaled by the paper-implied 5.4x speedup (Fig 6's VFE
/// split: 93.9 total − 33.6 edge ≈ 60 ms for the 321 ms Jetson tail).
fn jetson_module_factors() -> std::collections::BTreeMap<String, f64> {
    [
        ("preprocess", 0.074),
        ("vfe", 0.025),
        ("conv1", 0.119),
        ("conv2", 0.119),
        ("conv3", 0.119),
        ("conv4", 0.119),
        ("bev_head", 2.55),
        ("proposal", 3.19),
        ("roi_head", 3.81),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect()
}

/// Paper-implied edge-server speedup over the Jetson (see above).
pub const SERVER_SPEEDUP: f64 = 5.4;

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            edge: DeviceProfile {
                name: "jetson-orin-nano".into(),
                slowdown: 0.119,
                module_factors: jetson_module_factors(),
            },
            server: DeviceProfile {
                name: "edge-server".into(),
                slowdown: 0.119 / SERVER_SPEEDUP,
                module_factors: jetson_module_factors()
                    .into_iter()
                    .map(|(k, v)| (k, v / SERVER_SPEEDUP))
                    .collect(),
            },
            link: LinkConfig::default(),
            codec: Policy::Auto,
            wire: WirePrecision::F32,
            split: "vfe".into(),
            batch_max: 4,
            batch_wait_ms: 5.0,
            score_threshold: 0.3,
            nms_iou: 0.7,
            realtime: false,
        }
    }
}

impl SystemConfig {
    /// The paper's testbed: Jetson Orin Nano + edge server over the link
    /// implied by Figs 8–9.
    pub fn paper() -> SystemConfig {
        SystemConfig::default()
    }

    /// Dense-codec variant: what the unmodified paper implementation ships
    /// (it transfers intermediate tensors as-is, §VI notes compression as
    /// future work).
    pub fn paper_dense() -> SystemConfig {
        SystemConfig {
            codec: Policy::Dense,
            ..SystemConfig::default()
        }
    }

    pub fn to_json(&self) -> Value {
        let device_json = |d: &DeviceProfile| {
            Value::obj(vec![
                ("name", Value::str(&d.name)),
                ("slowdown", Value::num(d.slowdown)),
                (
                    "module_factors",
                    Value::Obj(
                        d.module_factors
                            .iter()
                            .map(|(k, &v)| (k.clone(), Value::num(v)))
                            .collect(),
                    ),
                ),
            ])
        };
        Value::obj(vec![
            ("edge", device_json(&self.edge)),
            ("server", device_json(&self.server)),
            (
                "link",
                Value::obj(vec![
                    ("bandwidth_bps", Value::num(self.link.bandwidth_bps)),
                    ("rtt_one_way", Value::num(self.link.rtt_one_way)),
                ]),
            ),
            (
                "codec",
                Value::str(match self.codec {
                    Policy::Auto => "auto",
                    Policy::Dense => "dense",
                    Policy::AutoQuantized => "auto_quantized",
                }),
            ),
            ("wire", Value::str(self.wire.as_str())),
            ("split", Value::str(&self.split)),
            ("batch_max", Value::num(self.batch_max as f64)),
            ("batch_wait_ms", Value::num(self.batch_wait_ms)),
            ("score_threshold", Value::num(self.score_threshold as f64)),
            ("nms_iou", Value::num(self.nms_iou as f64)),
            ("realtime", Value::Bool(self.realtime)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<SystemConfig> {
        let d = SystemConfig::default();
        let device = |key: &str, dft: &DeviceProfile| -> DeviceProfile {
            DeviceProfile {
                name: v
                    .at(&[key, "name"])
                    .and_then(Value::as_str)
                    .unwrap_or(&dft.name)
                    .to_string(),
                slowdown: v
                    .at(&[key, "slowdown"])
                    .and_then(Value::as_f64)
                    .unwrap_or(dft.slowdown),
                module_factors: match v.at(&[key, "module_factors"]).and_then(Value::as_obj) {
                    Some(m) => m
                        .iter()
                        .filter_map(|(k, x)| x.as_f64().map(|f| (k.clone(), f)))
                        .collect(),
                    // explicit device block without factors = uniform
                    None if v.get(key).is_some() => Default::default(),
                    None => dft.module_factors.clone(),
                },
            }
        };
        let codec = match v.get("codec").and_then(Value::as_str) {
            Some("dense") => Policy::Dense,
            Some("auto_quantized") => Policy::AutoQuantized,
            Some("auto") | None => Policy::Auto,
            Some(other) => anyhow::bail!("unknown codec policy '{other}'"),
        };
        let wire = match v.get("wire").and_then(Value::as_str) {
            Some(s) => WirePrecision::parse(s)?,
            None => WirePrecision::F32,
        };
        Ok(SystemConfig {
            edge: device("edge", &d.edge),
            server: device("server", &d.server),
            link: LinkConfig {
                bandwidth_bps: v
                    .at(&["link", "bandwidth_bps"])
                    .and_then(Value::as_f64)
                    .unwrap_or(d.link.bandwidth_bps),
                rtt_one_way: v
                    .at(&["link", "rtt_one_way"])
                    .and_then(Value::as_f64)
                    .unwrap_or(d.link.rtt_one_way),
            },
            codec,
            wire,
            split: v
                .get("split")
                .and_then(Value::as_str)
                .unwrap_or(&d.split)
                .to_string(),
            batch_max: v
                .get("batch_max")
                .and_then(Value::as_usize)
                .unwrap_or(d.batch_max),
            batch_wait_ms: v
                .get("batch_wait_ms")
                .and_then(Value::as_f64)
                .unwrap_or(d.batch_wait_ms),
            score_threshold: v
                .get("score_threshold")
                .and_then(Value::as_f64)
                .unwrap_or(d.score_threshold as f64) as f32,
            nms_iou: v
                .get("nms_iou")
                .and_then(Value::as_f64)
                .unwrap_or(d.nms_iou as f64) as f32,
            realtime: v
                .get("realtime")
                .and_then(Value::as_bool)
                .unwrap_or(d.realtime),
        })
    }

    pub fn load(path: &Path) -> Result<SystemConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing config {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = SystemConfig::paper();
        c.split = "conv2".into();
        c.codec = Policy::AutoQuantized;
        c.wire = WirePrecision::Int8;
        c.link.bandwidth_bps = 1e6;
        let back = SystemConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.split, "conv2");
        assert_eq!(back.codec, Policy::AutoQuantized);
        assert_eq!(back.wire, WirePrecision::Int8);
        assert_eq!(back.link.bandwidth_bps, 1e6);
        assert_eq!(back.edge, c.edge);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = json::parse(r#"{"split": "conv1"}"#).unwrap();
        let c = SystemConfig::from_json(&v).unwrap();
        assert_eq!(c.split, "conv1");
        // unspecified devices keep the calibrated paper profile
        assert_eq!(c.server, SystemConfig::default().server);
        assert!(!c.edge.module_factors.is_empty());
        assert_eq!(c.codec, Policy::Auto);

        // an explicit device block without factors means uniform scaling
        let v2 = json::parse(r#"{"edge": {"name": "x", "slowdown": 3.0}}"#).unwrap();
        let c2 = SystemConfig::from_json(&v2).unwrap();
        assert!(c2.edge.module_factors.is_empty());
        assert_eq!(c2.edge.factor_for("conv1"), 3.0);
    }

    #[test]
    fn rejects_unknown_codec() {
        let v = json::parse(r#"{"codec": "zip"}"#).unwrap();
        assert!(SystemConfig::from_json(&v).is_err());
    }

    #[test]
    fn wire_defaults_to_f32_and_rejects_unknown() {
        let v = json::parse(r#"{"split": "conv1"}"#).unwrap();
        assert_eq!(
            SystemConfig::from_json(&v).unwrap().wire,
            WirePrecision::F32
        );
        let v = json::parse(r#"{"wire": "f16"}"#).unwrap();
        assert_eq!(
            SystemConfig::from_json(&v).unwrap().wire,
            WirePrecision::F16
        );
        let bad = json::parse(r#"{"wire": "bf16"}"#).unwrap();
        assert!(SystemConfig::from_json(&bad).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("splitpoint_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        let c = SystemConfig::paper_dense();
        c.save(&p).unwrap();
        let back = SystemConfig::load(&p).unwrap();
        assert_eq!(back.codec, Policy::Dense);
        std::fs::remove_file(&p).unwrap();
    }
}
