//! PJRT backend: loads the AOT'd HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6, PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Python never runs on this path.
//!
//! The crate's `PjRtClient` is `Rc`-based (not `Send`), so the pool is a
//! small executor service: each worker thread owns a client plus its
//! compiled executables, and [`PjrtPool`] dispatches execute requests over
//! channels. Jobs carry `Arc<Tensor>` handles (refcount bumps, no tensor
//! copies) and workers are picked by a lock-free atomic round-robin.
//!
//! Only compiled with `--features pjrt` (requires the `xla` dependency,
//! which the offline build environment cannot resolve — see Cargo.toml).

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{Manifest, ModuleSpec};
use crate::tensor::Tensor;

struct Job {
    module: String,
    inputs: Vec<Arc<Tensor>>,
    reply: Sender<Result<Vec<Tensor>>>,
}

/// Pool of PJRT worker threads, one compiled module set each.
pub struct PjrtPool {
    submit: Vec<Sender<Job>>,
    next: AtomicUsize,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PjrtPool {
    /// Load the manifest's artifacts on `threads` independent workers.
    pub fn load(manifest: &Manifest, threads: usize) -> Result<PjrtPool> {
        assert!(threads >= 1);
        let mut senders = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let (tx, rx) = channel::<Job>();
            let specs = manifest.modules.clone();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let worker = std::thread::Builder::new()
                .name(format!("xla-worker-{i}"))
                .spawn(move || worker_main(specs, rx, ready_tx))
                .context("spawning xla worker")?;
            // surface load/compile errors synchronously
            ready_rx
                .recv()
                .map_err(|_| anyhow!("xla worker {i} died during load"))??;
            senders.push(tx);
            workers.push(worker);
        }
        Ok(PjrtPool {
            submit: senders,
            next: AtomicUsize::new(0),
            workers: Mutex::new(workers),
        })
    }

    /// Execute a module (atomic round-robin across workers; inputs travel
    /// as refcounted handles).
    pub fn execute(&self, spec: &ModuleSpec, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = channel();
        let idx = self.next.fetch_add(1, Ordering::Relaxed) % self.submit.len();
        self.submit[idx]
            .send(Job {
                module: spec.name.clone(),
                inputs: inputs.to_vec(), // Arc clones: refcount bumps only
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("xla worker gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("xla worker dropped reply"))?
    }
}

impl Drop for PjrtPool {
    fn drop(&mut self) {
        self.submit.clear(); // close channels
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------- worker

struct LoadedModule {
    spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

fn worker_main(specs: Vec<ModuleSpec>, rx: Receiver<Job>, ready: Sender<Result<()>>) {
    let loaded = match load_all(&specs) {
        Ok(l) => {
            let _ = ready.send(Ok(()));
            l
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let result = run_module(&loaded, &job.module, &job.inputs);
        let _ = job.reply.send(result);
    }
}

fn load_all(specs: &[ModuleSpec]) -> Result<HashMap<String, LoadedModule>> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
    let mut out = HashMap::new();
    for spec in specs {
        let path: &Path = &spec.artifact;
        if !path.exists() {
            bail!("artifact {} missing — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        out.insert(
            spec.name.clone(),
            LoadedModule {
                spec: spec.clone(),
                exe,
            },
        );
    }
    Ok(out)
}

fn run_module(
    loaded: &HashMap<String, LoadedModule>,
    name: &str,
    inputs: &[Arc<Tensor>],
) -> Result<Vec<Tensor>> {
    let lm = loaded
        .get(name)
        .with_context(|| format!("module '{name}' not loaded"))?;
    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;
    let result = lm
        .exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing '{name}': {e}"))?;
    // single device, single output buffer; modules are lowered with
    // return_tuple=True so the buffer is a tuple of outputs
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching '{name}' result: {e}"))?;
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow!("untupling '{name}' result: {e}"))?;
    if parts.len() != lm.spec.outputs.len() {
        bail!(
            "module '{name}' returned {} outputs, manifest says {}",
            parts.len(),
            lm.spec.outputs.len()
        );
    }
    parts
        .into_iter()
        .zip(&lm.spec.outputs)
        .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
        .collect()
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {:?}: {e}", t.shape()))
}

fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e}"))?;
    Tensor::from_vec(shape, v)
}
