//! In-crate reference executor: a deterministic rust port of the pure-jnp
//! oracles in `python/compile/kernels/ref.py` + `python/compile/model.py`.
//!
//! This is the **default backend** (the optional `pjrt` feature swaps in
//! the AOT'd HLO artifacts), so `cargo build && cargo test` work fully
//! offline. Numerical contract: identical semantics to the python model —
//! MeanVFE, fused 3x3x3 conv + bias + ReLU with spconv occupancy masks
//! (submanifold stages subsample the active set, regular stages dilate it),
//! MapToBEV + Backbone2D + anchor DenseHead, and the Voxel R-CNN RoI head
//! (grid pooling over three scales, shared point MLP, mean|max pool,
//! cls/reg towers with residual decode).
//!
//! Weights are drawn from the crate's xoshiro PRNG seeded with the
//! manifest's `weights_seed` (He-scaled normals, biases 0.01·N(0,1), drawn
//! in `model.py::init_weights` order). They differ bit-for-bit from the
//! JAX draws, which is fine: the paper reports no accuracy numbers, and
//! the correctness contract is split == unsplit equivalence (DESIGN.md §3).
//!
//! The executor is sparse end to end: every 3D stage visits only the
//! occupied output sites from the mask's cached site index and seeds the
//! site index of everything it produces, so the per-frame path never
//! rescans a dense grid.
//!
//! # Parallel gather-GEMM kernels
//!
//! The heavy stages run as **cache-blocked gather-GEMM** on a shared
//! [`WorkerPool`]:
//!
//! * each sparse 3D conv stage gathers the 3×3×3 neighborhood of a tile of
//!   active output sites into a contiguous `(TILE × 27·cin)` patch matrix
//!   (absent / masked-off taps zero-filled), then hits a blocked
//!   `(TILE × 27·cin) @ (27·cin × cout)` micro-kernel — every weight row
//!   is streamed once per tile instead of once per site, and the inner
//!   loop is a branch-free axpy over the contiguous `cout` row;
//! * `conv2d` (BEV backbone) and the `linear` towers use the same tiling;
//! * work is partitioned over site/row ranges across the pool's threads.
//!
//! The `(27·cin × cout)` GEMM operand is exactly the weight storage layout
//! (`init_weights` draws kernels tap-major with `cout` contiguous), so the
//! SIMD/autovec-friendly cout-major operand is materialized once at
//! [`ReferenceModel::new`] time and never re-transposed per call.
//!
//! **Bit-identity:** tiling and thread partitioning only interleave
//! *independent output rows* — the per-output-element operation order is
//! unchanged from the scalar kernels (ascending tap × channel, zero
//! activations skipped), so `threads=N == threads=1` and the gather-GEMM
//! path equals the pre-refactor scalar path bit-for-bit (pinned by the
//! tests below and `rust/tests/executor.rs`). The scalar kernels survive
//! as [`ReferenceModel::execute_legacy`], the measured `@legacy` bench
//! anchors (docs/PERF.md).
//!
//! Patch/accumulator buffers come from the pool's per-worker scratch
//! arenas, so steady-state kernel execution allocates nothing beyond the
//! output tensors themselves.
//!
//! # SIMD dispatch + per-tap occupancy masks
//!
//! The blocked axpy inner loop dispatches through [`crate::runtime::simd`]:
//! AVX2 (x86_64) / NEON (aarch64) lanes each own a **distinct output
//! channel**, the multiply and add stay separate instructions (no FMA
//! contraction), and the `cout % width` remainder runs the identical
//! scalar loop — so the SIMD path is bitwise identical to the scalar
//! fallback and to the legacy kernels. The instruction set is resolved
//! once at construction ([`ReferenceModel::with_simd`], CLI
//! `--simd auto|scalar|forced`) and threaded into the kernels as a plain
//! enum; the hot loops never re-probe the CPU.
//!
//! The sparse 3D gather additionally builds a per-tap occupancy plane for
//! each tile (in the same per-worker scratch arena as the patch matrix):
//! a tap — one of the 27 neighbor offsets — that is absent for *every*
//! site in the tile is skipped by both the gather fill and the GEMM's
//! `cin` weight rows for that tap. Absent taps contribute only exact-zero
//! activations, which the scalar loop already elides via its `xv == 0.0`
//! test, so the skip is bitwise exact; on KITTI-like occupancy most tiles
//! sit on the active set's boundary and drop a large fraction of their 27
//! taps. [`ReferenceModel::tap_stats`] exposes the seen/skipped counters
//! (the skip rate compounds with SIMD on sparse frames).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::manifest::{Manifest, ModelConfig, ModuleSpec, StageSpec};
use crate::runtime::pool::{Scratch, WorkerPool};
use crate::runtime::simd::{self, SimdLevel, SimdMode};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Sites/rows per gather-GEMM tile: enough rows to amortize each weight
/// cache line 8×, small enough that a tile's patch + accumulators stay in
/// L1 for every stage geometry.
const TILE: usize = 8;

/// Below this many fused multiply-adds a parallel region costs more in
/// thread spawns than it saves; run inline on the caller instead. Purely a
/// scheduling decision — results are identical either way.
const PAR_MIN_WORK: usize = 1 << 15;

// ---------------------------------------------------------------- weights

#[derive(Debug, Clone)]
struct Conv3dW {
    /// (3, 3, 3, cin, cout) row-major — i.e. tap-major `(27·cin × cout)`,
    /// exactly the GEMM operand the blocked kernel streams.
    w: Vec<f32>,
    b: Vec<f32>,
    cin: usize,
    cout: usize,
}

#[derive(Debug, Clone)]
struct Conv2dW {
    /// (3, 3, cin, cout) row-major — tap-major `(9·cin × cout)`
    w: Vec<f32>,
    b: Vec<f32>,
    cin: usize,
    cout: usize,
}

#[derive(Debug, Clone)]
struct LinW {
    /// (cin, cout) row-major
    w: Vec<f32>,
    b: Vec<f32>,
    cin: usize,
    cout: usize,
}

fn he_normals(rng: &mut Rng, n: usize, fan_in: usize) -> Vec<f32> {
    let scale = (2.0 / fan_in as f64).sqrt();
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn biases(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (0.01 * rng.normal()) as f32).collect()
}

fn conv3d_w(rng: &mut Rng, cin: usize, cout: usize) -> Conv3dW {
    Conv3dW {
        w: he_normals(rng, 27 * cin * cout, 27 * cin),
        b: biases(rng, cout),
        cin,
        cout,
    }
}

fn conv2d_w(rng: &mut Rng, cin: usize, cout: usize) -> Conv2dW {
    Conv2dW {
        w: he_normals(rng, 9 * cin * cout, 9 * cin),
        b: biases(rng, cout),
        cin,
        cout,
    }
}

fn linear_w(rng: &mut Rng, cin: usize, cout: usize) -> LinW {
    LinW {
        w: he_normals(rng, cin * cout, cin),
        b: biases(rng, cout),
        cin,
        cout,
    }
}

#[derive(Debug, Clone)]
struct Weights {
    stages: Vec<Conv3dW>,
    bev_block1: Conv2dW,
    bev_block2: Conv2dW,
    bev_cls: LinW,
    bev_box: LinW,
    bev_dir: LinW,
    roi_proj: Vec<LinW>,
    roi_mlp1: LinW,
    roi_mlp2: LinW,
    roi_fc1: LinW,
    roi_fc2: LinW,
    roi_cls: LinW,
    roi_reg: LinW,
}

fn stage_cout(cfg: &ModelConfig, name: &str) -> Result<usize> {
    cfg.stages
        .iter()
        .find(|s| s.name == name)
        .map(|s| s.cout)
        .with_context(|| format!("roi pool scale '{name}' is not a backbone stage"))
}

fn init_weights(cfg: &ModelConfig) -> Result<Weights> {
    let mut rng = Rng::new(cfg.weights_seed);
    let stages = cfg
        .stages
        .iter()
        .map(|s| conv3d_w(&mut rng, s.cin, s.cout))
        .collect();
    let bb = cfg.bev_backbone_channels;
    let bev_block1 = conv2d_w(&mut rng, cfg.bev_channels, bb);
    let bev_block2 = conv2d_w(&mut rng, bb, bb);
    let bev_cls = linear_w(&mut rng, bb, cfg.anchors_per_cell);
    let bev_box = linear_w(&mut rng, bb, cfg.anchors_per_cell * cfg.box_code_size);
    let bev_dir = linear_w(&mut rng, bb, cfg.anchors_per_cell * 2);
    let mut roi_proj = Vec::with_capacity(cfg.roi_pool_scales.len());
    for scale in &cfg.roi_pool_scales {
        roi_proj.push(linear_w(
            &mut rng,
            stage_cout(cfg, scale)?,
            cfg.roi_pool_channels,
        ));
    }
    let concat = cfg.roi_pool_scales.len() * cfg.roi_pool_channels;
    let roi_mlp1 = linear_w(&mut rng, concat, cfg.roi_mlp);
    let roi_mlp2 = linear_w(&mut rng, cfg.roi_mlp, cfg.roi_mlp);
    let roi_fc1 = linear_w(&mut rng, 2 * cfg.roi_mlp, cfg.roi_fc);
    let roi_fc2 = linear_w(&mut rng, cfg.roi_fc, cfg.roi_fc);
    let roi_cls = linear_w(&mut rng, cfg.roi_fc, 1);
    let roi_reg = linear_w(&mut rng, cfg.roi_fc, cfg.box_code_size);
    Ok(Weights {
        stages,
        bev_block1,
        bev_block2,
        bev_cls,
        bev_box,
        bev_dir,
        roi_proj,
        roi_mlp1,
        roi_mlp2,
        roi_fc1,
        roi_fc2,
        roi_cls,
        roi_reg,
    })
}

// ---------------------------------------------------- job partition helper

/// Split `out` into per-range `&mut` chunks of `row_width` elements per
/// item, pairing each range with its slice. The jobs are disjoint by
/// construction, so a parallel region can own them without aliasing.
fn row_jobs<'a>(
    out: &'a mut [f32],
    ranges: &[Range<usize>],
    row_width: usize,
) -> Vec<(Range<usize>, &'a mut [f32])> {
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest: &mut [f32] = out;
    for r in ranges {
        let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * row_width);
        rest = tail;
        jobs.push((r.clone(), chunk));
    }
    jobs
}

// ---------------------------------------------------------- linear kernels

/// `out[n, cout] = x[n, cin] @ w + b`, optional ReLU — row-tiled and
/// parallelized over row ranges. Per-row operation order matches
/// [`scalar_linear`] exactly (ascending `cin`, zero activations skipped),
/// so the output is bit-identical at any tile size or thread count.
fn linear(
    pool: &WorkerPool,
    level: SimdLevel,
    x: &[f32],
    n: usize,
    lw: &LinW,
    relu: bool,
) -> Vec<f32> {
    let (cin, cout) = (lw.cin, lw.cout);
    debug_assert_eq!(x.len(), n * cin);
    let mut out = vec![0.0f32; n * cout];
    let parts = if n * cin * cout < PAR_MIN_WORK {
        1
    } else {
        pool.threads()
    };
    let ranges = WorkerPool::partition(n, parts);
    let jobs = row_jobs(&mut out, &ranges, cout);
    pool.scatter(jobs, |_w, (rows, chunk)| {
        linear_rows(x, rows, lw, relu, chunk, level);
    });
    out
}

/// The tiled row micro-kernel behind [`linear`]: each weight row is
/// streamed once per `TILE` output rows instead of once per row.
fn linear_rows(
    x: &[f32],
    rows: Range<usize>,
    lw: &LinW,
    relu: bool,
    chunk: &mut [f32],
    level: SimdLevel,
) {
    let (cin, cout) = (lw.cin, lw.cout);
    let r0 = rows.start;
    let nrows = rows.len();
    let mut t0 = 0usize;
    while t0 < nrows {
        let tl = TILE.min(nrows - t0);
        let acc = &mut chunk[t0 * cout..(t0 + tl) * cout];
        for arow in acc.chunks_exact_mut(cout) {
            arow.copy_from_slice(&lw.b);
        }
        for ci in 0..cin {
            let wrow = &lw.w[ci * cout..(ci + 1) * cout];
            for t in 0..tl {
                let xv = x[(r0 + t0 + t) * cin + ci];
                if xv == 0.0 {
                    continue;
                }
                let arow = &mut acc[t * cout..(t + 1) * cout];
                simd::axpy(level, arow, wrow, xv);
            }
        }
        if relu {
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
        }
        t0 += tl;
    }
}

/// Pre-gather-GEMM scalar linear (one row at a time, weight rows reloaded
/// per row). Kept verbatim as the `@legacy` bench anchor.
fn scalar_linear(x: &[f32], n: usize, lw: &LinW, relu: bool) -> Vec<f32> {
    let (cin, cout) = (lw.cin, lw.cout);
    debug_assert_eq!(x.len(), n * cin);
    let mut out = vec![0.0f32; n * cout];
    for i in 0..n {
        let acc = &mut out[i * cout..(i + 1) * cout];
        acc.copy_from_slice(&lw.b);
        let xrow = &x[i * cin..(i + 1) * cin];
        for (ci, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &lw.w[ci * cout..(ci + 1) * cout];
            for (a, &wv) in acc.iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
        if relu {
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------- conv2d kernels

/// Fused 3x3 2D conv (stride 1, SAME) + bias + ReLU over an (H, W, Cin)
/// buffer — `ref.py::conv2d_ref` as a parallel gather-GEMM: output rows
/// are partitioned across the pool; each worker gathers pixel tiles into a
/// patch matrix (border taps zero-filled) and runs the blocked
/// `(TILE × 9·cin) @ (9·cin × cout)` micro-kernel in place.
fn conv2d_relu(
    pool: &WorkerPool,
    level: SimdLevel,
    x: &[f32],
    h: usize,
    w: usize,
    cw: &Conv2dW,
) -> Vec<f32> {
    let (cin, cout) = (cw.cin, cw.cout);
    debug_assert_eq!(x.len(), h * w * cin);
    let mut out = vec![0.0f32; h * w * cout];
    let parts = if h * w * 9 * cin * cout < PAR_MIN_WORK {
        1
    } else {
        pool.threads()
    };
    let ranges = WorkerPool::partition(h, parts);
    let jobs = row_jobs(&mut out, &ranges, w * cout);
    pool.scatter(jobs, |_wk, (oys, chunk)| {
        let mut scratch = pool.scratch();
        conv2d_rows(x, h, w, cw, oys, chunk, &mut scratch, level);
        pool.recycle(scratch);
    });
    out
}

#[allow(clippy::too_many_arguments)]
fn conv2d_rows(
    x: &[f32],
    h: usize,
    w: usize,
    cw: &Conv2dW,
    oys: Range<usize>,
    chunk: &mut [f32],
    scratch: &mut Scratch,
    level: SimdLevel,
) {
    let (cin, cout) = (cw.cin, cw.cout);
    let k_total = 9 * cin;
    let patch = scratch.patch_mut(TILE * k_total);
    for oy in oys.clone() {
        let crow = oy - oys.start;
        let mut ox0 = 0usize;
        while ox0 < w {
            let tl = TILE.min(w - ox0);
            // ---- gather: branchy border handling happens once per tile,
            // leaving the GEMM inner loop branch-free
            for t in 0..tl {
                let ox = ox0 + t;
                let prow = &mut patch[t * k_total..(t + 1) * k_total];
                for ky in 0..3usize {
                    let iy = oy as i64 + ky as i64 - 1;
                    for kx in 0..3usize {
                        let ix = ox as i64 + kx as i64 - 1;
                        let tap = (ky * 3 + kx) * cin;
                        let dst = &mut prow[tap..tap + cin];
                        if iy >= 0 && iy < h as i64 && ix >= 0 && ix < w as i64 {
                            let s = (iy as usize * w + ix as usize) * cin;
                            dst.copy_from_slice(&x[s..s + cin]);
                        } else {
                            dst.fill(0.0);
                        }
                    }
                }
            }
            // ---- blocked GEMM straight into the output rows
            let acc = &mut chunk[(crow * w + ox0) * cout..(crow * w + ox0 + tl) * cout];
            for arow in acc.chunks_exact_mut(cout) {
                arow.copy_from_slice(&cw.b);
            }
            for kk in 0..k_total {
                let wrow = &cw.w[kk * cout..(kk + 1) * cout];
                for t in 0..tl {
                    let xv = patch[t * k_total + kk];
                    if xv == 0.0 {
                        continue;
                    }
                    let arow = &mut acc[t * cout..(t + 1) * cout];
                    simd::axpy(level, arow, wrow, xv);
                }
            }
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
            ox0 += tl;
        }
    }
}

/// Pre-gather-GEMM scalar conv2d. Kept verbatim as the `@legacy` bench
/// anchor behind `runtime/bev_head@legacy`.
fn scalar_conv2d_relu(x: &[f32], h: usize, w: usize, cw: &Conv2dW) -> Vec<f32> {
    let (cin, cout) = (cw.cin, cw.cout);
    debug_assert_eq!(x.len(), h * w * cin);
    let mut out = vec![0.0f32; h * w * cout];
    for oy in 0..h {
        for ox in 0..w {
            let acc = &mut out[(oy * w + ox) * cout..(oy * w + ox + 1) * cout];
            acc.copy_from_slice(&cw.b);
            for ky in 0..3usize {
                let iy = oy as i64 + ky as i64 - 1;
                if iy < 0 || iy >= h as i64 {
                    continue;
                }
                for kx in 0..3usize {
                    let ix = ox as i64 + kx as i64 - 1;
                    if ix < 0 || ix >= w as i64 {
                        continue;
                    }
                    let xrow = &x[(iy as usize * w + ix as usize) * cin..][..cin];
                    let wbase = (ky * 3 + kx) * cin * cout;
                    for (ci, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &cw.w[wbase + ci * cout..wbase + (ci + 1) * cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------- conv3d kernels

/// The sparse 3D gather-GEMM worker kernel: process `sites` (a contiguous
/// ascending slice of the active output list) in tiles — resolve each
/// tile's 3×3×3 neighborhood occupancy into the scratch **mask plane**,
/// gather the present taps into the scratch patch matrix, then run the
/// blocked GEMM into `chunk`, the caller's disjoint interval of the output
/// buffer starting at row `base_row`. Taps absent for *every* site in the
/// tile skip both the gather fill and their `cin` GEMM weight rows — an
/// absent tap only ever contributes exact-zero activations, which the GEMM
/// elides per element anyway (`xv == 0.0`), so the skip is bitwise exact.
/// Nonzero post-ReLU sites are appended to `out_sites` (ascending, since
/// `sites` is). Returns `(taps_seen, taps_skipped)` for the tap-mask
/// telemetry (27 seen per tile processed).
#[allow(clippy::too_many_arguments)]
fn conv3d_sites(
    fd: &[f32],
    md: &[f32],
    dims_in: (usize, usize, usize),
    dims_out: (usize, usize),
    stride: [usize; 3],
    cw: &Conv3dW,
    sites: &[u32],
    base_row: usize,
    chunk: &mut [f32],
    out_sites: &mut Vec<u32>,
    scratch: &mut Scratch,
    level: SimdLevel,
) -> (u64, u64) {
    let (d_in, h_in, w_in) = dims_in;
    let (h_out, w_out) = dims_out;
    let (cin, cout) = (cw.cin, cw.cout);
    let [sz, sy, sx] = stride;
    let k_total = 27 * cin;
    let (patch, mask_plane) = scratch.patch_and_mask(TILE * k_total, TILE * 27);
    let mut taps_seen = 0u64;
    let mut taps_skipped = 0u64;
    let mut i = 0usize;
    while i < sites.len() {
        let tl = TILE.min(sites.len() - i);
        let tile = &sites[i..i + tl];
        // ---- occupancy pass: one branchy coordinate walk per tile fills
        // the mask plane with each tap's source site (+1; 0 = absent) and
        // folds per-tap presence across the tile
        let mut tap_any = [false; 27];
        for (t, &o) in tile.iter().enumerate() {
            let oi = o as usize;
            let oz = oi / (h_out * w_out);
            let oy = (oi / w_out) % h_out;
            let ox = oi % w_out;
            let mrow = &mut mask_plane[t * 27..(t + 1) * 27];
            let mut tap = 0usize;
            for dz in 0..3usize {
                let z = (oz * sz + dz) as i64 - 1;
                for dy in 0..3usize {
                    let y = (oy * sy + dy) as i64 - 1;
                    for dx in 0..3usize {
                        let x = (ox * sx + dx) as i64 - 1;
                        let inside = z >= 0
                            && z < d_in as i64
                            && y >= 0
                            && y < h_in as i64
                            && x >= 0
                            && x < w_in as i64;
                        let mut src = 0u32;
                        if inside {
                            let s = (z as usize * h_in + y as usize) * w_in + x as usize;
                            if md[s] != 0.0 {
                                src = s as u32 + 1;
                                tap_any[tap] = true;
                            }
                        }
                        mrow[tap] = src;
                        tap += 1;
                    }
                }
            }
        }
        // ---- gather: only taps present somewhere in the tile are filled
        // (skipped tap columns hold stale data the GEMM never reads)
        for t in 0..tl {
            let prow = &mut patch[t * k_total..(t + 1) * k_total];
            let mrow = &mask_plane[t * 27..(t + 1) * 27];
            for (tap, &src) in mrow.iter().enumerate() {
                if !tap_any[tap] {
                    continue;
                }
                let dst = &mut prow[tap * cin..(tap + 1) * cin];
                if src != 0 {
                    let s = (src - 1) as usize;
                    dst.copy_from_slice(&fd[s * cin..(s + 1) * cin]);
                } else {
                    dst.fill(0.0);
                }
            }
        }
        // ---- bias init + blocked GEMM (weight rows stream once per tile;
        // all-absent taps skip their cin rows entirely)
        for &o in tile {
            let off = (o as usize - base_row) * cout;
            chunk[off..off + cout].copy_from_slice(&cw.b);
        }
        for (tap, &any) in tap_any.iter().enumerate() {
            taps_seen += 1;
            if !any {
                taps_skipped += 1;
                continue;
            }
            for kk in tap * cin..(tap + 1) * cin {
                let wrow = &cw.w[kk * cout..(kk + 1) * cout];
                for (t, &o) in tile.iter().enumerate() {
                    let xv = patch[t * k_total + kk];
                    if xv == 0.0 {
                        continue;
                    }
                    let off = (o as usize - base_row) * cout;
                    let arow = &mut chunk[off..off + cout];
                    simd::axpy(level, arow, wrow, xv);
                }
            }
        }
        // ---- ReLU + output-site tracking
        for &o in tile {
            let off = (o as usize - base_row) * cout;
            let arow = &mut chunk[off..off + cout];
            let mut nonzero = false;
            for a in arow.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                } else if *a > 0.0 {
                    nonzero = true;
                }
            }
            if nonzero {
                out_sites.push(o);
            }
        }
        i += tl;
    }
    (taps_seen, taps_skipped)
}

/// Pre-gather-GEMM scalar 3D conv over the active set. Kept verbatim as
/// the `@legacy` bench anchor behind `runtime/conv_stage@legacy`.
#[allow(clippy::too_many_arguments)]
fn scalar_conv3d(
    fd: &[f32],
    md: &[f32],
    dims_in: (usize, usize, usize),
    dims_out: (usize, usize),
    stride: [usize; 3],
    cw: &Conv3dW,
    active: &[u32],
    out: &mut [f32],
    out_sites: &mut Vec<u32>,
) {
    let (d_in, h_in, w_in) = dims_in;
    let (h_out, w_out) = dims_out;
    let (cin, cout) = (cw.cin, cw.cout);
    let [sz, sy, sx] = stride;
    for &o in active {
        let oi = o as usize;
        let oz = oi / (h_out * w_out);
        let oy = (oi / w_out) % h_out;
        let ox = oi % w_out;
        let acc = &mut out[oi * cout..(oi + 1) * cout];
        acc.copy_from_slice(&cw.b);
        for dz in 0..3usize {
            let z = (oz * sz + dz) as i64 - 1;
            if z < 0 || z >= d_in as i64 {
                continue;
            }
            for dy in 0..3usize {
                let y = (oy * sy + dy) as i64 - 1;
                if y < 0 || y >= h_in as i64 {
                    continue;
                }
                for dx in 0..3usize {
                    let x = (ox * sx + dx) as i64 - 1;
                    if x < 0 || x >= w_in as i64 {
                        continue;
                    }
                    let s = (z as usize * h_in + y as usize) * w_in + x as usize;
                    if md[s] == 0.0 {
                        continue; // input is zero off the active set
                    }
                    let xrow = &fd[s * cin..(s + 1) * cin];
                    let wbase = ((dz * 3 + dy) * 3 + dx) * cin * cout;
                    for (ci, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &cw.w[wbase + ci * cout..wbase + (ci + 1) * cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
        let mut nonzero = false;
        for a in acc.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            } else if *a > 0.0 {
                nonzero = true;
            }
        }
        if nonzero {
            out_sites.push(o);
        }
    }
}

// --------------------------------------------------------- roi pool kernel

/// Per-scale context for the RoI grid pool, resolved once per call.
struct RoiScale<'a> {
    proj: &'a LinW,
    fdata: &'a [f32],
    fd_d: usize,
    fd_h: usize,
    fd_w: usize,
    fc: usize,
    vz: f32,
    vy: f32,
    vx: f32,
}

/// Grid-pool + per-scale projection for a contiguous range of RoIs,
/// writing into `chunk` (that range's rows of the concatenated pooled
/// matrix). Each destination slice is computed independently, so the
/// ki-parallel loop order is value-identical to the original scale-outer
/// nest.
#[allow(clippy::too_many_arguments)]
fn roi_pool_rows(
    scales: &[RoiScale<'_>],
    rd: &[f32],
    lin: &[f32],
    origin: (f32, f32, f32),
    g: usize,
    pc: usize,
    concat_c: usize,
    kis: Range<usize>,
    chunk: &mut [f32],
    level: SimdLevel,
) {
    let g3 = g * g * g;
    let (x0, y0, z0) = origin;
    for ki in kis.clone() {
        let krel = ki - kis.start;
        let r = &rd[ki * 7..ki * 7 + 7];
        let (cx, cy, cz) = (r[0], r[1], r[2]);
        let (bl, bw, bh) = (r[3], r[4], r[5]);
        let (cos, sin) = (r[6].cos(), r[6].sin());
        for (si, sc) in scales.iter().enumerate() {
            for gi in 0..g3 {
                let dz = lin[gi / (g * g)];
                let dy = lin[(gi / g) % g];
                let dx = lin[gi % g];
                // rotate the box-frame offset into world space
                let (ox, oy, oz) = (dx * bl, dy * bw, dz * bh);
                let px = ox * cos - oy * sin + cx;
                let py = ox * sin + oy * cos + cy;
                let pz = oz + cz;
                let ix = ((px - x0) / sc.vx).floor();
                let iy = ((py - y0) / sc.vy).floor();
                let iz = ((pz - z0) / sc.vz).floor();
                let valid = ix >= 0.0
                    && ix < sc.fd_w as f32
                    && iy >= 0.0
                    && iy < sc.fd_h as f32
                    && iz >= 0.0
                    && iz < sc.fd_d as f32;
                let dst_base = (krel * g3 + gi) * concat_c + si * pc;
                let dest = &mut chunk[dst_base..dst_base + pc];
                dest.copy_from_slice(&sc.proj.b);
                if valid {
                    let flat = (iz as usize * sc.fd_h + iy as usize) * sc.fd_w + ix as usize;
                    let xrow = &sc.fdata[flat * sc.fc..(flat + 1) * sc.fc];
                    for (ci, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &sc.proj.w[ci * pc..(ci + 1) * pc];
                        simd::axpy(level, dest, wrow, xv);
                    }
                }
                for a in dest.iter_mut() {
                    if *a < 0.0 {
                        *a = 0.0;
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------------- the model

/// Deterministic reference executor over a manifest's module set.
#[derive(Debug)]
pub struct ReferenceModel {
    cfg: ModelConfig,
    specs: Vec<ModuleSpec>,
    weights: Weights,
    pool: Arc<WorkerPool>,
    /// SIMD dispatch level, resolved once at construction.
    simd: SimdLevel,
    /// 3×3×3 taps examined by the sparse conv gather (27 per tile).
    tap_seen: AtomicU64,
    /// Taps whose gather + GEMM rows were skipped (absent for the whole
    /// tile) — the per-tap occupancy-mask win on sparse frames.
    tap_skipped: AtomicU64,
}

impl ReferenceModel {
    /// Single-threaded model (kernels run inline on the caller).
    pub fn new(manifest: &Manifest) -> Result<ReferenceModel> {
        Self::new_pooled(manifest, Arc::new(WorkerPool::new(1)))
    }

    /// Model whose kernels parallelize over `pool`'s worker threads. The
    /// pool is shared — the engine hands the same pool to every module, and
    /// callers size it against the pipeline's tail workers (docs/PERF.md).
    /// SIMD dispatch defaults to auto-detection.
    pub fn new_pooled(manifest: &Manifest, pool: Arc<WorkerPool>) -> Result<ReferenceModel> {
        Self::with_simd(manifest, pool, SimdMode::Auto)
    }

    /// [`Self::new_pooled`] with an explicit SIMD dispatch mode
    /// (`--simd auto|scalar|forced`). The mode is resolved to a concrete
    /// [`SimdLevel`] here, once; every kernel call dispatches on the
    /// stored enum. All levels are bitwise identical (module docs).
    pub fn with_simd(
        manifest: &Manifest,
        pool: Arc<WorkerPool>,
        mode: SimdMode,
    ) -> Result<ReferenceModel> {
        Ok(ReferenceModel {
            cfg: manifest.config.clone(),
            specs: manifest.modules.clone(),
            weights: init_weights(&manifest.config)?,
            pool,
            simd: simd::resolve(mode)?,
            tap_seen: AtomicU64::new(0),
            tap_skipped: AtomicU64::new(0),
        })
    }

    /// The kernel worker pool (tests read its scratch stats).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The SIMD level the kernels dispatch to.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// `(taps_seen, taps_skipped)` accumulated by the sparse 3D conv
    /// gather since construction. Relaxed counters — telemetry, not
    /// synchronization.
    pub fn tap_stats(&self) -> (u64, u64) {
        (
            self.tap_seen.load(Ordering::Relaxed),
            self.tap_skipped.load(Ordering::Relaxed),
        )
    }

    /// Dense index of a module by name (aligned with the manifest order).
    pub fn module_index(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name == name)
    }

    /// Execute module `idx` (aligned with the manifest's module order).
    /// Inputs are already shape-validated by the runtime dispatcher.
    pub fn execute(&self, idx: usize, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        let spec = &self.specs[idx];
        match spec.name.as_str() {
            "vfe" => self.vfe(spec, &inputs[0], &inputs[1]),
            "bev_head" => self.bev_head(spec, &inputs[0], false),
            "roi_head" => self.roi_head(spec, inputs),
            name => {
                let (si, stage) = self
                    .cfg
                    .stages
                    .iter()
                    .enumerate()
                    .find(|(_, s)| s.name == name)
                    .with_context(|| {
                        format!("reference backend has no implementation for '{name}'")
                    })?;
                self.conv_stage(
                    spec,
                    stage,
                    &self.weights.stages[si],
                    &inputs[0],
                    &inputs[1],
                    false,
                )
            }
        }
    }

    /// Execute module `idx` through the pre-gather-GEMM scalar kernels.
    /// Bench-only: the `runtime/*@legacy` micro-bench twins re-measure the
    /// single-threaded triple-loop behaviour from HEAD so
    /// `speedup_vs_legacy` is a true in-run before/after pair
    /// (docs/PERF.md). Only the restructured modules (3D conv stages and
    /// `bev_head`) carry a legacy path; occupancy propagation is shared, so
    /// the twin isolates exactly the kernel difference.
    pub fn execute_legacy(&self, idx: usize, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        let spec = self
            .specs
            .get(idx)
            .with_context(|| format!("module id {idx} out of range"))?;
        match spec.name.as_str() {
            "bev_head" => self.bev_head(spec, &inputs[0], true),
            name => {
                let (si, stage) = self
                    .cfg
                    .stages
                    .iter()
                    .enumerate()
                    .find(|(_, s)| s.name == name)
                    .with_context(|| format!("no legacy scalar kernel for module '{name}'"))?;
                self.conv_stage(
                    spec,
                    stage,
                    &self.weights.stages[si],
                    &inputs[0],
                    &inputs[1],
                    true,
                )
            }
        }
    }

    /// MeanVFE — `model.py::vfe`: per-voxel mean of point features plus the
    /// occupancy mask, visiting only the scattered sites.
    fn vfe(&self, spec: &ModuleSpec, sum: &Tensor, cnt: &Tensor) -> Result<Vec<Tensor>> {
        let f = sum.channels();
        let spatial = sum.spatial();
        let mut feat = vec![0.0f32; sum.numel()];
        let mut mask = vec![0.0f32; spatial];
        let mut feat_sites: Vec<u32> = Vec::new();
        let mut mask_sites: Vec<u32> = Vec::new();
        let sd = sum.data();
        let cd = cnt.data();
        for &s in cnt.site_index() {
            let si = s as usize;
            let c = cd[si];
            if c <= 0.0 {
                continue; // mask = (cnt > 0); site_index only says "non-zero"
            }
            mask[si] = 1.0;
            mask_sites.push(s);
            let inv = 1.0 / c.max(1.0);
            let base = si * f;
            let mut nonzero = false;
            for k in 0..f {
                let v = sd[base + k] * inv;
                feat[base + k] = v;
                nonzero |= v != 0.0;
            }
            if nonzero {
                feat_sites.push(s);
            }
        }
        Ok(vec![
            Tensor::from_vec_with_sites(&spec.outputs[0].shape, feat, feat_sites)?,
            Tensor::from_vec_with_sites(&spec.outputs[1].shape, mask, mask_sites)?,
        ])
    }

    /// One Backbone3D stage — `model.py::conv_stage`: occupancy propagation
    /// (subsample or dilate) followed by the fused 3x3x3 conv + bias + ReLU
    /// evaluated only at active output sites. `legacy` selects the scalar
    /// per-site kernel instead of the parallel gather-GEMM (bench anchor);
    /// both produce bit-identical outputs.
    fn conv_stage(
        &self,
        spec: &ModuleSpec,
        stage: &StageSpec,
        cw: &Conv3dW,
        feat: &Tensor,
        mask: &Tensor,
        legacy: bool,
    ) -> Result<Vec<Tensor>> {
        let in_shape = feat.shape();
        if in_shape.len() != 4 {
            bail!("conv stage '{}' wants a rank-4 input", stage.name);
        }
        let (d_in, h_in, w_in, cin) = (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
        let out_shape = &spec.outputs[0].shape;
        let (d_out, h_out, w_out, cout) =
            (out_shape[0], out_shape[1], out_shape[2], out_shape[3]);
        if cin != cw.cin || cout != cw.cout {
            bail!("conv stage '{}' channel mismatch", stage.name);
        }
        let [sz, sy, sx] = stage.stride;
        let out_spatial = d_out * h_out * w_out;

        // ---- occupancy propagation (ref.py::stride_mask / dilate_mask)
        let in_sites = mask.site_index();
        let active: Vec<u32> = if stage.submanifold {
            // subsample: out active iff the strided input site is active
            in_sites
                .iter()
                .filter_map(|&s| {
                    let si = s as usize;
                    let z = si / (h_in * w_in);
                    let y = (si / w_in) % h_in;
                    let x = si % w_in;
                    if z % sz == 0 && y % sy == 0 && x % sx == 0 {
                        let (oz, oy, ox) = (z / sz, y / sy, x / sx);
                        if oz < d_out && oy < h_out && ox < w_out {
                            return Some(((oz * h_out + oy) * w_out + ox) as u32);
                        }
                    }
                    None
                })
                .collect()
        } else {
            // dilate: 3x3x3 max-pool with the conv's stride, padding 1
            let mut flags = vec![false; out_spatial];
            for &s in in_sites {
                let si = s as usize;
                let z = si / (h_in * w_in);
                let y = (si / w_in) % h_in;
                let x = si % w_in;
                for dz in 0..3i64 {
                    let nz = z as i64 + 1 - dz;
                    if nz < 0 || nz % sz as i64 != 0 {
                        continue;
                    }
                    let oz = (nz / sz as i64) as usize;
                    if oz >= d_out {
                        continue;
                    }
                    for dy in 0..3i64 {
                        let ny = y as i64 + 1 - dy;
                        if ny < 0 || ny % sy as i64 != 0 {
                            continue;
                        }
                        let oy = (ny / sy as i64) as usize;
                        if oy >= h_out {
                            continue;
                        }
                        for dx in 0..3i64 {
                            let nx = x as i64 + 1 - dx;
                            if nx < 0 || nx % sx as i64 != 0 {
                                continue;
                            }
                            let ox = (nx / sx as i64) as usize;
                            if ox >= w_out {
                                continue;
                            }
                            flags[(oz * h_out + oy) * w_out + ox] = true;
                        }
                    }
                }
            }
            (0..out_spatial)
                .filter(|&i| flags[i])
                .map(|i| i as u32)
                .collect()
        };

        let mut mask_out = vec![0.0f32; out_spatial];
        for &s in &active {
            mask_out[s as usize] = 1.0;
        }

        // ---- fused conv + bias + ReLU at active output sites only
        // (`out * mask` zeroes everything else, so skipping it is exact)
        let fd = feat.data();
        let md = mask.data();
        let mut out = vec![0.0f32; out_spatial * cout];
        let mut out_sites: Vec<u32> = Vec::with_capacity(active.len());

        if legacy {
            scalar_conv3d(
                fd,
                md,
                (d_in, h_in, w_in),
                (h_out, w_out),
                [sz, sy, sx],
                cw,
                &active,
                &mut out,
                &mut out_sites,
            );
        } else if !active.is_empty() {
            let pool = self.pool.as_ref();
            let parts = if active.len() * 27 * cin * cout < PAR_MIN_WORK {
                1
            } else {
                pool.threads()
            };
            let ranges = WorkerPool::partition(active.len(), parts);
            let mut site_lists: Vec<Vec<u32>> = ranges.iter().map(|_| Vec::new()).collect();
            {
                // chunk the active list across workers: the list is
                // ascending, so each chunk's output rows form a disjoint
                // interval of `out`, carved out with split_at_mut
                let mut jobs: Vec<(Range<usize>, usize, &mut [f32], &mut Vec<u32>)> =
                    Vec::with_capacity(ranges.len());
                let mut rest: &mut [f32] = out.as_mut_slice();
                let mut row_cursor = 0usize;
                for (r, sites_out) in ranges.iter().zip(site_lists.iter_mut()) {
                    let first_row = active[r.start] as usize;
                    let last_row = active[r.end - 1] as usize;
                    let skip = (first_row - row_cursor) * cout;
                    let (_gap, tail) = std::mem::take(&mut rest).split_at_mut(skip);
                    let (chunk, tail) =
                        tail.split_at_mut((last_row + 1 - first_row) * cout);
                    rest = tail;
                    row_cursor = last_row + 1;
                    jobs.push((r.clone(), first_row, chunk, sites_out));
                }
                let active_ref: &[u32] = &active;
                let level = self.simd;
                let (tap_seen, tap_skipped) = (&self.tap_seen, &self.tap_skipped);
                pool.scatter(jobs, |_wk, (sites_r, base_row, chunk, sites_out)| {
                    let mut scratch = pool.scratch();
                    let (seen, skipped) = conv3d_sites(
                        fd,
                        md,
                        (d_in, h_in, w_in),
                        (h_out, w_out),
                        [sz, sy, sx],
                        cw,
                        &active_ref[sites_r],
                        base_row,
                        chunk,
                        sites_out,
                        &mut scratch,
                        level,
                    );
                    pool.recycle(scratch);
                    tap_seen.fetch_add(seen, Ordering::Relaxed);
                    tap_skipped.fetch_add(skipped, Ordering::Relaxed);
                });
            }
            for l in site_lists {
                out_sites.extend(l);
            }
        }

        Ok(vec![
            Tensor::from_vec_with_sites(out_shape, out, out_sites)?,
            Tensor::from_vec_with_sites(&spec.outputs[1].shape, mask_out, active)?,
        ])
    }

    /// MapToBEV + Backbone2D + DenseHead — `model.py::bev_head`. `legacy`
    /// selects the scalar kernels (bench anchor); outputs are identical.
    fn bev_head(&self, spec: &ModuleSpec, feat: &Tensor, legacy: bool) -> Result<Vec<Tensor>> {
        let shape = feat.shape();
        if shape.len() != 4 {
            bail!("bev_head wants a rank-4 input");
        }
        let (d, h, w, c) = (shape[0], shape[1], shape[2], shape[3]);
        let bevc = d * c;
        if bevc != self.weights.bev_block1.cin {
            bail!(
                "bev_head channel mismatch: {} vs {}",
                bevc,
                self.weights.bev_block1.cin
            );
        }
        // map_to_bev: (D, H, W, C) -> (H, W, D*C)
        let fd = feat.data();
        let mut x = vec![0.0f32; h * w * bevc];
        for zd in 0..d {
            for yy in 0..h {
                for xx in 0..w {
                    let src = ((zd * h + yy) * w + xx) * c;
                    let dst = (yy * w + xx) * bevc + zd * c;
                    x[dst..dst + c].copy_from_slice(&fd[src..src + c]);
                }
            }
        }
        let pool = self.pool.as_ref();
        let level = self.simd;
        let x = if legacy {
            let x1 = scalar_conv2d_relu(&x, h, w, &self.weights.bev_block1);
            scalar_conv2d_relu(&x1, h, w, &self.weights.bev_block2)
        } else {
            let x1 = conv2d_relu(pool, level, &x, h, w, &self.weights.bev_block1);
            conv2d_relu(pool, level, &x1, h, w, &self.weights.bev_block2)
        };

        let hw = h * w;
        let head = |lw: &LinW| {
            if legacy {
                scalar_linear(&x, hw, lw, false)
            } else {
                linear(pool, level, &x, hw, lw, false)
            }
        };
        let cls = head(&self.weights.bev_cls);
        let boxp = head(&self.weights.bev_box);
        let dir = head(&self.weights.bev_dir);
        Ok(vec![
            Tensor::from_vec(&spec.outputs[0].shape, cls)?,
            Tensor::from_vec(&spec.outputs[1].shape, boxp)?,
            Tensor::from_vec(&spec.outputs[2].shape, dir)?,
        ])
    }

    /// Voxel RoI pooling + refinement — `model.py::roi_head` /
    /// `ref.py::roi_pool_ref`. The grid-pool gather parallelizes over RoIs
    /// (each RoI's rows of the pooled matrix are contiguous) and the MLP /
    /// FC towers ride the parallel [`linear`] kernel.
    fn roi_head(&self, spec: &ModuleSpec, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        let cfg = &self.cfg;
        let pool = self.pool.as_ref();
        let level = self.simd;
        let rois = inputs
            .last()
            .context("roi_head wants the roi tensor last")?;
        let k = rois.shape().first().copied().unwrap_or(0);
        let g = cfg.roi_grid;
        let g3 = g * g * g;
        let pc = cfg.roi_pool_channels;
        let concat_c = cfg.roi_pool_scales.len() * pc;
        let rd = rois.data();

        let (x0, y0, z0) = (
            cfg.pc_range_x.0 as f32,
            cfg.pc_range_y.0 as f32,
            cfg.pc_range_z.0 as f32,
        );
        let (x1, y1, z1) = (
            cfg.pc_range_x.1 as f32,
            cfg.pc_range_y.1 as f32,
            cfg.pc_range_z.1 as f32,
        );
        // grid-point offsets in the box frame, cell centers in [-0.5, 0.5]
        let lin: Vec<f32> = (0..g)
            .map(|i| (i as f32 + 0.5) / g as f32 - 0.5)
            .collect();

        // per-scale contexts, resolved once (weights, feature volume,
        // voxel geometry)
        let mut scales_ctx: Vec<RoiScale> = Vec::with_capacity(cfg.roi_pool_scales.len());
        for (si, scale) in cfg.roi_pool_scales.iter().enumerate() {
            let feat_name = format!("{scale}_feat");
            let fi = spec
                .inputs
                .iter()
                .position(|t| t.name == feat_name)
                .with_context(|| format!("roi_head input '{feat_name}' missing"))?;
            let feat = &inputs[fi];
            let fs = feat.shape();
            let (fd_d, fd_h, fd_w, fc) = (fs[0], fs[1], fs[2], fs[3]);
            scales_ctx.push(RoiScale {
                proj: &self.weights.roi_proj[si],
                fdata: feat.data(),
                fd_d,
                fd_h,
                fd_w,
                fc,
                vz: (z1 - z0) / fd_d as f32,
                vy: (y1 - y0) / fd_h as f32,
                vx: (x1 - x0) / fd_w as f32,
            });
        }

        let mut xcat = vec![0.0f32; k * g3 * concat_c];
        if k > 0 {
            // each grid point costs ~cin·pc fused multiply-adds per scale;
            // concat_c = scales·pc is a close enough work proxy
            let parts = if k * g3 * concat_c < PAR_MIN_WORK {
                1
            } else {
                pool.threads()
            };
            let ranges = WorkerPool::partition(k, parts);
            let jobs = row_jobs(&mut xcat, &ranges, g3 * concat_c);
            let scales_ref: &[RoiScale] = &scales_ctx;
            pool.scatter(jobs, |_w, (kis, chunk)| {
                roi_pool_rows(
                    scales_ref,
                    rd,
                    &lin,
                    (x0, y0, z0),
                    g,
                    pc,
                    concat_c,
                    kis,
                    chunk,
                    level,
                );
            });
        }

        // shared per-grid-point MLP (the head's compute bulk)
        let h1 = linear(pool, level, &xcat, k * g3, &self.weights.roi_mlp1, true);
        let h2 = linear(pool, level, &h1, k * g3, &self.weights.roi_mlp2, true);

        // permutation-invariant pool over the grid: [mean || max]
        let mlp = self.weights.roi_mlp2.cout;
        let mut pooled = vec![0.0f32; k * 2 * mlp];
        for ki in 0..k {
            let dst = &mut pooled[ki * 2 * mlp..(ki + 1) * 2 * mlp];
            let (mean_part, max_part) = dst.split_at_mut(mlp);
            max_part.fill(f32::NEG_INFINITY);
            for gi in 0..g3 {
                let row = &h2[(ki * g3 + gi) * mlp..(ki * g3 + gi + 1) * mlp];
                for m in 0..mlp {
                    mean_part[m] += row[m];
                    if row[m] > max_part[m] {
                        max_part[m] = row[m];
                    }
                }
            }
            let inv = 1.0 / g3 as f32;
            for m in mean_part.iter_mut() {
                *m *= inv;
            }
        }

        let f1 = linear(pool, level, &pooled, k, &self.weights.roi_fc1, true);
        let f2 = linear(pool, level, &f1, k, &self.weights.roi_fc2, true);
        let cls = linear(pool, level, &f2, k, &self.weights.roi_cls, false);
        let reg = linear(pool, level, &f2, k, &self.weights.roi_reg, false);

        // residual decode in the RoI local frame (Voxel R-CNN style)
        let mut boxes = vec![0.0f32; k * 7];
        for ki in 0..k {
            let r = &rd[ki * 7..ki * 7 + 7];
            let dl = &reg[ki * 7..ki * 7 + 7];
            let diag = (r[3] * r[3] + r[4] * r[4]).sqrt();
            let b = &mut boxes[ki * 7..ki * 7 + 7];
            b[0] = r[0] + dl[0] * diag;
            b[1] = r[1] + dl[1] * diag;
            b[2] = r[2] + dl[2] * r[5];
            for m in 0..3 {
                b[3 + m] = r[3 + m] * dl[3 + m].clamp(-2.0, 2.0).exp();
            }
            b[6] = r[6] + dl[6];
        }
        Ok(vec![
            Tensor::from_vec(&spec.outputs[0].shape, cls)?,
            Tensor::from_vec(&spec.outputs[1].shape, boxes)?,
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    fn model() -> ReferenceModel {
        ReferenceModel::new(&test_manifest()).unwrap()
    }

    fn model_threaded(threads: usize) -> ReferenceModel {
        ReferenceModel::new_pooled(&test_manifest(), Arc::new(WorkerPool::new(threads)))
            .unwrap()
    }

    fn module_idx(m: &ReferenceModel, name: &str) -> usize {
        m.specs.iter().position(|s| s.name == name).unwrap()
    }

    fn sparse_input(shape: &[usize], hot: &[(usize, f32)]) -> Arc<Tensor> {
        let mut t = Tensor::zeros(shape);
        for &(i, v) in hot {
            t.data_mut()[i] = v;
        }
        Arc::new(t)
    }

    /// A random KITTI-ish sparse (feat, mask) pair for a conv stage input.
    fn random_stage_input(
        shape: &[usize],
        occupancy: f64,
        seed: u64,
    ) -> (Arc<Tensor>, Arc<Tensor>) {
        let mut rng = Rng::new(seed);
        let c = shape[3];
        let spatial: usize = shape[..3].iter().product();
        let mut feat = Tensor::zeros(shape);
        let mut mask = Tensor::zeros(&[shape[0], shape[1], shape[2], 1]);
        for s in 0..spatial {
            if rng.chance(occupancy) {
                mask.data_mut()[s] = 1.0;
                for ch in 0..c {
                    feat.data_mut()[s * c + ch] = (rng.normal() as f32).abs();
                }
            }
        }
        (Arc::new(feat), Arc::new(mask))
    }

    #[test]
    fn weights_are_deterministic() {
        let a = model();
        let b = model();
        assert_eq!(a.weights.stages[0].w, b.weights.stages[0].w);
        assert_eq!(a.weights.roi_reg.b, b.weights.roi_reg.b);
        // He scaling keeps magnitudes sane
        let std = {
            let w = &a.weights.bev_block1.w;
            let m = w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64;
            (w.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / w.len() as f64).sqrt()
        };
        let expect = (2.0 / (9.0 * a.weights.bev_block1.cin as f64)).sqrt();
        assert!((std / expect - 1.0).abs() < 0.1, "std {std} vs {expect}");
    }

    #[test]
    fn vfe_means_and_masks() {
        let m = model();
        let d = 16 * 128 * 128;
        // site 5: 2 points summing to (2, 4, 6, 1); site 9: 1 point at zero coords
        let sum = sparse_input(
            &[16, 128, 128, 4],
            &[(5 * 4, 2.0), (5 * 4 + 1, 4.0), (5 * 4 + 2, 6.0), (5 * 4 + 3, 1.0)],
        );
        let mut cnt = Tensor::zeros(&[16, 128, 128, 1]);
        cnt.data_mut()[5] = 2.0;
        cnt.data_mut()[9] = 1.0;
        let out = m.execute(module_idx(&m, "vfe"), &[sum, Arc::new(cnt)]).unwrap();
        let (feat, mask) = (&out[0], &out[1]);
        assert_eq!(feat.numel(), d * 4);
        assert_eq!(feat.data()[5 * 4], 1.0);
        assert_eq!(feat.data()[5 * 4 + 1], 2.0);
        assert_eq!(feat.data()[5 * 4 + 3], 0.5);
        assert_eq!(mask.data()[5], 1.0);
        assert_eq!(mask.data()[9], 1.0); // occupied even though features are 0
        assert_eq!(mask.site_index(), &[5, 9]);
        assert_eq!(feat.site_index(), &[5]);
    }

    #[test]
    fn conv_stage_matches_brute_force_at_active_sites() {
        let m = model();
        // a few active input sites scattered around (test manifest conv1:
        // regular conv, stride 1, 4 -> 16 channels)
        let (h, w) = (128usize, 128usize);
        let sites = [(3usize, 40usize, 50usize), (3, 41, 50), (7, 100, 2)];
        let mut feat = Tensor::zeros(&[16, 128, 128, 4]);
        let mut mask = Tensor::zeros(&[16, 128, 128, 1]);
        for (i, &(z, y, x)) in sites.iter().enumerate() {
            let s = (z * h + y) * w + x;
            for c in 0..4 {
                feat.data_mut()[s * 4 + c] = (i + 1) as f32 * 0.3 + c as f32 * 0.1;
            }
            mask.data_mut()[s] = 1.0;
        }
        let out = m
            .execute(
                module_idx(&m, "conv1"),
                &[Arc::new(feat.clone()), Arc::new(mask.clone())],
            )
            .unwrap();
        let (of, om) = (&out[0], &out[1]);
        assert_eq!(of.shape(), &[16, 128, 128, 16]);
        // regular conv dilates: 2 adjacent sites + 1 lone site, all interior
        assert_eq!(om.site_index().len(), 27 + 9 + 27);
        // brute-force the conv at every active output site
        let cw = &m.weights.stages[0];
        for &o in om.site_index() {
            let oi = o as usize;
            let (oz, oy, ox) = (oi / (h * w), (oi / w) % h, oi % w);
            let mut expect = cw.b.clone();
            for dz in 0..3i64 {
                for dy in 0..3i64 {
                    for dx in 0..3i64 {
                        let (z, y, x) =
                            (oz as i64 + dz - 1, oy as i64 + dy - 1, ox as i64 + dx - 1);
                        if z < 0 || z >= 16 || y < 0 || y >= 128 || x < 0 || x >= 128 {
                            continue;
                        }
                        let s = (z as usize * h + y as usize) * w + x as usize;
                        for ci in 0..4 {
                            let xv = feat.data()[s * 4 + ci];
                            for (co, e) in expect.iter_mut().enumerate() {
                                *e += xv
                                    * cw.w[(((dz as usize * 3 + dy as usize) * 3
                                        + dx as usize)
                                        * 4
                                        + ci)
                                        * 16
                                        + co];
                            }
                        }
                    }
                }
            }
            for (co, e) in expect.iter().enumerate() {
                let got = of.data()[oi * 16 + co];
                let want = e.max(0.0);
                assert!(
                    (got - want).abs() < 1e-4,
                    "site {oi} ch {co}: {got} vs {want}"
                );
            }
        }
        // everything off the active set is exactly zero
        let active: std::collections::HashSet<u32> = om.site_index().iter().copied().collect();
        for s in 0..16 * h * w {
            if !active.contains(&(s as u32)) {
                assert!(of.data()[s * 16..(s + 1) * 16].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn strided_stage_dilates_and_downsamples() {
        let m = model();
        // conv2 in the test manifest: stride (2,1,1), 16 -> 32 channels
        let mut feat = Tensor::zeros(&[16, 128, 128, 16]);
        let mut mask = Tensor::zeros(&[16, 128, 128, 1]);
        let s = (8 * 128 + 64) * 128 + 64; // (z=8, y=64, x=64)
        for c in 0..16 {
            feat.data_mut()[s * 16 + c] = 1.0;
        }
        mask.data_mut()[s] = 1.0;
        let out = m
            .execute(module_idx(&m, "conv2"), &[Arc::new(feat), Arc::new(mask)])
            .unwrap();
        let om = &out[1];
        assert_eq!(om.shape(), &[8, 128, 128, 1]);
        // z=8 with stride 2 + pad 1 reaches output z ∈ {4} only when
        // 2*oz + dz - 1 == 8 has a dz in 0..3, i.e. oz ∈ {4} (dz=1)
        // wait: oz=4 -> covers z 7,8,9 — and no other oz reaches 8? oz*2+dz-1=8
        // needs dz = 9-2*oz ∈ {0,1,2} -> oz=4 (dz=1); y,x dilate by ±1
        let expect: usize = 9;
        assert_eq!(om.site_index().len(), expect, "one z slot x 3x3 in (y,x)");
    }

    #[test]
    fn heads_produce_finite_deterministic_outputs() {
        let m = model();
        let mut f4 = Tensor::zeros(&[2, 32, 32, 128]);
        let mut rng = Rng::new(3);
        for x in f4.data_mut().iter_mut() {
            if rng.chance(0.3) {
                *x = (rng.normal() as f32).abs();
            }
        }
        let f4 = Arc::new(f4);
        let out = m.execute(module_idx(&m, "bev_head"), &[f4.clone()]).unwrap();
        assert_eq!(out[0].shape(), &[6144]);
        assert_eq!(out[1].shape(), &[6144, 7]);
        assert_eq!(out[2].shape(), &[6144, 2]);
        assert!(out[0].data().iter().all(|x| x.is_finite()));
        let again = m.execute(module_idx(&m, "bev_head"), &[f4]).unwrap();
        assert_eq!(out[0], again[0]);

        // roi head on padding + one real box
        let mut rois = Tensor::zeros(&[96, 7]);
        rois.data_mut()[..7].copy_from_slice(&[10.0, 0.0, -1.0, 3.9, 1.6, 1.56, 0.3]);
        for slot in 1..96 {
            rois.data_mut()[slot * 7..slot * 7 + 7]
                .copy_from_slice(&[-1e4, -1e4, -1e4, 0.0, 0.0, 0.0, 0.0]);
        }
        let c2 = Arc::new(Tensor::zeros(&[8, 128, 128, 32]));
        let c3 = Arc::new(Tensor::zeros(&[4, 64, 64, 64]));
        let c4 = Arc::new(Tensor::zeros(&[2, 32, 32, 128]));
        let out = m
            .execute(
                module_idx(&m, "roi_head"),
                &[c2, c3, c4, Arc::new(rois)],
            )
            .unwrap();
        assert_eq!(out[0].shape(), &[96]);
        assert_eq!(out[1].shape(), &[96, 7]);
        assert!(out[0].data().iter().all(|x| x.is_finite()));
        assert!(out[1].data().iter().all(|x| x.is_finite()));
        // padding boxes keep zero size after the exp residual
        assert_eq!(out[1].data()[95 * 7 + 3], 0.0);
    }

    #[test]
    fn gather_gemm_matches_legacy_scalar_kernels_bitwise() {
        let m = model();
        // conv1 (regular, stride 1) on a realistic sparse input
        let (feat, mask) = random_stage_input(&[16, 128, 128, 4], 0.02, 7);
        let idx = module_idx(&m, "conv1");
        let new = m.execute(idx, &[feat.clone(), mask.clone()]).unwrap();
        let old = m.execute_legacy(idx, &[feat, mask]).unwrap();
        assert_eq!(new, old, "conv1 gather-GEMM diverged from scalar kernel");
        assert_eq!(new[0].site_index(), old[0].site_index());

        // conv3 (strided 2,2,2) exercises the strided gather path
        let (feat, mask) = random_stage_input(&[8, 128, 128, 32], 0.01, 8);
        let idx3 = module_idx(&m, "conv3");
        let new = m.execute(idx3, &[feat.clone(), mask.clone()]).unwrap();
        let old = m.execute_legacy(idx3, &[feat, mask]).unwrap();
        assert_eq!(new, old, "conv3 gather-GEMM diverged from scalar kernel");

        // bev_head: conv2d + linear towers
        let mut f4 = Tensor::zeros(&[2, 32, 32, 128]);
        let mut rng = Rng::new(9);
        for x in f4.data_mut().iter_mut() {
            if rng.chance(0.3) {
                *x = rng.normal() as f32;
            }
        }
        let f4 = Arc::new(f4);
        let bidx = module_idx(&m, "bev_head");
        let new = m.execute(bidx, &[f4.clone()]).unwrap();
        let old = m.execute_legacy(bidx, &[f4]).unwrap();
        assert_eq!(new, old, "bev_head gather-GEMM diverged from scalar kernel");
    }

    #[test]
    fn thread_counts_are_bit_identical_per_module() {
        let m1 = model_threaded(1);
        let m4 = model_threaded(4);
        let (feat, mask) = random_stage_input(&[16, 128, 128, 4], 0.02, 11);
        let idx = module_idx(&m1, "conv1");
        let a = m1.execute(idx, &[feat.clone(), mask.clone()]).unwrap();
        let b = m4.execute(idx, &[feat, mask]).unwrap();
        assert_eq!(a, b, "conv1 diverged across thread counts");
        assert_eq!(a[0].site_index(), b[0].site_index());

        let mut f4 = Tensor::zeros(&[2, 32, 32, 128]);
        let mut rng = Rng::new(13);
        for x in f4.data_mut().iter_mut() {
            if rng.chance(0.25) {
                *x = rng.normal() as f32;
            }
        }
        let f4 = Arc::new(f4);
        let bidx = module_idx(&m1, "bev_head");
        assert_eq!(
            m1.execute(bidx, &[f4.clone()]).unwrap(),
            m4.execute(bidx, &[f4]).unwrap(),
            "bev_head diverged across thread counts"
        );
    }

    #[test]
    fn legacy_path_exists_only_for_restructured_modules() {
        let m = model();
        let sum = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
        let cnt = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
        assert!(m.execute_legacy(module_idx(&m, "vfe"), &[sum, cnt]).is_err());
    }

    fn model_scalar() -> ReferenceModel {
        ReferenceModel::with_simd(
            &test_manifest(),
            Arc::new(WorkerPool::new(1)),
            SimdMode::Scalar,
        )
        .unwrap()
    }

    #[test]
    fn simd_dispatch_is_bitwise_identical_to_forced_scalar() {
        // auto-dispatch (AVX2/NEON where available) vs forced scalar, per
        // module; on scalar-only hosts this degenerates to scalar==scalar,
        // which is exactly the guarantee the fallback makes
        let ms = model_scalar();
        let mv = model(); // SimdMode::Auto
        assert_eq!(ms.simd_level(), SimdLevel::Scalar);
        assert_eq!(mv.simd_level(), simd::detect());

        let (feat, mask) = random_stage_input(&[16, 128, 128, 4], 0.02, 21);
        let idx = module_idx(&ms, "conv1");
        assert_eq!(
            ms.execute(idx, &[feat.clone(), mask.clone()]).unwrap(),
            mv.execute(idx, &[feat, mask]).unwrap(),
            "conv1 diverged between scalar and {} dispatch",
            mv.simd_level().name()
        );

        let (feat, mask) = random_stage_input(&[8, 128, 128, 32], 0.01, 22);
        let idx3 = module_idx(&ms, "conv3");
        assert_eq!(
            ms.execute(idx3, &[feat.clone(), mask.clone()]).unwrap(),
            mv.execute(idx3, &[feat, mask]).unwrap(),
            "strided conv3 diverged between scalar and SIMD dispatch"
        );

        let mut f4 = Tensor::zeros(&[2, 32, 32, 128]);
        let mut rng = Rng::new(23);
        for x in f4.data_mut().iter_mut() {
            if rng.chance(0.3) {
                *x = rng.normal() as f32;
            }
        }
        let f4 = Arc::new(f4);
        let bidx = module_idx(&ms, "bev_head");
        assert_eq!(
            ms.execute(bidx, &[f4.clone()]).unwrap(),
            mv.execute(bidx, &[f4]).unwrap(),
            "bev_head diverged between scalar and SIMD dispatch"
        );

        // roi_head: grid pool + towers (cout = 1 for the cls head also
        // exercises the all-remainder axpy path)
        let mut rois = Tensor::zeros(&[96, 7]);
        rois.data_mut()[..7].copy_from_slice(&[10.0, 0.0, -1.0, 3.9, 1.6, 1.56, 0.3]);
        for slot in 1..96 {
            rois.data_mut()[slot * 7..slot * 7 + 7]
                .copy_from_slice(&[-1e4, -1e4, -1e4, 0.0, 0.0, 0.0, 0.0]);
        }
        let mut c2 = Tensor::zeros(&[8, 128, 128, 32]);
        let mut rng = Rng::new(24);
        for x in c2.data_mut().iter_mut() {
            if rng.chance(0.05) {
                *x = (rng.normal() as f32).abs();
            }
        }
        let c2 = Arc::new(c2);
        let c3 = Arc::new(Tensor::zeros(&[4, 64, 64, 64]));
        let c4 = Arc::new(Tensor::zeros(&[2, 32, 32, 128]));
        let rois = Arc::new(rois);
        let ridx = module_idx(&ms, "roi_head");
        assert_eq!(
            ms.execute(ridx, &[c2.clone(), c3.clone(), c4.clone(), rois.clone()])
                .unwrap(),
            mv.execute(ridx, &[c2, c3, c4, rois]).unwrap(),
            "roi_head diverged between scalar and SIMD dispatch"
        );
    }

    #[test]
    fn forced_mode_errors_only_on_scalar_hosts() {
        let r = ReferenceModel::with_simd(
            &test_manifest(),
            Arc::new(WorkerPool::new(1)),
            SimdMode::Forced,
        );
        match r {
            Ok(m) => assert_ne!(m.simd_level(), SimdLevel::Scalar),
            Err(_) => assert_eq!(simd::detect(), SimdLevel::Scalar),
        }
    }

    #[test]
    fn tap_masks_skip_absent_taps_on_sparse_frames() {
        let m = model();
        assert_eq!(m.tap_stats(), (0, 0));
        // one isolated occupied site: the dilated active set is its 27
        // neighbors, and their neighborhoods are mostly absent
        let mut feat = Tensor::zeros(&[16, 128, 128, 4]);
        let mut mask = Tensor::zeros(&[16, 128, 128, 1]);
        let s = (8 * 128 + 64) * 128 + 64;
        for c in 0..4 {
            feat.data_mut()[s * 4 + c] = 1.0;
        }
        mask.data_mut()[s] = 1.0;
        let idx = module_idx(&m, "conv1");
        m.execute(idx, &[Arc::new(feat), Arc::new(mask)]).unwrap();
        let (seen, skipped) = m.tap_stats();
        assert!(seen >= 27, "one active tile must count its 27 taps");
        assert_eq!(seen % 27, 0, "taps are counted per whole tile");
        assert!(skipped > 0, "an isolated site must skip absent taps");
        assert!(skipped < seen, "the center tap is present, not skipped");

        // an empty frame runs no tiles at all
        let before = m.tap_stats();
        let feat = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
        let mask = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
        let out = m.execute(idx, &[feat, mask]).unwrap();
        assert_eq!(m.tap_stats(), before, "empty active set counts nothing");
        assert!(out[0].data().iter().all(|&x| x == 0.0));
        assert!(out[1].site_index().is_empty());
    }

    #[test]
    fn tap_mask_skips_match_legacy_on_adversarial_occupancy() {
        // single occupied site (max skipping), a dense 4³ block (interior
        // tiles skip nothing), and a fragmented diagonal — all must stay
        // bitwise equal to the legacy scalar kernel
        let m = model();
        let idx = module_idx(&m, "conv1");
        let cases: Vec<Vec<(usize, usize, usize)>> = vec![
            vec![(8, 64, 64)],
            (0..4usize)
                .flat_map(|z| {
                    (0..4usize).flat_map(move |y| (0..4usize).map(move |x| (6 + z, 60 + y, 60 + x)))
                })
                .collect(),
            (0..10usize).map(|i| (i, 3 * i, 5 * i)).collect(),
        ];
        for (ci, sites) in cases.iter().enumerate() {
            let mut feat = Tensor::zeros(&[16, 128, 128, 4]);
            let mut mask = Tensor::zeros(&[16, 128, 128, 1]);
            for (i, &(z, y, x)) in sites.iter().enumerate() {
                let s = (z * 128 + y) * 128 + x;
                for c in 0..4 {
                    feat.data_mut()[s * 4 + c] = (i + 1) as f32 * 0.17 + c as f32 * 0.05;
                }
                mask.data_mut()[s] = 1.0;
            }
            let feat = Arc::new(feat);
            let mask = Arc::new(mask);
            let new = m.execute(idx, &[feat.clone(), mask.clone()]).unwrap();
            let old = m.execute_legacy(idx, &[feat, mask]).unwrap();
            assert_eq!(new, old, "case {ci}: tap-masked kernel diverged from legacy");
            assert_eq!(new[0].site_index(), old[0].site_index(), "case {ci}");
        }
    }
}
