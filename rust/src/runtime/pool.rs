//! Shared worker pool + per-worker scratch arenas for the reference
//! executor's parallel kernels.
//!
//! The pool is deliberately simple: a parallel region hands out at most
//! `threads` pre-partitioned jobs (each owning a disjoint `&mut` slice of
//! the output buffer), [`WorkerPool::scatter`] runs them on scoped OS
//! threads, and the region joins before returning. Work is partitioned by
//! the *caller* — never stolen — so every output element is computed by
//! exactly one worker with the same per-element operation order as the
//! single-threaded path. That is the whole `threads=N == threads=1`
//! bit-identity argument: parallelism only interleaves independent output
//! rows, it never re-associates a float reduction.
//!
//! Region setup is O(threads) thread spawns (tens of µs); the kernels
//! behind it run for milliseconds, so no persistent thread + unsafe
//! closure-smuggling machinery is warranted. Single-job regions run inline
//! on the caller with zero overhead, which is also the `threads=1` path.
//!
//! Scratch buffers (patch/accumulator matrices for the gather-GEMM
//! kernels) come from a take/recycle arena mirroring the voxelizer's grid
//! pool: workers pop a [`Scratch`], grow it to the kernel's working-set
//! size once, and push it back, so steady-state kernel execution performs
//! no allocation (pinned by `rust/tests/executor.rs`).

use std::ops::Range;
use std::sync::Mutex;

/// Cap on pooled scratch arenas: enough for every worker of a few
/// concurrently executing regions (pipeline tail workers × kernel
/// threads), while bounding memory if a caller leaks regions.
const MAX_SCRATCH: usize = 32;

/// Reusable per-worker kernel buffer: `patch` holds the gathered
/// neighborhood matrix of the tile being processed (the kernels
/// accumulate in place in the output buffer, so one matrix suffices), and
/// `mask` holds the per-tap occupancy plane the sparse 3D conv gather
/// builds alongside it (which source site, if any, feeds each tap of each
/// site in the tile).
#[derive(Debug, Default)]
pub struct Scratch {
    pub patch: Vec<f32>,
    pub mask: Vec<u32>,
}

impl Scratch {
    /// Grow `patch` to at least `len` elements and return it. Contents are
    /// unspecified — gather passes must overwrite every element they read.
    pub fn patch_mut(&mut self, len: usize) -> &mut [f32] {
        if self.patch.len() < len {
            self.patch.resize(len, 0.0);
        }
        &mut self.patch[..len]
    }

    /// Grow `patch` and `mask` together and return both. One call (rather
    /// than two methods) because the gather needs simultaneous `&mut`
    /// borrows of the two planes, which a pair of `&mut self` accessors
    /// cannot hand out.
    pub fn patch_and_mask(&mut self, patch_len: usize, mask_len: usize) -> (&mut [f32], &mut [u32]) {
        if self.patch.len() < patch_len {
            self.patch.resize(patch_len, 0.0);
        }
        if self.mask.len() < mask_len {
            self.mask.resize(mask_len, 0);
        }
        (&mut self.patch[..patch_len], &mut self.mask[..mask_len])
    }

    /// Bytes currently reserved by this arena.
    pub fn capacity_bytes(&self) -> usize {
        self.patch.capacity() * std::mem::size_of::<f32>()
            + self.mask.capacity() * std::mem::size_of::<u32>()
    }
}

/// Resolve a requested thread count: `0` means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

/// Fixed-width worker pool for the reference executor's kernels.
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
    scratch: Mutex<Vec<Scratch>>,
}

impl WorkerPool {
    /// A pool of `threads` workers (`0` = all available cores).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool {
            threads: resolve_threads(threads).max(1),
            scratch: Mutex::new(Vec::new()),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `0..n` into at most `parts` contiguous, non-empty,
    /// near-equal ranges (first `n % parts` ranges get one extra item).
    pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let parts = parts.clamp(1, n);
        let base = n / parts;
        let extra = n % parts;
        let mut out = Vec::with_capacity(parts);
        let mut start = 0usize;
        for i in 0..parts {
            let len = base + usize::from(i < extra);
            out.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, n);
        out
    }

    /// Run `f(job_index, job)` for every job, in parallel. Callers
    /// pre-partition their work into at most [`WorkerPool::threads`] jobs,
    /// each owning whatever `&mut` output slice it needs — disjointness is
    /// enforced by construction (the jobs are built with `split_at_mut`).
    /// A single job runs inline on the caller's thread with no spawn.
    pub fn scatter<T, F>(&self, jobs: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(usize, T) + Sync,
    {
        let mut jobs = jobs;
        if jobs.is_empty() {
            return;
        }
        if jobs.len() == 1 {
            f(0, jobs.pop().expect("one job"));
            return;
        }
        let f = &f;
        std::thread::scope(|scope| {
            let mut iter = jobs.into_iter().enumerate();
            let (first_idx, first_job) = iter.next().expect("at least two jobs");
            for (i, job) in iter {
                scope.spawn(move || f(i, job));
            }
            // the caller's thread is worker 0, not an idle joiner
            f(first_idx, first_job);
        });
    }

    /// [`WorkerPool::partition`] + [`WorkerPool::scatter`] in one call:
    /// split `0..n` into at most `parts` contiguous ranges and run
    /// `f(range)` for each in parallel. The server's cross-client tail
    /// dispatch scatters each batch over the engine's kernel pool this
    /// way — `parts` lanes of frames, each frame's kernels then fanning
    /// out over the remaining thread budget — so stage- and kernel-level
    /// parallelism share one pool (and its scratch arenas) instead of
    /// oversubscribing.
    pub fn scatter_ranges<F>(&self, n: usize, parts: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.scatter(Self::partition(n, parts.max(1)), |_, r| f(r));
    }

    /// Pop a scratch arena (or a fresh empty one). Pair with
    /// [`WorkerPool::recycle`] so its buffers' capacity is reused by the
    /// next region instead of reallocated.
    pub fn scratch(&self) -> Scratch {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    /// Hand a scratch arena back to the pool.
    pub fn recycle(&self, s: Scratch) {
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < MAX_SCRATCH {
            pool.push(s);
        }
    }

    /// (pooled arena count, total reserved bytes) — the steady-state
    /// no-growth property test reads this.
    pub fn scratch_stats(&self) -> (usize, usize) {
        let pool = self.scratch.lock().unwrap();
        (pool.len(), pool.iter().map(Scratch::capacity_bytes).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_exactly_once() {
        for (n, parts) in [(0, 4), (1, 4), (7, 3), (8, 3), (100, 7), (3, 8)] {
            let ranges = WorkerPool::partition(n, parts);
            let mut covered = 0usize;
            let mut expect_start = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect_start, "ranges must be contiguous");
                assert!(r.end > r.start, "no empty ranges");
                covered += r.len();
                expect_start = r.end;
            }
            assert_eq!(covered, n, "n={n} parts={parts}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn scatter_runs_every_job_with_disjoint_slices() {
        let pool = WorkerPool::new(4);
        let n = 103usize;
        let mut out = vec![0u32; n];
        let ranges = WorkerPool::partition(n, pool.threads());
        let mut jobs: Vec<(Range<usize>, &mut [u32])> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [u32] = out.as_mut_slice();
        for r in ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
            rest = tail;
            jobs.push((r, chunk));
        }
        pool.scatter(jobs, |_w, (range, chunk)| {
            for (i, slot) in range.zip(chunk.iter_mut()) {
                *slot = i as u32 * 3;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32 * 3);
        }
    }

    #[test]
    fn scatter_single_job_runs_inline() {
        let pool = WorkerPool::new(1);
        let caller = std::thread::current().id();
        let ran = AtomicUsize::new(0);
        pool.scatter(vec![()], |w, ()| {
            assert_eq!(w, 0);
            assert_eq!(std::thread::current().id(), caller);
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scatter_ranges_covers_every_index_once() {
        let pool = WorkerPool::new(3);
        let n = 37usize;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.scatter_ranges(n, 5, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
        // n == 0 and parts == 0 are no-ops, not panics
        pool.scatter_ranges(0, 4, |_| panic!("no ranges for n=0"));
        pool.scatter_ranges(3, 0, |_| {});
    }

    #[test]
    fn scratch_recycles_capacity() {
        let pool = WorkerPool::new(2);
        let mut s = pool.scratch();
        assert_eq!(s.patch_mut(1024).len(), 1024);
        let bytes = s.capacity_bytes();
        assert!(bytes >= 4096);
        pool.recycle(s);
        assert_eq!(pool.scratch_stats(), (1, bytes));
        // taking it back drains the pool; capacity survives the roundtrip
        let again = pool.scratch();
        assert_eq!(pool.scratch_stats().0, 0);
        assert_eq!(again.capacity_bytes(), bytes);
        pool.recycle(again);
    }

    #[test]
    fn patch_and_mask_grow_together_and_count_in_capacity() {
        let pool = WorkerPool::new(1);
        let mut s = pool.scratch();
        let (patch, mask) = s.patch_and_mask(256, 216);
        assert_eq!(patch.len(), 256);
        assert_eq!(mask.len(), 216);
        mask[0] = 7;
        patch[0] = 1.0;
        let bytes = s.capacity_bytes();
        assert!(bytes >= 256 * 4 + 216 * 4);
        pool.recycle(s);
        assert_eq!(pool.scratch_stats(), (1, bytes));
        // shrinking requests reuse the same buffers — no reallocation
        let mut s = pool.scratch();
        let (p2, m2) = s.patch_and_mask(16, 27);
        assert_eq!((p2.len(), m2.len()), (16, 27));
        assert_eq!(s.capacity_bytes(), bytes);
    }

    #[test]
    fn zero_threads_resolves_to_available_cores() {
        let pool = WorkerPool::new(0);
        assert!(pool.threads() >= 1);
        assert_eq!(pool.threads(), resolve_threads(0));
    }
}
