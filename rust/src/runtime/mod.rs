//! Module runtime: executes the pipeline's compute modules.
//!
//! Two backends behind one dispatcher:
//!
//! * **reference** (default) — the in-crate deterministic port of
//!   `python/compile/kernels/ref.py` ([`reference`]); runs inline on the
//!   caller thread, fully offline.
//! * **pjrt** (`--features pjrt`, needs the `xla` crate) — loads the AOT'd
//!   HLO-text artifacts and executes them on a pool of PJRT worker threads
//!   (the `pjrt` module; compiled out of default builds, so not linked
//!   here — rustdoc on the default feature set would dangle).
//!
//! Hot-path contract: modules are addressed by dense [`ModuleId`] (resolved
//! once at engine construction), inputs flow as `&[Arc<Tensor>]` (no deep
//! copies into the backend), and per-module stats are indexed slots — the
//! steady-state execute path performs no `String` hashing or cloning.

pub mod pool;
pub mod reference;
pub mod simd;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{Manifest, ModuleSpec};
use crate::tensor::Tensor;

/// Dense id of a manifest module (aligned with `manifest.modules` order).
pub type ModuleId = usize;

/// Runtime statistics per module (feeds Table I).
#[derive(Debug, Clone, Default)]
pub struct ModuleStats {
    pub executions: u64,
    pub total: Duration,
}

enum Backend {
    Reference(reference::ReferenceModel),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtPool),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Reference(_) => write!(f, "Backend::Reference"),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => write!(f, "Backend::Pjrt"),
        }
    }
}

/// Shared handle to the module executor (`Send + Sync`; clone the `Arc`).
#[derive(Debug)]
pub struct XlaRuntime {
    backend: Backend,
    /// worker threads backing the kernel pool (reference) / job pool (PJRT)
    kernel_threads: usize,
    specs: Vec<ModuleSpec>,
    /// per-module accumulated stats, indexed by [`ModuleId`]
    stats: Mutex<Vec<ModuleStats>>,
}

impl XlaRuntime {
    /// Load the manifest's modules on the default backend.
    pub fn load(manifest: &Manifest) -> Result<XlaRuntime> {
        Self::load_pooled(manifest, 1)
    }

    /// Load with `threads` workers (`0` = all available cores). On the
    /// reference backend the threads form the shared kernel
    /// [`pool::WorkerPool`] that the gather-GEMM conv/linear stages
    /// parallelize over; on PJRT they size the executable worker pool.
    /// Outputs are bit-identical at any thread count (see
    /// `runtime::reference`).
    pub fn load_pooled(manifest: &Manifest, threads: usize) -> Result<XlaRuntime> {
        Self::load_with(manifest, threads, simd::SimdMode::Auto)
    }

    /// [`Self::load_pooled`] with an explicit SIMD dispatch mode for the
    /// reference backend's kernels (`--simd auto|scalar|forced`). PJRT
    /// executables carry their own codegen, so the mode is ignored there.
    pub fn load_with(
        manifest: &Manifest,
        threads: usize,
        simd: simd::SimdMode,
    ) -> Result<XlaRuntime> {
        let threads = pool::resolve_threads(threads).max(1);
        #[cfg(feature = "pjrt")]
        let backend = {
            let _ = simd; // AOT'd HLO picks its own instruction set
            Backend::Pjrt(pjrt::PjrtPool::load(manifest, threads)?)
        };
        #[cfg(not(feature = "pjrt"))]
        let backend = Backend::Reference(reference::ReferenceModel::with_simd(
            manifest,
            Arc::new(pool::WorkerPool::new(threads)),
            simd,
        )?);
        Ok(XlaRuntime {
            backend,
            kernel_threads: threads,
            specs: manifest.modules.clone(),
            stats: Mutex::new(vec![ModuleStats::default(); manifest.modules.len()]),
        })
    }

    /// Worker threads backing this runtime's kernels.
    pub fn threads(&self) -> usize {
        self.kernel_threads
    }

    /// The reference backend's shared kernel [`pool::WorkerPool`] (`None`
    /// on PJRT, whose executables schedule internally). The concurrent
    /// split server scatters cross-client tail batches over this same
    /// pool, so stage-level and kernel-level parallelism draw on one
    /// thread budget and one scratch-arena set.
    pub fn kernel_pool(&self) -> Option<&pool::WorkerPool> {
        match &self.backend {
            Backend::Reference(m) => Some(m.pool()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => None,
        }
    }

    /// (count, reserved bytes) of the reference backend's pooled kernel
    /// scratch arenas; `(0, 0)` on PJRT. The steady-state no-growth
    /// property test (`rust/tests/executor.rs`) reads this.
    pub fn scratch_stats(&self) -> (usize, usize) {
        match &self.backend {
            Backend::Reference(m) => m.pool().scratch_stats(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => (0, 0),
        }
    }

    /// The instruction set the reference kernels dispatch to (`"scalar"`,
    /// `"avx2"`, `"neon"`), or `"pjrt"` when that backend is compiled in.
    /// Recorded in bench artifacts and printed by session banners.
    pub fn simd_dispatch(&self) -> &'static str {
        match &self.backend {
            Backend::Reference(m) => m.simd_level().name(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// `(taps_seen, taps_skipped)` accumulated by the sparse 3D conv
    /// gather's per-tap occupancy masks; `(0, 0)` on PJRT. Skipped taps
    /// avoided both the gather fill and the axpy pass — the ratio is the
    /// sparse-frame win the tap masks buy (reported by `--report`).
    pub fn tap_stats(&self) -> (u64, u64) {
        match &self.backend {
            Backend::Reference(m) => m.tap_stats(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => (0, 0),
        }
    }

    pub fn has_module(&self, name: &str) -> bool {
        self.specs.iter().any(|m| m.name == name)
    }

    /// Resolve a module name to its dense id (do this once, not per frame).
    pub fn module_id(&self, name: &str) -> Result<ModuleId> {
        self.specs
            .iter()
            .position(|m| m.name == name)
            .with_context(|| format!("module '{name}' not loaded"))
    }

    /// Execute a module by name (convenience path for benches and tests;
    /// the engine resolves ids at construction and calls
    /// [`Self::execute_id`]).
    pub fn execute(&self, name: &str, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        self.execute_id(self.module_id(name)?, inputs)
    }

    /// Execute module `id` on shared host tensors. Inputs are validated
    /// against the manifest shapes, passed to the backend by reference —
    /// never deep-cloned — and outputs come back as fresh tensors.
    pub fn execute_id(&self, id: ModuleId, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
        let spec = self
            .specs
            .get(id)
            .with_context(|| format!("module id {id} out of range"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "module '{}' wants {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, ispec) in inputs.iter().zip(&spec.inputs) {
            if t.shape() != ispec.shape.as_slice() {
                bail!(
                    "module '{}' input '{}' shape {:?} != manifest {:?}",
                    spec.name,
                    ispec.name,
                    t.shape(),
                    ispec.shape
                );
            }
        }

        let started = Instant::now();
        let out = match &self.backend {
            Backend::Reference(m) => m.execute(id, inputs)?,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.execute(spec, inputs)?,
        };
        if out.len() != spec.outputs.len() {
            bail!(
                "module '{}' returned {} outputs, manifest says {}",
                spec.name,
                out.len(),
                spec.outputs.len()
            );
        }
        let elapsed = started.elapsed();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = &mut stats[id];
            s.executions += 1;
            s.total += elapsed;
        }
        Ok(out)
    }

    /// Submit a module execution without blocking the caller: the job runs
    /// on its own worker thread and the returned [`InflightJob`] is waited
    /// on whenever the output is actually needed. This is the overlap
    /// primitive for callers that want two modules in flight at once (the
    /// staged pipeline overlaps whole *stages* instead, which is cheaper —
    /// its worker threads live for the stream, not per job). Associated
    /// function because the job needs an owned `Arc` to outlive the caller.
    /// Errors if the worker thread cannot be spawned (thread/pid pressure).
    pub fn submit_id(
        rt: &Arc<XlaRuntime>,
        id: ModuleId,
        inputs: Vec<Arc<Tensor>>,
    ) -> Result<InflightJob> {
        let module = rt
            .specs
            .get(id)
            .map(|s| s.name.clone())
            .unwrap_or_else(|| format!("#{id}"));
        let rt = rt.clone();
        let handle = std::thread::Builder::new()
            .name("sp-inflight".into())
            .spawn(move || rt.execute_id(id, &inputs))
            .with_context(|| format!("spawning in-flight worker for '{module}'"))?;
        Ok(InflightJob { handle, module })
    }

    /// Name-resolving convenience for [`XlaRuntime::submit_id`].
    pub fn submit(
        rt: &Arc<XlaRuntime>,
        name: &str,
        inputs: Vec<Arc<Tensor>>,
    ) -> Result<InflightJob> {
        Self::submit_id(rt, rt.module_id(name)?, inputs)
    }

    /// Per-module accumulated timings (drives the Table I bench). Only
    /// modules that actually executed appear, matching the old map-based
    /// semantics.
    pub fn stats(&self) -> HashMap<String, ModuleStats> {
        let stats = self.stats.lock().unwrap();
        self.specs
            .iter()
            .zip(stats.iter())
            .filter(|(_, s)| s.executions > 0)
            .map(|(m, s)| (m.name.clone(), s.clone()))
            .collect()
    }

    pub fn reset_stats(&self) {
        for s in self.stats.lock().unwrap().iter_mut() {
            *s = ModuleStats::default();
        }
    }
}

/// A module execution in flight: the handle to a job submitted with
/// [`XlaRuntime::submit_id`]. Dropping without waiting detaches the job
/// (it still completes and its stats are recorded).
#[derive(Debug)]
pub struct InflightJob {
    handle: std::thread::JoinHandle<Result<Vec<Tensor>>>,
    module: String,
}

impl InflightJob {
    /// Module name this job executes (diagnostics).
    pub fn module(&self) -> &str {
        &self.module
    }

    /// True once the job's worker has finished (never blocks).
    pub fn is_finished(&self) -> bool {
        self.handle.is_finished()
    }

    /// Block until the job completes and take its outputs.
    pub fn wait(self) -> Result<Vec<Tensor>> {
        match self.handle.join() {
            Ok(result) => result,
            Err(_) => Err(anyhow!("in-flight job for module '{}' panicked", self.module)),
        }
    }
}

/// Helper kept public for tests: make sure `Arc<XlaRuntime>` is shareable.
pub fn assert_send_sync(_: &Arc<XlaRuntime>) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    fn runtime() -> XlaRuntime {
        XlaRuntime::load(&test_manifest()).unwrap()
    }

    #[test]
    fn module_ids_are_stable_and_named() {
        let rt = runtime();
        assert!(rt.has_module("vfe"));
        assert!(!rt.has_module("nope"));
        assert_eq!(rt.module_id("vfe").unwrap(), 0);
        assert_eq!(rt.module_id("roi_head").unwrap(), 6);
        assert!(rt.module_id("nope").is_err());
    }

    #[test]
    fn execute_validates_shapes_and_counts() {
        let rt = runtime();
        let bad = Arc::new(Tensor::zeros(&[2, 2]));
        assert!(rt.execute("vfe", &[bad.clone(), bad.clone()]).is_err());
        assert!(rt.execute("vfe", &[bad]).is_err());
        assert!(rt.execute("nonexistent", &[]).is_err());
    }

    #[test]
    fn stats_track_executions_by_module() {
        let rt = runtime();
        let sum = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
        let cnt = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
        let out = rt.execute("vfe", &[sum, cnt]).unwrap();
        assert_eq!(out.len(), 2);
        let stats = rt.stats();
        assert_eq!(stats["vfe"].executions, 1);
        assert!(!stats.contains_key("conv1"), "untouched modules excluded");
        rt.reset_stats();
        assert!(rt.stats().is_empty());
    }

    #[test]
    fn inflight_job_matches_blocking_execute() {
        let rt = Arc::new(runtime());
        let sum = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
        let cnt = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
        let blocking = rt.execute("vfe", &[sum.clone(), cnt.clone()]).unwrap();
        let job = XlaRuntime::submit(&rt, "vfe", vec![sum, cnt]).unwrap();
        assert_eq!(job.module(), "vfe");
        let out = job.wait().unwrap();
        assert_eq!(out.len(), blocking.len());
        for (a, b) in out.iter().zip(&blocking) {
            assert_eq!(a, b, "in-flight output diverged from blocking execute");
        }
        assert_eq!(rt.stats()["vfe"].executions, 2);
    }

    #[test]
    fn inflight_jobs_overlap_and_report_errors() {
        let rt = Arc::new(runtime());
        let sum = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
        let cnt = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
        let jobs: Vec<_> = (0..3)
            .map(|_| XlaRuntime::submit(&rt, "vfe", vec![sum.clone(), cnt.clone()]).unwrap())
            .collect();
        for job in jobs {
            assert_eq!(job.wait().unwrap().len(), 2);
        }
        assert_eq!(rt.stats()["vfe"].executions, 3);
        // shape errors surface at wait, not at submit
        let bad = XlaRuntime::submit(&rt, "vfe", vec![Arc::new(Tensor::zeros(&[2, 2]))]);
        assert!(bad.unwrap().wait().is_err());
        assert!(XlaRuntime::submit(&rt, "nonexistent", Vec::new()).is_err());
    }

    #[test]
    fn load_with_reports_dispatch_and_tap_stats() {
        let rt = XlaRuntime::load_with(&test_manifest(), 1, simd::SimdMode::Scalar).unwrap();
        #[cfg(not(feature = "pjrt"))]
        {
            assert_eq!(rt.simd_dispatch(), "scalar");
            let auto = XlaRuntime::load_with(&test_manifest(), 1, simd::SimdMode::Auto).unwrap();
            assert_eq!(auto.simd_dispatch(), simd::detect().name());
        }
        assert_eq!(rt.tap_stats(), (0, 0), "no kernels ran yet");
    }

    #[test]
    fn runtime_is_shareable() {
        let rt = Arc::new(runtime());
        assert_send_sync(&rt);
        let rt2 = rt.clone();
        std::thread::spawn(move || {
            let sum = Arc::new(Tensor::zeros(&[16, 128, 128, 4]));
            let cnt = Arc::new(Tensor::zeros(&[16, 128, 128, 1]));
            rt2.execute("vfe", &[sum, cnt]).unwrap();
        })
        .join()
        .unwrap();
        assert_eq!(rt.stats()["vfe"].executions, 1);
    }
}
