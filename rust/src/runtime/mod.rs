//! PJRT runtime: loads the AOT'd HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6, PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Python never runs on this path.
//!
//! The crate's `PjRtClient` is `Rc`-based (not `Send`), so the runtime is a
//! small executor service: each worker thread owns a client plus its
//! compiled executables, and [`XlaRuntime`] (cheap to share, `Send + Sync`)
//! dispatches execute requests over channels. One worker is the default;
//! more give throughput for the multi-sensor batcher at the cost of
//! per-worker compile time.

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{Manifest, ModuleSpec};
use crate::tensor::Tensor;

/// Runtime statistics per module (feeds Table I).
#[derive(Debug, Clone, Default)]
pub struct ModuleStats {
    pub executions: u64,
    pub total: Duration,
}

struct Job {
    module: String,
    inputs: Vec<Tensor>,
    reply: Sender<Result<Vec<Tensor>>>,
}

/// Shared handle to the executor service.
pub struct XlaRuntime {
    submit: Mutex<Vec<Sender<Job>>>,
    next: Mutex<usize>,
    stats: Mutex<HashMap<String, ModuleStats>>,
    module_names: Vec<String>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl XlaRuntime {
    /// Load the manifest's artifacts on one worker thread.
    pub fn load(manifest: &Manifest) -> Result<XlaRuntime> {
        Self::load_pooled(manifest, 1)
    }

    /// Load with `threads` independent PJRT workers.
    pub fn load_pooled(manifest: &Manifest, threads: usize) -> Result<XlaRuntime> {
        assert!(threads >= 1);
        let mut senders = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let (tx, rx) = channel::<Job>();
            let specs = manifest.modules.clone();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let worker = std::thread::Builder::new()
                .name(format!("xla-worker-{i}"))
                .spawn(move || worker_main(specs, rx, ready_tx))
                .context("spawning xla worker")?;
            // surface load/compile errors synchronously
            ready_rx
                .recv()
                .map_err(|_| anyhow!("xla worker {i} died during load"))??;
            senders.push(tx);
            workers.push(worker);
        }
        Ok(XlaRuntime {
            submit: Mutex::new(senders),
            next: Mutex::new(0),
            stats: Mutex::new(HashMap::new()),
            module_names: manifest.modules.iter().map(|m| m.name.clone()).collect(),
            workers: Mutex::new(workers),
        })
    }

    pub fn has_module(&self, name: &str) -> bool {
        self.module_names.iter().any(|m| m == name)
    }

    /// Execute a module on host tensors (round-robin across workers).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let started = Instant::now();
        let (reply_tx, reply_rx) = channel();
        {
            let senders = self.submit.lock().unwrap();
            let mut next = self.next.lock().unwrap();
            let idx = *next % senders.len();
            *next = next.wrapping_add(1);
            senders[idx]
                .send(Job {
                    module: name.to_string(),
                    inputs: inputs.to_vec(),
                    reply: reply_tx,
                })
                .map_err(|_| anyhow!("xla worker gone"))?;
        }
        let out = reply_rx
            .recv()
            .map_err(|_| anyhow!("xla worker dropped reply"))??;

        let elapsed = started.elapsed();
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(name.to_string()).or_default();
        s.executions += 1;
        s.total += elapsed;
        Ok(out)
    }

    /// Per-module accumulated timings (drives the Table I bench).
    pub fn stats(&self) -> HashMap<String, ModuleStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

impl Drop for XlaRuntime {
    fn drop(&mut self) {
        self.submit.lock().unwrap().clear(); // close channels
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------- worker

struct LoadedModule {
    spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

fn worker_main(specs: Vec<ModuleSpec>, rx: Receiver<Job>, ready: Sender<Result<()>>) {
    let loaded = match load_all(&specs) {
        Ok(l) => {
            let _ = ready.send(Ok(()));
            l
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let result = run_module(&loaded, &job.module, &job.inputs);
        let _ = job.reply.send(result);
    }
}

fn load_all(specs: &[ModuleSpec]) -> Result<HashMap<String, LoadedModule>> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
    let mut out = HashMap::new();
    for spec in specs {
        let path: &Path = &spec.artifact;
        if !path.exists() {
            bail!("artifact {} missing — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        out.insert(
            spec.name.clone(),
            LoadedModule {
                spec: spec.clone(),
                exe,
            },
        );
    }
    Ok(out)
}

fn run_module(
    loaded: &HashMap<String, LoadedModule>,
    name: &str,
    inputs: &[Tensor],
) -> Result<Vec<Tensor>> {
    let lm = loaded
        .get(name)
        .with_context(|| format!("module '{name}' not loaded"))?;
    if inputs.len() != lm.spec.inputs.len() {
        bail!(
            "module '{name}' wants {} inputs, got {}",
            lm.spec.inputs.len(),
            inputs.len()
        );
    }
    for (t, spec) in inputs.iter().zip(&lm.spec.inputs) {
        if t.shape() != spec.shape.as_slice() {
            bail!(
                "module '{name}' input '{}' shape {:?} != manifest {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
        }
    }
    let literals: Vec<xla::Literal> = inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
    let result = lm
        .exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing '{name}': {e}"))?;
    // single device, single output buffer; modules are lowered with
    // return_tuple=True so the buffer is a tuple of outputs
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching '{name}' result: {e}"))?;
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow!("untupling '{name}' result: {e}"))?;
    if parts.len() != lm.spec.outputs.len() {
        bail!(
            "module '{name}' returned {} outputs, manifest says {}",
            parts.len(),
            lm.spec.outputs.len()
        );
    }
    parts
        .into_iter()
        .zip(&lm.spec.outputs)
        .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
        .collect()
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {:?}: {e}", t.shape()))
}

fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e}"))?;
    Tensor::from_vec(shape, v)
}

// Exercised against real artifacts by rust/tests/integration.rs.

/// Helper kept public for tests: make sure `Arc<XlaRuntime>` is shareable.
pub fn assert_send_sync(_: &Arc<XlaRuntime>) {}
