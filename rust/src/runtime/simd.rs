//! Runtime-dispatched SIMD axpy micro-kernel for the reference executor.
//!
//! The gather-GEMM kernels (`runtime::reference`) spend nearly all their
//! time in one shape of loop: `acc[c] += x * w[c]` over a contiguous
//! `cout`-length row. This module vectorizes exactly that loop across the
//! **output-channel** dimension — each SIMD lane is a distinct accumulator
//! for a distinct output channel, so no floating-point reduction is ever
//! re-associated and the vector path is **bitwise identical** to the
//! scalar path:
//!
//! * lanes never interact: lane `c` computes `acc[c] + x * w[c]`, the
//!   same two IEEE-754 operations in the same order as the scalar loop;
//! * the multiply and add stay **separate instructions** (`mul` then
//!   `add`, never FMA — a fused contraction would skip the intermediate
//!   rounding the scalar code performs);
//! * the `cout % width` remainder runs the identical scalar loop.
//!
//! The instruction set is picked **once** at [`detect`] time (AVX2 on
//! x86_64 when the CPU reports it, NEON unconditionally on aarch64 — it
//! is part of the baseline ISA — scalar everywhere else) and threaded
//! through `ReferenceModel` as a [`SimdLevel`] value, so the hot loop
//! never re-probes CPUID. The CLI exposes the choice as
//! `--simd auto|scalar|forced` ([`SimdMode`]).

use anyhow::{bail, Result};

/// CLI-selectable dispatch mode (`--simd auto|scalar|forced`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Use the best instruction set the host reports (the default).
    #[default]
    Auto,
    /// Force the scalar fallback even when SIMD is available (bench
    /// `@scalar` twins, bisection of suspected codegen issues).
    Scalar,
    /// Require a vector path; error out if detection finds none. Guards
    /// perf runs against silently measuring the fallback.
    Forced,
}

impl SimdMode {
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
            SimdMode::Forced => "forced",
        }
    }
}

/// The instruction set a `ReferenceModel` dispatches to. Resolved once at
/// construction; copying it into kernel calls is free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Plain `for` loop — the reference semantics, available everywhere.
    Scalar,
    /// 8 × f32 per iteration via 256-bit AVX2 loads/stores.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 4 × f32 per iteration via 128-bit NEON; baseline on aarch64.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl SimdLevel {
    /// Stable lowercase name, recorded in bench artifacts
    /// (`cpu_features.dispatch`) and printed by session banners.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => "neon",
        }
    }
}

/// Probe the host CPU once. Cheap enough to call freely, but callers
/// should cache the result (as `ReferenceModel` does) so the kernels
/// branch on a plain enum.
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            SimdLevel::Avx2
        } else {
            SimdLevel::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline ISA — no runtime probe
        // needed (and `std` itself assumes it on this target).
        SimdLevel::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdLevel::Scalar
    }
}

/// Turn a CLI [`SimdMode`] into the concrete dispatch level.
pub fn resolve(mode: SimdMode) -> Result<SimdLevel> {
    let detected = detect();
    match mode {
        SimdMode::Auto => Ok(detected),
        SimdMode::Scalar => Ok(SimdLevel::Scalar),
        SimdMode::Forced => {
            if detected == SimdLevel::Scalar {
                bail!(
                    "--simd forced: no vector path available on this host \
                     (arch {}; AVX2 not detected and NEON requires aarch64)",
                    std::env::consts::ARCH
                );
            }
            Ok(detected)
        }
    }
}

/// `acc[c] += x * w[c]` for `c` in `0..acc.len()`, dispatched on `level`.
///
/// `w` must be at least as long as `acc`; only the first `acc.len()`
/// weights are read. All levels produce bit-identical results (see the
/// module docs for the argument).
#[inline]
pub fn axpy(level: SimdLevel, acc: &mut [f32], w: &[f32], x: f32) {
    debug_assert!(w.len() >= acc.len());
    match level {
        SimdLevel::Scalar => axpy_scalar(acc, w, x),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 variant only exists when `detect()` saw the
        // avx2 CPUID bit (or the caller constructed it deliberately on a
        // host that has it — `resolve` is the only public constructor
        // path); bounds are checked by the loop condition.
        SimdLevel::Avx2 => unsafe { axpy_avx2(acc, w, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; bounds are checked by the
        // loop condition.
        SimdLevel::Neon => unsafe { axpy_neon(acc, w, x) },
    }
}

/// The reference loop — byte-for-byte what the pre-SIMD kernels did.
#[inline]
fn axpy_scalar(acc: &mut [f32], w: &[f32], x: f32) {
    for (a, &wv) in acc.iter_mut().zip(w) {
        *a += x * wv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(acc: &mut [f32], w: &[f32], x: f32) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    let n = acc.len();
    let xv = _mm256_set1_ps(x);
    let mut i = 0usize;
    while i + 8 <= n {
        let wv = _mm256_loadu_ps(w.as_ptr().add(i));
        let av = _mm256_loadu_ps(acc.as_ptr().add(i));
        // mul then add — deliberately NOT `_mm256_fmadd_ps`: each lane
        // must round `x * w` before the add, exactly like the scalar
        // `*a += x * wv`, or bit-identity to the scalar kernels breaks.
        let prod = _mm256_mul_ps(xv, wv);
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(av, prod));
        i += 8;
    }
    axpy_scalar(&mut acc[i..], &w[i..], x);
}

#[cfg(target_arch = "aarch64")]
unsafe fn axpy_neon(acc: &mut [f32], w: &[f32], x: f32) {
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};
    let n = acc.len();
    let xv = vdupq_n_f32(x);
    let mut i = 0usize;
    while i + 4 <= n {
        let wv = vld1q_f32(w.as_ptr().add(i));
        let av = vld1q_f32(acc.as_ptr().add(i));
        // mul then add — deliberately NOT `vfmaq_f32`: fused contraction
        // would skip the intermediate rounding the scalar loop performs.
        let prod = vmulq_f32(xv, wv);
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(av, prod));
        i += 4;
    }
    axpy_scalar(&mut acc[i..], &w[i..], x);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// xorshift-ish generator over a wide magnitude band, including exact
    /// zeros (the kernels' skip case) and denormal-adjacent values.
    fn fill(seed: u64, out: &mut [f32]) {
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).max(1);
        for (i, v) in out.iter_mut().enumerate() {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let unit = (s >> 11) as f32 / (1u64 << 53) as f32 - 0.5;
            *v = match i % 7 {
                0 => 0.0,
                1 => unit * 1e-6,
                2 => unit * 1e6,
                _ => unit * 4.0,
            };
        }
    }

    #[test]
    fn mode_names_round_trip() {
        assert_eq!(SimdMode::Auto.name(), "auto");
        assert_eq!(SimdMode::Scalar.name(), "scalar");
        assert_eq!(SimdMode::Forced.name(), "forced");
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
    }

    #[test]
    fn resolve_honors_mode() {
        assert_eq!(resolve(SimdMode::Scalar).unwrap(), SimdLevel::Scalar);
        assert_eq!(resolve(SimdMode::Auto).unwrap(), detect());
        match resolve(SimdMode::Forced) {
            Ok(level) => {
                assert_ne!(level, SimdLevel::Scalar);
                assert_eq!(level, detect());
            }
            // forced must only fail where there is genuinely nothing to
            // force — i.e. detection already fell back to scalar
            Err(_) => assert_eq!(detect(), SimdLevel::Scalar),
        }
    }

    #[test]
    fn dispatched_axpy_is_bitwise_equal_to_scalar() {
        let level = detect();
        // remainder coverage: below / at / above both vector widths
        for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 11, 15, 16, 17, 31, 32, 33, 64, 100] {
            for seed in 0..4u64 {
                let mut w = vec![0.0f32; n];
                let mut acc_scalar = vec![0.0f32; n];
                fill(seed * 1000 + n as u64, &mut w);
                fill(seed * 2000 + n as u64 + 1, &mut acc_scalar);
                let mut acc_simd = acc_scalar.clone();
                let x = if seed == 3 { 0.0 } else { 1.25 + seed as f32 * 0.37 };
                axpy(level, &mut acc_simd, &w, x);
                axpy(SimdLevel::Scalar, &mut acc_scalar, &w, x);
                for (i, (a, b)) in acc_simd.iter().zip(&acc_scalar).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "lane {i} of n={n} seed={seed} diverged under {}",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn repeated_axpy_accumulation_stays_bitwise_equal() {
        // the kernels call axpy thousands of times into the same
        // accumulator; make sure divergence cannot build up across calls
        let level = detect();
        let cout = 96; // not a multiple of 8 → exercises the remainder
        let mut acc_scalar = vec![0.0f32; cout];
        let mut acc_simd = vec![0.0f32; cout];
        let mut w = vec![0.0f32; cout];
        for step in 0..200u64 {
            fill(step + 7, &mut w);
            let x = (step as f32 * 0.731).sin();
            axpy(level, &mut acc_simd, &w, x);
            axpy(SimdLevel::Scalar, &mut acc_scalar, &w, x);
        }
        let a: Vec<u32> = acc_simd.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = acc_scalar.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }
}
