//! Voxelizer: the pre-process stage (paper Fig 3, "Pre-process").
//!
//! Scatters a point cloud into the dense (sum, count) grids that the VFE
//! module consumes. This runs on the edge device for every split pattern
//! except raw offload, so it is a rust hot path: a single pass over the
//! points, branch-light inner loop — and, since the zero-clone refactor,
//! **no steady-state allocation at all**: output grids come from an
//! internal scratch pool, and recycling clears only the sites the previous
//! frame touched (via the tensor's occupied-site index) instead of
//! re-zeroing ~4 MB of dense grid per frame.
//!
//! The scatter pass also builds the occupied-site index as a by-product
//! and seeds it into the output tensors, so `occupied()`,
//! `Tensor::occupancy()` and the sparse wire codec never rescan the grid.

use std::sync::{Arc, Mutex};

use crate::model::manifest::ModelConfig;
use crate::pointcloud::PointCloud;
use crate::tensor::Tensor;

/// Cap on pooled scratch grids (bounds memory when many frames are in
/// flight; each entry is one (sum, cnt) pair).
const MAX_POOL: usize = 8;

/// A zeroed (sum, cnt) buffer pair awaiting reuse.
#[derive(Debug)]
struct PoolEntry {
    sum: Tensor,
    cnt: Tensor,
}

/// Point→voxel scatter for a fixed grid geometry.
#[derive(Debug, Clone)]
pub struct Voxelizer {
    grid: [usize; 3], // (D, H, W)
    origin: [f32; 3], // (x0, y0, z0)
    inv_voxel: [f32; 3], // 1 / (vx, vy, vz)
    features: usize,
    /// Scratch-grid pool, shared by clones of this voxelizer.
    pool: Arc<Mutex<Vec<PoolEntry>>>,
}

/// Output of the pre-process stage. Grids are refcounted so they flow into
/// the frame store, wire packets and the recycler without deep copies.
#[derive(Debug, Clone)]
pub struct VoxelGrids {
    /// (D, H, W, F) per-voxel feature sums
    pub sum: Arc<Tensor>,
    /// (D, H, W, 1) per-voxel point counts
    pub cnt: Arc<Tensor>,
    /// points that fell inside the grid
    pub in_range: usize,
}

impl Voxelizer {
    pub fn from_config(cfg: &ModelConfig) -> Voxelizer {
        let [d, h, w] = cfg.grid;
        // voxel_size is (z, y, x); compute from ranges to avoid drift
        let vx = (cfg.pc_range_x.1 - cfg.pc_range_x.0) / w as f64;
        let vy = (cfg.pc_range_y.1 - cfg.pc_range_y.0) / h as f64;
        let vz = (cfg.pc_range_z.1 - cfg.pc_range_z.0) / d as f64;
        Voxelizer {
            grid: cfg.grid,
            origin: [
                cfg.pc_range_x.0 as f32,
                cfg.pc_range_y.0 as f32,
                cfg.pc_range_z.0 as f32,
            ],
            inv_voxel: [1.0 / vx as f32, 1.0 / vy as f32, 1.0 / vz as f32],
            features: cfg.point_features,
            pool: Arc::new(Mutex::new(Vec::new())),
        }
    }

    pub fn grid(&self) -> [usize; 3] {
        self.grid
    }

    /// Zeroed grids for one frame: pooled when available, fresh otherwise.
    fn scratch(&self) -> (Tensor, Tensor) {
        if let Some(e) = self.pool.lock().unwrap().pop() {
            return (e.sum, e.cnt);
        }
        let [d, h, w] = self.grid;
        (
            Tensor::zeros(&[d, h, w, self.features]),
            Tensor::zeros(&[d, h, w, 1]),
        )
    }

    /// Scatter one cloud. Points outside the range are dropped (the scene
    /// generator pre-clips, but KITTI scans and raw-offload inputs do not).
    pub fn voxelize(&self, cloud: &PointCloud) -> VoxelGrids {
        let [d, h, w] = self.grid;
        let f = self.features;
        let (mut sum, mut cnt) = self.scratch();
        let [x0, y0, z0] = self.origin;
        let [ivx, ivy, ivz] = self.inv_voxel;
        let (df, hf, wf) = (d as f32, h as f32, w as f32);
        let mut in_range = 0usize;
        // occupied-site index, built as a by-product of the scatter pass
        let mut occupied: Vec<u32> = Vec::with_capacity(cloud.len().min(d * h * w));
        {
            let sum_data = sum.data_mut();
            let cnt_data = cnt.data_mut();
            for p in &cloud.points {
                // compute all three cell coords, then one combined bounds check
                let fx = (p.x - x0) * ivx;
                let fy = (p.y - y0) * ivy;
                let fz = (p.z - z0) * ivz;
                if fx < 0.0 || fx >= wf || fy < 0.0 || fy >= hf || fz < 0.0 || fz >= df {
                    continue;
                }
                let (ix, iy, iz) = (fx as usize, fy as usize, fz as usize);
                let site = (iz * h + iy) * w + ix;
                let base = site * f;
                if cnt_data[site] == 0.0 {
                    occupied.push(site as u32);
                }
                sum_data[base] += p.x;
                sum_data[base + 1] += p.y;
                sum_data[base + 2] += p.z;
                if f > 3 {
                    sum_data[base + 3] += p.intensity;
                }
                cnt_data[site] += 1.0;
                in_range += 1;
            }
        }
        occupied.sort_unstable();

        // seed the occupied-site indexes: cnt's is exactly `occupied`;
        // sum's keeps only sites whose feature vector is non-zero (a point
        // exactly at the origin with zero intensity sums to zero)
        let sum_sites: Vec<u32> = {
            let data = sum.data();
            occupied
                .iter()
                .copied()
                .filter(|&s| {
                    let b = s as usize * f;
                    data[b..b + f].iter().any(|&x| x != 0.0)
                })
                .collect()
        };
        sum.seed_sites(sum_sites);
        cnt.seed_sites(occupied);

        VoxelGrids {
            sum: Arc::new(sum),
            cnt: Arc::new(cnt),
            in_range,
        }
    }

    /// Occupied-voxel count of a scatter result (cached index, no rescan).
    pub fn occupied(grids: &VoxelGrids) -> usize {
        grids.cnt.site_index().len()
    }

    /// Occupied fraction of the grid in [0, 1] (cached index, no rescan).
    /// This is the quantity the conv stages' per-tap mask skip feeds on:
    /// at KITTI-like occupancy (a few percent) most 3×3×3 taps are absent
    /// for a whole gather tile, so low fractions predict high
    /// `XlaRuntime::tap_stats()` skip rates.
    pub fn occupancy_fraction(grids: &VoxelGrids) -> f64 {
        let [d, h, w] = [
            grids.cnt.shape()[0],
            grids.cnt.shape()[1],
            grids.cnt.shape()[2],
        ];
        let total = d * h * w;
        if total == 0 {
            return 0.0;
        }
        grids.cnt.site_index().len() as f64 / total as f64
    }

    /// Hand a frame's grids back to the scratch pool. No-op unless this is
    /// the last reference (a wire packet may still share the tensors).
    pub fn recycle(&self, grids: VoxelGrids) {
        self.recycle_parts(grids.sum, grids.cnt);
    }

    /// [`Self::recycle`] for grids already split into store slots. Each
    /// buffer is cleared through its own occupied-site index — touching
    /// only the sites the frame wrote, not the whole dense grid.
    pub fn recycle_parts(&self, sum: Arc<Tensor>, cnt: Arc<Tensor>) {
        let Ok(mut sum) = Arc::try_unwrap(sum) else {
            return;
        };
        let Ok(mut cnt) = Arc::try_unwrap(cnt) else {
            return;
        };
        let [d, h, w] = self.grid;
        let f = self.features;
        if sum.shape() != [d, h, w, f].as_slice() || cnt.shape() != [d, h, w, 1].as_slice() {
            return; // foreign tensors (e.g. resized config); drop them
        }
        let sum_sites = sum.site_index_arc();
        let cnt_sites = cnt.site_index_arc();
        {
            let data = sum.data_mut();
            for &s in sum_sites.iter() {
                let b = s as usize * f;
                data[b..b + f].fill(0.0);
            }
        }
        {
            let data = cnt.data_mut();
            for &s in cnt_sites.iter() {
                data[s as usize] = 0.0;
            }
        }
        debug_assert!(sum.data().iter().all(|&x| x == 0.0), "sum not cleared");
        debug_assert!(cnt.data().iter().all(|&x| x == 0.0), "cnt not cleared");
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < MAX_POOL {
            pool.push(PoolEntry { sum, cnt });
        }
    }

    /// Number of pooled scratch pairs (tests / metrics).
    pub fn pooled(&self) -> usize {
        self.pool.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::Point;

    fn test_config() -> ModelConfig {
        use crate::model::manifest::tests::test_manifest;
        test_manifest().config
    }

    fn vox() -> Voxelizer {
        Voxelizer::from_config(&test_config())
    }

    #[test]
    fn scatter_places_point_in_correct_voxel() {
        let v = vox();
        // voxel sizes: x,y: 0.36, z: 0.25; ranges x [0,46.08], y [-23.04,..], z [-3,1]
        let cloud = PointCloud {
            points: vec![Point { x: 0.5, y: -23.0, z: -2.9, intensity: 0.7 }],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 1);
        // ix = 0.5/0.36 = 1, iy = 0.04/0.36 = 0, iz = 0.1/0.25 = 0
        assert_eq!(g.cnt.get(&[0, 0, 1, 0]), 1.0);
        assert_eq!(g.sum.get(&[0, 0, 1, 0]), 0.5);
        assert_eq!(g.sum.get(&[0, 0, 1, 3]), 0.7);
        assert_eq!(Voxelizer::occupied(&g), 1);
    }

    #[test]
    fn out_of_range_points_dropped() {
        let v = vox();
        let cloud = PointCloud {
            points: vec![
                Point { x: -1.0, y: 0.0, z: 0.0, intensity: 0.0 },
                Point { x: 47.0, y: 0.0, z: 0.0, intensity: 0.0 },
                Point { x: 5.0, y: 0.0, z: 1.5, intensity: 0.0 },
            ],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 0);
        assert_eq!(Voxelizer::occupied(&g), 0);
    }

    #[test]
    fn counts_accumulate() {
        let v = vox();
        let p = Point { x: 10.0, y: 0.0, z: -1.0, intensity: 0.5 };
        let cloud = PointCloud { points: vec![p; 5] };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 5);
        assert_eq!(Voxelizer::occupied(&g), 1);
        let total: f32 = g.cnt.data().iter().sum();
        assert_eq!(total, 5.0);
        // mean recoverable: sum / cnt == x
        let site = g.cnt.data().iter().position(|&c| c > 0.0).unwrap();
        assert!((g.sum.data()[site * 4] / 5.0 - 10.0).abs() < 1e-5);
    }

    #[test]
    fn boundary_points_land_in_last_voxel() {
        let v = vox();
        let eps = 1e-4;
        let cloud = PointCloud {
            points: vec![Point {
                x: 46.08 - eps,
                y: 23.04 - eps,
                z: 1.0 - eps,
                intensity: 0.1,
            }],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 1);
        assert_eq!(g.cnt.get(&[15, 127, 127, 0]), 1.0);
    }

    #[test]
    fn synthetic_scene_occupancy_in_expected_band() {
        // The transfer-size mechanism (Fig 8) depends on VFE occupancy being
        // a few percent — assert the generator + voxelizer land there.
        let v = vox();
        let scene = crate::pointcloud::scene::SceneGenerator::with_seed(1).generate();
        let g = v.voxelize(&scene.cloud);
        let occ = Voxelizer::occupied(&g) as f64 / (16.0 * 128.0 * 128.0);
        assert!(
            (0.005..0.15).contains(&occ),
            "VFE occupancy {occ:.4} outside the KITTI-like band"
        );
    }

    #[test]
    fn occupancy_fraction_matches_occupied_count() {
        let v = vox();
        let scene = crate::pointcloud::scene::SceneGenerator::with_seed(3).generate();
        let g = v.voxelize(&scene.cloud);
        let expect = Voxelizer::occupied(&g) as f64 / (16.0 * 128.0 * 128.0);
        assert_eq!(Voxelizer::occupancy_fraction(&g), expect);
        let empty = v.voxelize(&PointCloud::default());
        assert_eq!(Voxelizer::occupancy_fraction(&empty), 0.0);
    }

    #[test]
    fn occupied_index_matches_dense_scan() {
        let v = vox();
        let scene = crate::pointcloud::scene::SceneGenerator::with_seed(5).generate();
        let g = v.voxelize(&scene.cloud);
        let dense: Vec<u32> = g
            .cnt
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0.0)
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(g.cnt.site_index(), dense.as_slice());
        assert_eq!(Voxelizer::occupied(&g), dense.len());
    }

    #[test]
    fn pooled_reuse_is_bitwise_identical_to_fresh() {
        use crate::pointcloud::scene::SceneGenerator;
        let pooled = vox();
        let fresh = vox();
        let a = SceneGenerator::with_seed(11).generate();
        let b = SceneGenerator::with_seed(12).generate();
        // dirty the pool with scene A, then re-voxelize scene B through it
        let ga = pooled.voxelize(&a.cloud);
        pooled.recycle(ga);
        assert_eq!(pooled.pooled(), 1);
        let gb_pooled = pooled.voxelize(&b.cloud);
        assert_eq!(pooled.pooled(), 0);
        let gb_fresh = fresh.voxelize(&b.cloud);
        assert_eq!(gb_pooled.in_range, gb_fresh.in_range);
        assert_eq!(*gb_pooled.sum, *gb_fresh.sum);
        assert_eq!(*gb_pooled.cnt, *gb_fresh.cnt);
        assert_eq!(gb_pooled.sum.site_index(), gb_fresh.sum.site_index());
    }

    #[test]
    fn recycle_skips_shared_grids() {
        let v = vox();
        let g = v.voxelize(&PointCloud::default());
        let hold = g.sum.clone(); // simulate a packet still sharing the grid
        v.recycle(g);
        assert_eq!(v.pooled(), 0, "shared grids must not be recycled");
        drop(hold);
    }
}
