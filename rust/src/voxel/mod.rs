//! Voxelizer: the pre-process stage (paper Fig 3, "Pre-process").
//!
//! Scatters a point cloud into the dense (sum, count) grids that the VFE
//! module consumes. This runs on the edge device for every split pattern
//! except raw offload, so it is a rust hot path: a single pass over the
//! points, branch-light inner loop, no allocation beyond the two output
//! grids.

use crate::model::manifest::ModelConfig;
use crate::pointcloud::PointCloud;
use crate::tensor::Tensor;

/// Point→voxel scatter for a fixed grid geometry.
#[derive(Debug, Clone)]
pub struct Voxelizer {
    grid: [usize; 3], // (D, H, W)
    origin: [f32; 3], // (x0, y0, z0)
    inv_voxel: [f32; 3], // 1 / (vx, vy, vz)
    features: usize,
}

/// Output of the pre-process stage.
#[derive(Debug, Clone)]
pub struct VoxelGrids {
    /// (D, H, W, F) per-voxel feature sums
    pub sum: Tensor,
    /// (D, H, W, 1) per-voxel point counts
    pub cnt: Tensor,
    /// points that fell inside the grid
    pub in_range: usize,
}

impl Voxelizer {
    pub fn from_config(cfg: &ModelConfig) -> Voxelizer {
        let [d, h, w] = cfg.grid;
        // voxel_size is (z, y, x); compute from ranges to avoid drift
        let vx = (cfg.pc_range_x.1 - cfg.pc_range_x.0) / w as f64;
        let vy = (cfg.pc_range_y.1 - cfg.pc_range_y.0) / h as f64;
        let vz = (cfg.pc_range_z.1 - cfg.pc_range_z.0) / d as f64;
        Voxelizer {
            grid: cfg.grid,
            origin: [
                cfg.pc_range_x.0 as f32,
                cfg.pc_range_y.0 as f32,
                cfg.pc_range_z.0 as f32,
            ],
            inv_voxel: [1.0 / vx as f32, 1.0 / vy as f32, 1.0 / vz as f32],
            features: cfg.point_features,
        }
    }

    pub fn grid(&self) -> [usize; 3] {
        self.grid
    }

    /// Scatter one cloud. Points outside the range are dropped (the scene
    /// generator pre-clips, but KITTI scans and raw-offload inputs do not).
    pub fn voxelize(&self, cloud: &PointCloud) -> VoxelGrids {
        let [d, h, w] = self.grid;
        let f = self.features;
        let mut sum = Tensor::zeros(&[d, h, w, f]);
        let mut cnt = Tensor::zeros(&[d, h, w, 1]);
        let sum_data = sum.data_mut();
        let cnt_data = cnt.data_mut();
        let [x0, y0, z0] = self.origin;
        let [ivx, ivy, ivz] = self.inv_voxel;
        let (df, hf, wf) = (d as f32, h as f32, w as f32);
        let mut in_range = 0usize;

        for p in &cloud.points {
            // compute all three cell coords, then one combined bounds check
            let fx = (p.x - x0) * ivx;
            let fy = (p.y - y0) * ivy;
            let fz = (p.z - z0) * ivz;
            if fx < 0.0 || fx >= wf || fy < 0.0 || fy >= hf || fz < 0.0 || fz >= df {
                continue;
            }
            let (ix, iy, iz) = (fx as usize, fy as usize, fz as usize);
            let site = (iz * h + iy) * w + ix;
            let base = site * f;
            sum_data[base] += p.x;
            sum_data[base + 1] += p.y;
            sum_data[base + 2] += p.z;
            if f > 3 {
                sum_data[base + 3] += p.intensity;
            }
            cnt_data[site] += 1.0;
            in_range += 1;
        }

        VoxelGrids { sum, cnt, in_range }
    }

    /// Occupied-voxel count of a scatter result.
    pub fn occupied(grids: &VoxelGrids) -> usize {
        grids.cnt.data().iter().filter(|&&c| c > 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::Point;

    fn test_config() -> ModelConfig {
        use crate::model::manifest::tests::test_manifest;
        test_manifest().config
    }

    fn vox() -> Voxelizer {
        Voxelizer::from_config(&test_config())
    }

    #[test]
    fn scatter_places_point_in_correct_voxel() {
        let v = vox();
        // voxel sizes: x,y: 0.36, z: 0.25; ranges x [0,46.08], y [-23.04,..], z [-3,1]
        let cloud = PointCloud {
            points: vec![Point { x: 0.5, y: -23.0, z: -2.9, intensity: 0.7 }],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 1);
        // ix = 0.5/0.36 = 1, iy = 0.04/0.36 = 0, iz = 0.1/0.25 = 0
        assert_eq!(g.cnt.get(&[0, 0, 1, 0]), 1.0);
        assert_eq!(g.sum.get(&[0, 0, 1, 0]), 0.5);
        assert_eq!(g.sum.get(&[0, 0, 1, 3]), 0.7);
        assert_eq!(Voxelizer::occupied(&g), 1);
    }

    #[test]
    fn out_of_range_points_dropped() {
        let v = vox();
        let cloud = PointCloud {
            points: vec![
                Point { x: -1.0, y: 0.0, z: 0.0, intensity: 0.0 },
                Point { x: 47.0, y: 0.0, z: 0.0, intensity: 0.0 },
                Point { x: 5.0, y: 0.0, z: 1.5, intensity: 0.0 },
            ],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 0);
        assert_eq!(Voxelizer::occupied(&g), 0);
    }

    #[test]
    fn counts_accumulate() {
        let v = vox();
        let p = Point { x: 10.0, y: 0.0, z: -1.0, intensity: 0.5 };
        let cloud = PointCloud { points: vec![p; 5] };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 5);
        assert_eq!(Voxelizer::occupied(&g), 1);
        let total: f32 = g.cnt.data().iter().sum();
        assert_eq!(total, 5.0);
        // mean recoverable: sum / cnt == x
        let site = g.cnt.data().iter().position(|&c| c > 0.0).unwrap();
        assert!((g.sum.data()[site * 4] / 5.0 - 10.0).abs() < 1e-5);
    }

    #[test]
    fn boundary_points_land_in_last_voxel() {
        let v = vox();
        let eps = 1e-4;
        let cloud = PointCloud {
            points: vec![Point {
                x: 46.08 - eps,
                y: 23.04 - eps,
                z: 1.0 - eps,
                intensity: 0.1,
            }],
        };
        let g = v.voxelize(&cloud);
        assert_eq!(g.in_range, 1);
        assert_eq!(g.cnt.get(&[15, 127, 127, 0]), 1.0);
    }

    #[test]
    fn synthetic_scene_occupancy_in_expected_band() {
        // The transfer-size mechanism (Fig 8) depends on VFE occupancy being
        // a few percent — assert the generator + voxelizer land there.
        let v = vox();
        let scene = crate::pointcloud::scene::SceneGenerator::with_seed(1).generate();
        let g = v.voxelize(&scene.cloud);
        let occ = Voxelizer::occupied(&g) as f64 / (16.0 * 128.0 * 128.0);
        assert!(
            (0.005..0.15).contains(&occ),
            "VFE occupancy {occ:.4} outside the KITTI-like band"
        );
    }
}
