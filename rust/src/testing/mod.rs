//! Property-testing harness (proptest substitute, offline build).
//!
//! Seeded randomized cases without shrinking; a failing case prints its
//! seed so `SPLITPOINT_PROP_SEED=<n>` replays it exactly.

use crate::util::rng::Rng;

/// Number of cases per property (`SPLITPOINT_PROP_CASES` overrides).
pub fn default_cases() -> usize {
    std::env::var("SPLITPOINT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded RNGs; panics with the failing seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("SPLITPOINT_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5eed_2026);
    let replay = std::env::var("SPLITPOINT_PROP_SEED").is_ok();
    let n = if replay { 1 } else { cases };
    for case in 0..n {
        let seed = base.wrapping_add(case as u64 * 0x9e37_79b9);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}, replay with \
                 SPLITPOINT_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 10, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "SPLITPOINT_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("fails", 5, |_| Err("nope".into()));
    }
}
