//! Micro/macro benchmark harness (criterion substitute, offline build).
//!
//! Warmup + fixed-iteration timing with mean/p50/p95 reporting; every
//! paper-figure bench (`rust/benches/`) is built on this.

pub mod paper;
pub mod regression;

use std::time::Instant;

use crate::metrics::Stats;

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 2,
            iters: 10,
        }
    }
}

impl BenchConfig {
    /// Honor `SPLITPOINT_BENCH_ITERS` / `_WARMUP` env overrides (CI dials
    /// the suite down; the perf pass dials it up).
    pub fn from_env() -> BenchConfig {
        let get = |k: &str, d: usize| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(d)
        };
        BenchConfig {
            warmup_iters: get("SPLITPOINT_BENCH_WARMUP", 2),
            iters: get("SPLITPOINT_BENCH_ITERS", 10),
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.stats.mean()
    }
}

/// Time `f` under the config; `f` returns an optional "observed value"
/// (e.g. simulated ms) — when provided it is recorded instead of wall time,
/// letting virtual-clock benches reuse the same reporting.
pub fn run_bench<F>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult
where
    F: FnMut() -> Option<f64>,
{
    for _ in 0..cfg.warmup_iters {
        let _ = f();
    }
    let mut stats = Stats::new();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        let observed = f();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        stats.push(observed.unwrap_or(wall_ms));
    }
    BenchResult {
        name: name.to_string(),
        stats,
    }
}

/// Pretty table of results.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<36} {:>10} {:>10} {:>10} {:>6}",
        "bench", "mean ms", "p50 ms", "p95 ms", "n"
    );
    for r in results {
        println!(
            "{:<36} {:>10.2} {:>10.2} {:>10.2} {:>6}",
            r.name,
            r.stats.mean(),
            r.stats.p50(),
            r.stats.p95(),
            r.stats.count()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_observed_value_when_given() {
        let r = run_bench(
            "obs",
            BenchConfig {
                warmup_iters: 0,
                iters: 5,
            },
            || Some(42.0),
        );
        assert_eq!(r.stats.count(), 5);
        assert!((r.mean_ms() - 42.0).abs() < 1e-12);
    }

    #[test]
    fn records_wall_time_otherwise() {
        let r = run_bench(
            "wall",
            BenchConfig {
                warmup_iters: 1,
                iters: 3,
            },
            || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                None
            },
        );
        assert!(r.mean_ms() >= 1.5, "{}", r.mean_ms());
    }
}
