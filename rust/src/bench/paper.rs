//! Paper-figure report generators: every table and figure of the paper's
//! evaluation, regenerated against this stack. Shared by the CLI
//! (`splitpoint sweep|table1`) and the bench suite (`cargo bench`).

use std::fmt::Write as _;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::metrics::Recorder;
use crate::model::graph::SplitPoint;
use crate::pointcloud::scene::SceneGenerator;

/// Paper reference numbers (RAGE 2024 / CS.DC 2025, §IV).
pub mod reference {
    /// Table I: module execution-time ratios (% of total), Voxel R-CNN.
    pub const TABLE1: [(&str, f64); 6] = [
        ("vfe", 0.16869),
        ("backbone3d", 33.55415),
        ("map_to_bev", 0.28388),
        ("backbone2d", 2.43162),
        ("dense_head", 1.15625),
        ("roi_head", 62.40541),
    ];
    /// Fig 6: inference time ms per split pattern.
    pub const FIG6: [(&str, f64); 4] = [
        ("edge_only", 322.0),
        ("after:vfe", 93.9),
        ("after:conv1", 138.0),
        ("after:conv2", 426.0),
    ];
    /// Fig 7: edge execution time ms.
    pub const FIG7: [(&str, f64); 4] = [
        ("edge_only", 322.0),
        ("after:vfe", 33.6),
        ("after:conv1", 98.2),
        ("after:conv2", 353.0),
    ];
    /// Fig 8: transfer size MB (raw = input cloud).
    pub const FIG8: [(&str, f64); 4] = [
        ("raw", 1.84),
        ("after:vfe", 1.18),
        ("after:conv1", 7.23),
        ("after:conv2", 29.0),
    ];
    /// Fig 9: transfer time ms.
    pub const FIG9: [(&str, f64); 3] = [
        ("after:vfe", 19.2),
        ("after:conv1", 77.0),
        ("after:conv2", 313.0),
    ];
}

/// The split patterns the paper evaluates (plus the raw-offload baseline
/// the intro argues against).
pub fn paper_splits(engine: &Engine) -> Result<Vec<SplitPoint>> {
    let g = engine.graph();
    Ok(vec![
        g.split_edge_only(),
        g.split_raw(),
        g.split_after("vfe")?,
        g.split_after("conv1")?,
        g.split_after("conv2")?,
    ])
}

/// Measured sweep over split patterns: one Recorder per metric family.
#[derive(Debug, Clone, Default)]
pub struct SweepResult {
    /// label -> series of per-frame values
    pub inference_ms: Recorder,
    pub edge_ms: Recorder,
    pub transfer_mb: Recorder,
    pub transfer_ms: Recorder,
    /// per-node host time shares from edge_only runs (Table I)
    pub module_ms: Recorder,
    /// raw input size per frame (Fig 8's baseline bar)
    pub raw_mb: Recorder,
}

/// Run `frames` synthetic frames through each split pattern.
pub fn run_sweep(
    engine: &Engine,
    splits: &[SplitPoint],
    frames: usize,
    seed: u64,
) -> Result<SweepResult> {
    let mut out = SweepResult::default();
    let mut gen = SceneGenerator::with_seed(seed);
    for _ in 0..frames {
        let scene = gen.generate();
        out.raw_mb
            .record("raw_input", scene.cloud.size_bytes() as f64 / 1e6);
        for &sp in splits {
            let label = engine.graph().split_label(sp);
            let r = engine.run_frame(&scene.cloud, sp)?;
            out.inference_ms
                .record(&label, r.timing.inference_time.as_millis_f64());
            out.edge_ms.record(&label, r.timing.edge_time.as_millis_f64());
            if sp.head_len < engine.graph().len() {
                out.transfer_mb
                    .record(&label, r.timing.uplink_bytes as f64 / 1e6);
                out.transfer_ms
                    .record(&label, r.timing.uplink_time.as_millis_f64());
            }
            if sp.head_len == engine.graph().len() {
                // edge-only run: harvest per-module times for Table I
                for (name, t, _) in &r.timing.node_times {
                    out.module_ms.record(name, t.as_millis_f64());
                }
            }
        }
    }
    Ok(out)
}

/// Map our node names onto the paper's Table I module rows.
fn table1_rows(sweep: &SweepResult) -> Vec<(&'static str, f64)> {
    let m = |n: &str| sweep.module_ms.get(n).map(|s| s.mean()).unwrap_or(0.0);
    let backbone3d = m("conv1") + m("conv2") + m("conv3") + m("conv4");
    // bev_head fuses MapToBEV + Backbone2D + DenseHead in one artifact; we
    // report it as backbone2d and mark the fused rows (paper's 0.28% +
    // 2.43% + 1.16% together).
    vec![
        ("vfe", m("vfe") + m("preprocess")),
        ("backbone3d", backbone3d),
        ("map_to_bev+backbone2d+dense_head", m("bev_head")),
        ("roi_head", m("proposal") + m("roi_head")),
    ]
}

/// Table I report: measured module ratios vs the paper's.
pub fn table1_report(sweep: &SweepResult) -> String {
    let rows = table1_rows(sweep);
    let total: f64 = rows.iter().map(|(_, v)| v).sum();
    let mut s = String::new();
    let _ = writeln!(s, "## Table I — module execution-time ratios (edge profile)\n");
    let _ = writeln!(s, "| module | measured ms | measured % | paper % |");
    let _ = writeln!(s, "|---|---|---|---|");
    let paper = |name: &str| -> f64 {
        match name {
            "vfe" => 0.16869,
            "backbone3d" => 33.55415,
            "map_to_bev+backbone2d+dense_head" => 0.28388 + 2.43162 + 1.15625,
            "roi_head" => 62.40541,
            _ => 0.0,
        }
    };
    for (name, ms) in &rows {
        let _ = writeln!(
            s,
            "| {name} | {ms:.2} | {:.2}% | {:.2}% |",
            100.0 * ms / total,
            paper(name)
        );
    }
    s
}

/// Figs 6–9 report: measured vs paper, with reduction percentages.
pub fn figures_report(sweep: &SweepResult) -> String {
    let mut s = String::new();
    let mean = |rec: &Recorder, label: &str| rec.get(label).map(|x| x.mean());

    let _ = writeln!(s, "## Fig 6 — inference time per split pattern\n");
    let _ = writeln!(s, "| split | measured ms | vs edge-only | paper ms | paper delta |");
    let _ = writeln!(s, "|---|---|---|---|---|");
    let base = mean(&sweep.inference_ms, "edge_only").unwrap_or(f64::NAN);
    for (label, paper_ms) in reference::FIG6 {
        if let Some(ms) = mean(&sweep.inference_ms, label) {
            let _ = writeln!(
                s,
                "| {label} | {ms:.1} | {:+.1}% | {paper_ms} | {:+.1}% |",
                100.0 * (ms - base) / base,
                100.0 * (paper_ms - 322.0) / 322.0
            );
        }
    }

    let _ = writeln!(s, "\n## Fig 7 — edge execution time per split pattern\n");
    let _ = writeln!(s, "| split | measured ms | vs edge-only | paper ms | paper delta |");
    let _ = writeln!(s, "|---|---|---|---|---|");
    let base7 = mean(&sweep.edge_ms, "edge_only").unwrap_or(f64::NAN);
    for (label, paper_ms) in reference::FIG7 {
        if let Some(ms) = mean(&sweep.edge_ms, label) {
            let _ = writeln!(
                s,
                "| {label} | {ms:.1} | {:+.1}% | {paper_ms} | {:+.1}% |",
                100.0 * (ms - base7) / base7,
                100.0 * (paper_ms - 322.0) / 322.0
            );
        }
    }

    let _ = writeln!(s, "\n## Fig 8 — transfer size per split pattern\n");
    let _ = writeln!(s, "| split | measured MB | paper MB |");
    let _ = writeln!(s, "|---|---|---|");
    let raw = sweep.raw_mb.get("raw_input").map(|s| s.mean()).unwrap_or(0.0);
    let _ = writeln!(s, "| raw input cloud | {raw:.2} | 1.84 |");
    for (label, paper_mb) in reference::FIG8 {
        if label == "raw" {
            continue;
        }
        if let Some(mb) = mean(&sweep.transfer_mb, label) {
            let _ = writeln!(s, "| {label} | {mb:.2} | {paper_mb} |");
        }
    }

    let _ = writeln!(s, "\n## Fig 9 — transfer time per split pattern\n");
    let _ = writeln!(s, "| split | measured ms | paper ms |");
    let _ = writeln!(s, "|---|---|---|");
    for (label, paper_ms) in reference::FIG9 {
        if let Some(ms) = mean(&sweep.transfer_ms, label) {
            let _ = writeln!(s, "| {label} | {ms:.1} | {paper_ms} |");
        }
    }
    s
}

/// Table II report from static analysis (plus measured bytes).
pub fn table2_report(engine: &Engine) -> String {
    let g = engine.graph();
    let mut s = String::new();
    let _ = writeln!(
        s,
        "## Table II — transfer sets per split point (live-set analysis)\n"
    );
    let _ = writeln!(s, "| split after | tensors crossing the link |");
    let _ = writeln!(s, "|---|---|");
    for sp in g.all_splits() {
        let live = g.live_set(sp);
        let _ = writeln!(
            s,
            "| {} | {} |",
            g.split_label(sp),
            if live.is_empty() {
                "(none — edge only)".to_string()
            } else {
                live.join(", ")
            }
        );
    }
    s
}
