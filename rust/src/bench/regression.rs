//! Perf-regression gate over `BENCH_micro.json` (the CI lane's checker).
//!
//! The bench binary preserves the committed `baseline` section verbatim and
//! writes this run's numbers under `current`, so one file carries the whole
//! before/after pair. This module compares the two and fails the gate when
//! any tracked bench regresses beyond the threshold.
//!
//! Policy:
//!
//! * the compared statistic is `p50_ms` when both sides carry it (medians
//!   shrug off one noisy outlier iteration on shared CI runners), falling
//!   back to `mean_ms`;
//! * `@legacy` benches are exempt — they re-create *deliberately slow*
//!   pre-refactor behaviour as an in-run comparison anchor, so a "regression"
//!   there is meaningless;
//! * benches present in only one of the two sections never fail the gate
//!   (new benches join the baseline on the next full run); they are listed
//!   in the report instead.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::{self, Value};

/// One bench compared against its baseline.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub name: String,
    pub baseline_ms: f64,
    pub current_ms: f64,
    /// current / baseline — above 1.0 is slower
    pub ratio: f64,
}

/// Outcome of gating one `BENCH_micro.json` against its own baseline.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    /// every bench compared, sorted worst-ratio first
    pub checked: Vec<Comparison>,
    /// the subset whose ratio exceeds 1 + threshold
    pub regressions: Vec<Comparison>,
    /// benches in `current` with no baseline entry (will seed next run)
    pub unbaselined: Vec<String>,
    /// baseline benches that produced no `current` number this run —
    /// renamed, crashed, or filtered out; their regression coverage is
    /// gone until the baseline is re-recorded, so the report flags them
    pub missing_from_current: Vec<String>,
    /// `@legacy` benches excluded from gating
    pub exempt: Vec<String>,
    pub threshold: f64,
    /// true when the committed file had no baseline at all (first
    /// measurement hasn't happened yet). This **fails** the gate: an
    /// unmeasured tree must not green-light — the run's own output is the
    /// seed to commit.
    pub baseline_missing: bool,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && !self.baseline_missing
    }

    /// Markdown report (the CI artifact).
    pub fn to_markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "## perf gate (threshold {:.0}%)\n", self.threshold * 100.0);
        if self.baseline_missing {
            let _ = writeln!(
                out,
                "**FAIL** — no committed baseline: the tree is unmeasured, so there is \
                 nothing to gate against and a pass here would be vacuous. Seed now: \
                 take the freshly measured `BENCH_micro.json` this run just wrote \
                 (CI uploads it as the `BENCH_micro` artifact), commit it at the repo \
                 root, and the gate arms on the next run. Locally: \
                 `cargo bench --bench micro -- --json && git add BENCH_micro.json`."
            );
            return out;
        }
        let _ = writeln!(
            out,
            "**{}** — {} bench(es) checked, {} regression(s)\n",
            if self.passed() { "PASS" } else { "FAIL" },
            self.checked.len(),
            self.regressions.len()
        );
        let _ = writeln!(out, "| bench | baseline ms | current ms | ratio | verdict |");
        let _ = writeln!(out, "|---|---|---|---|---|");
        for c in &self.checked {
            let verdict = if c.ratio > 1.0 + self.threshold {
                "REGRESSED"
            } else if c.ratio < 1.0 - self.threshold {
                "improved"
            } else {
                "ok"
            };
            let _ = writeln!(
                out,
                "| {} | {:.3} | {:.3} | {:.2}x | {verdict} |",
                c.name, c.baseline_ms, c.current_ms, c.ratio
            );
        }
        if !self.unbaselined.is_empty() {
            let _ = writeln!(
                out,
                "\nnot yet baselined (seed on next full run): {}",
                self.unbaselined.join(", ")
            );
        }
        if !self.missing_from_current.is_empty() {
            let _ = writeln!(
                out,
                "\n**WARNING** — baselined benches with no current measurement \
                 (renamed, crashed, or filtered; coverage lost): {}",
                self.missing_from_current.join(", ")
            );
        }
        if !self.exempt.is_empty() {
            let _ = writeln!(
                out,
                "\nexempt `@legacy` re-creations: {}",
                self.exempt.join(", ")
            );
        }
        out
    }
}

/// The statistic a bench entry is judged on: p50 when present (robust to a
/// single noisy iteration), else mean.
fn tracked_stat(entry: &Value) -> Option<f64> {
    entry
        .get("p50_ms")
        .and_then(Value::as_f64)
        .or_else(|| entry.get("mean_ms").and_then(Value::as_f64))
}

/// Compare `current` against `baseline`. `threshold` is fractional: 0.15
/// fails any bench whose tracked statistic grew by more than 15%.
pub fn compare(
    baseline: &BTreeMap<String, Value>,
    current: &BTreeMap<String, Value>,
    threshold: f64,
) -> GateReport {
    let mut report = GateReport {
        threshold,
        baseline_missing: baseline.is_empty(),
        ..GateReport::default()
    };
    for (name, cur) in current {
        if name.contains("@legacy") {
            report.exempt.push(name.clone());
            continue;
        }
        let cur_ms = tracked_stat(cur).filter(|v| *v > 0.0);
        let base_ms = baseline
            .get(name)
            .and_then(tracked_stat)
            .filter(|v| *v > 0.0);
        match (base_ms, cur_ms) {
            (Some(base_ms), Some(cur_ms)) => report.checked.push(Comparison {
                name: name.clone(),
                baseline_ms: base_ms,
                current_ms: cur_ms,
                ratio: cur_ms / base_ms,
            }),
            (None, Some(_)) => report.unbaselined.push(name.clone()),
            // a baselined bench whose current entry carries no usable
            // number (missing or non-positive stat) has lost its coverage
            // just as surely as one that vanished — flag it
            (_, None) if baseline.contains_key(name) => {
                report.missing_from_current.push(name.clone())
            }
            (_, None) => {}
        }
    }
    for name in baseline.keys() {
        if !name.contains("@legacy") && !current.contains_key(name) {
            report.missing_from_current.push(name.clone());
        }
    }
    report
        .checked
        .sort_by(|a, b| b.ratio.partial_cmp(&a.ratio).unwrap_or(std::cmp::Ordering::Equal));
    report.regressions = report
        .checked
        .iter()
        .filter(|c| c.ratio > 1.0 + threshold)
        .cloned()
        .collect();
    report
}

fn section(doc: &Value, key: &str) -> BTreeMap<String, Value> {
    doc.get(key)
        .and_then(Value::as_obj)
        .cloned()
        .unwrap_or_default()
}

fn parse_doc(text: &str) -> Result<Value> {
    match json::parse(text) {
        Ok(v) => Ok(v),
        Err(e) => bail!("bench json does not parse: {e}"),
    }
}

/// Gate a whole `BENCH_micro.json` document (its own `current` vs its own
/// committed `baseline`). Note: the bench binary seeds missing baseline
/// entries from `current` when it writes the file, so for a fresh CI run
/// prefer [`gate_against`] with the *committed* file as the baseline side —
/// otherwise brand-new benches gate vacuously against themselves.
pub fn gate_file(text: &str, threshold: f64) -> Result<GateReport> {
    let doc = parse_doc(text)?;
    Ok(compare(
        &section(&doc, "baseline"),
        &section(&doc, "current"),
        threshold,
    ))
}

/// Gate a freshly measured document against a *separately committed*
/// baseline document (its `baseline` section). This is what CI does: copy
/// `BENCH_micro.json` before the bench run, then compare the rewritten
/// file's `current` against the pristine copy's `baseline`.
pub fn gate_against(baseline_text: &str, current_text: &str, threshold: f64) -> Result<GateReport> {
    let base_doc = parse_doc(baseline_text)?;
    let cur_doc = parse_doc(current_text)?;
    Ok(compare(
        &section(&base_doc, "baseline"),
        &section(&cur_doc, "current"),
        threshold,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(baseline: &[(&str, f64)], current: &[(&str, f64)]) -> String {
        let entry = |ms: f64| format!("{{\"mean_ms\": {ms}, \"p50_ms\": {ms}}}");
        let section = |pairs: &[(&str, f64)]| {
            pairs
                .iter()
                .map(|(k, v)| format!("\"{k}\": {}", entry(*v)))
                .collect::<Vec<_>>()
                .join(", ")
        };
        format!(
            "{{\"baseline\": {{{}}}, \"current\": {{{}}}}}",
            section(baseline),
            section(current)
        )
    }

    #[test]
    fn identical_numbers_pass() {
        let text = doc(&[("a", 1.0), ("b", 2.0)], &[("a", 1.0), ("b", 2.0)]);
        let gate = gate_file(&text, 0.15).unwrap();
        assert!(gate.passed());
        assert_eq!(gate.checked.len(), 2);
        assert!(!gate.baseline_missing);
    }

    #[test]
    fn synthetic_regression_fails_the_gate() {
        // 16% slower on one tracked bench: beyond the 15% threshold
        let text = doc(&[("a", 1.0), ("b", 2.0)], &[("a", 1.16), ("b", 2.0)]);
        let gate = gate_file(&text, 0.15).unwrap();
        assert!(!gate.passed());
        assert_eq!(gate.regressions.len(), 1);
        assert_eq!(gate.regressions[0].name, "a");
        assert!(gate.to_markdown().contains("REGRESSED"));
    }

    #[test]
    fn regression_within_threshold_passes() {
        let text = doc(&[("a", 1.0)], &[("a", 1.10)]);
        assert!(gate_file(&text, 0.15).unwrap().passed());
    }

    #[test]
    fn missing_baseline_fails_with_seed_instructions() {
        // an empty committed baseline must NOT green-light an unmeasured
        // tree: the gate fails and the report says exactly how to seed
        let text = "{\"baseline\": {}, \"current\": {\"a\": {\"mean_ms\": 1.0}}}";
        let gate = gate_file(text, 0.15).unwrap();
        assert!(!gate.passed(), "vacuous pass on an unmeasured tree");
        assert!(gate.baseline_missing);
        assert!(gate.regressions.is_empty(), "not a regression, a seed gap");
        let md = gate.to_markdown();
        assert!(md.contains("FAIL"));
        assert!(md.contains("Seed now"));
        assert!(md.contains("BENCH_micro.json"));
    }

    #[test]
    fn legacy_benches_are_exempt_and_new_benches_reported() {
        let text = doc(
            &[("codec/encode_sparse", 1.0)],
            &[
                ("codec/encode_sparse", 1.0),
                // 10x "regression" on a legacy re-creation: ignored
                ("codec/encode_sparse@legacy", 10.0),
                // brand-new bench: listed, not gated
                ("pipeline/stream_16_frames", 5.0),
            ],
        );
        let gate = gate_file(&text, 0.15).unwrap();
        assert!(gate.passed());
        assert_eq!(gate.exempt, ["codec/encode_sparse@legacy"]);
        assert_eq!(gate.unbaselined, ["pipeline/stream_16_frames"]);
    }

    #[test]
    fn worst_ratio_sorts_first_and_p50_preferred() {
        let text = "{\"baseline\": {\
            \"a\": {\"mean_ms\": 1.0, \"p50_ms\": 1.0},\
            \"b\": {\"mean_ms\": 1.0}},\
          \"current\": {\
            \"a\": {\"mean_ms\": 9.0, \"p50_ms\": 1.2},\
            \"b\": {\"mean_ms\": 1.3}}}";
        let gate = gate_file(text, 0.15).unwrap();
        // a is judged on p50 (1.2x) not mean (9x); b on mean (1.3x)
        assert_eq!(gate.checked[0].name, "b");
        assert!((gate.checked[0].ratio - 1.3).abs() < 1e-9);
        assert!((gate.checked[1].ratio - 1.2).abs() < 1e-9);
        assert_eq!(gate.regressions.len(), 2);
    }

    #[test]
    fn garbage_file_is_an_error() {
        assert!(gate_file("not json", 0.15).is_err());
    }

    #[test]
    fn vanished_benches_are_flagged_not_gated() {
        // 'gone' has a baseline but produced no current number this run
        let text = doc(&[("a", 1.0), ("gone", 2.0)], &[("a", 1.0)]);
        let gate = gate_file(&text, 0.15).unwrap();
        assert!(gate.passed());
        assert_eq!(gate.missing_from_current, ["gone"]);
        assert!(gate.to_markdown().contains("coverage lost"));
    }

    #[test]
    fn zeroed_current_stat_counts_as_lost_coverage() {
        // 'a' is present in current but its tracked stat is 0.0 — a timing
        // bug, not a measurement; it must not silently vanish from the gate
        let text = doc(&[("a", 1.0), ("b", 1.0)], &[("a", 0.0), ("b", 1.0)]);
        let gate = gate_file(&text, 0.15).unwrap();
        assert!(gate.passed());
        assert_eq!(gate.missing_from_current, ["a"]);
        assert_eq!(gate.checked.len(), 1);
    }

    #[test]
    fn gate_against_uses_committed_baseline_not_self_seeded() {
        // committed file: baseline pins 'a' at 1.0
        let committed = doc(&[("a", 1.0)], &[("a", 1.0)]);
        // fresh run rewrote the file: the bench binary seeded 'new' into
        // baseline from its own first measurement, and 'a' regressed 20%
        let fresh = doc(&[("a", 1.0), ("new", 5.0)], &[("a", 1.2), ("new", 5.0)]);
        let gate = gate_against(&committed, &fresh, 0.15).unwrap();
        assert!(!gate.passed());
        assert_eq!(gate.regressions[0].name, "a");
        // 'new' is reported as unbaselined (no committed entry), not
        // vacuously compared against its own run
        assert_eq!(gate.unbaselined, ["new"]);
    }
}
