//! # splitpoint
//!
//! Reproduction of *“3D Point Cloud Object Detection on Edge Devices for
//! Split Computing”* (Noguchi & Azumi, 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the split-computing coordinator: the
//!   [`SplitSession`] facade ([`coordinator::session`]), pipeline graph
//!   and live-set analysis ([`model::graph`]), wire codec
//!   ([`tensor::codec`]), device/link models and edge/server nodes
//!   ([`coordinator`]), voxelizer ([`voxel`]), synthetic and KITTI LiDAR
//!   workloads ([`pointcloud`]), proposal/NMS stage ([`postprocess`]).
//! * **L2/L1 (build-time python)** — Voxel R-CNN modules and Pallas
//!   kernels, AOT-lowered to HLO-text artifacts loaded by [`runtime`].
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod model;
pub mod pointcloud;
pub mod postprocess;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod util;
pub mod voxel;

pub use coordinator::session::{SplitSession, SplitSessionBuilder};
pub use model::graph::{PipelineGraph, SplitPoint, TensorId, TensorStore};
pub use model::manifest::Manifest;
pub use pointcloud::FrameSource;
pub use tensor::Tensor;
