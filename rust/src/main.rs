//! splitpoint CLI — leader entrypoint for the split-computing stack.
//!
//! Subcommands:
//!   run             one or more frames through a chosen split (virtual clock)
//!   sweep           regenerate the paper's Figs 6–9 + Table I over N frames
//!   explain-splits  print Table II (live-set analysis) for every split point
//!   estimate        adaptive split selection: analytic cost of every split
//!   calibrate       fit the edge slowdown + link bandwidth to paper targets
//!   serve-server    edge-server process (TCP, realtime)
//!   serve-edge      edge-device process: stream frames to a server (TCP)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use splitpoint::bench::paper;
use splitpoint::config::SystemConfig;
use splitpoint::coordinator::adaptive::{self, Objective};
use splitpoint::coordinator::pipeline;
use splitpoint::coordinator::remote::{EdgeClient, Server};
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::util::cli::{parse_threads, Args, Cli, CommandSpec, OptSpec};
use splitpoint::Manifest;

fn cli() -> Cli {
    let common = || {
        vec![
            OptSpec { name: "artifacts", value: Some("dir"), help: "artifact dir (default: artifacts)" },
            OptSpec { name: "config", value: Some("file"), help: "system config JSON" },
            OptSpec { name: "split", value: Some("name"), help: "split point: raw|preprocess|vfe|conv1..conv4|bev_head|proposal|edge_only" },
            OptSpec { name: "frames", value: Some("n"), help: "number of frames (default 5)" },
            OptSpec { name: "seed", value: Some("n"), help: "scene generator seed (default 1)" },
            OptSpec { name: "pipeline-depth", value: Some("n"), help: "staged pipeline depth; 1 = serial (default 1)" },
            OptSpec { name: "tail-workers", value: Some("n"), help: "parallel tail stages when pipelined (default 1)" },
            OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads; bit-identical at any count (default 1)" },
        ]
    };
    Cli {
        bin: "splitpoint",
        about: "Split Computing for 3D point-cloud object detection (Noguchi & Azumi 2025 reproduction)",
        commands: vec![
            CommandSpec { name: "run", help: "run frames through one split pattern", opts: common() },
            CommandSpec { name: "sweep", help: "regenerate paper Figs 6-9 + Tables I/II", opts: common() },
            CommandSpec { name: "explain-splits", help: "print Table II live-set analysis", opts: common() },
            CommandSpec { name: "estimate", help: "adaptive split selection (analytic cost model)", opts: common() },
            CommandSpec { name: "calibrate", help: "fit device/link constants to the paper's targets", opts: common() },
            CommandSpec {
                name: "serve-server",
                help: "run the edge-server process (TCP)",
                opts: vec![
                    OptSpec { name: "listen", value: Some("addr"), help: "bind address (default 127.0.0.1:7070)" },
                    OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads for the server tail (default 1)" },
                ],
            },
            CommandSpec {
                name: "serve-edge",
                help: "run the edge-device process against a server (TCP)",
                opts: vec![
                    OptSpec { name: "connect", value: Some("addr"), help: "server address (default 127.0.0.1:7070)" },
                    OptSpec { name: "frames", value: Some("n"), help: "number of frames to stream (default 10)" },
                    OptSpec { name: "seed", value: Some("n"), help: "scene generator seed (default 1)" },
                    OptSpec { name: "pipeline-depth", value: Some("n"), help: "max in-flight frames; overlap head(N+1) with server(N) (default 1 = serial)" },
                    OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads for the edge head (default 1)" },
                ],
            },
        ],
        global_opts: vec![],
    }
}

fn load_engine(args: &Args) -> Result<Engine> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&artifacts)?;
    let mut cfg = match args.get("config") {
        Some(p) => SystemConfig::load(&PathBuf::from(p))?,
        None => SystemConfig::paper(),
    };
    if let Some(split) = args.get("split") {
        cfg.split = split.to_string();
    }
    // one worker budget (`--threads`) serves both levels of parallelism:
    // when the staged pipeline runs W tail stages concurrently, each
    // execute's kernel pool gets threads/W so the two levels compose
    // instead of oversubscribing the host
    let threads = parse_threads(args.get("threads"))?;
    let depth: usize = args.get_parse("pipeline-depth")?.unwrap_or(1);
    let tail_workers: usize = if depth > 1 {
        args.get_parse("tail-workers")?.unwrap_or(1)
    } else {
        1
    };
    let kernel = pipeline::PipelineConfig::kernel_threads_for(threads, tail_workers);
    Engine::new_threaded(&manifest, cfg, kernel)
}

fn cmd_run(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let frames: usize = args.get_parse("frames")?.unwrap_or(5);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let depth: usize = args.get_parse("pipeline-depth")?.unwrap_or(1);
    let tail_workers: usize = args.get_parse("tail-workers")?.unwrap_or(1);
    let sp = engine.split()?;
    let mut gen = SceneGenerator::with_seed(seed);
    let kernel_threads = engine.runtime().threads();
    let depth_note = if depth > 1 {
        format!(", pipeline depth {depth} x{tail_workers} tails, {kernel_threads} kernel thread(s)")
    } else {
        format!(", {kernel_threads} kernel thread(s)")
    };
    println!(
        "running {frames} frame(s) at split '{}' (edge={} x{}, server={} x{}{depth_note})",
        engine.graph().split_label(sp),
        engine.config().edge.name,
        engine.config().edge.slowdown,
        engine.config().server.name,
        engine.config().server.slowdown,
    );
    let print_frame = |i: usize, pts: usize, r: &splitpoint::coordinator::FrameResult| {
        println!(
            "frame {i}: {} pts, {} dets | inference {:.1} ms, edge {:.1} ms, uplink {:.2} MB / {:.1} ms",
            pts,
            r.detections.len(),
            r.timing.inference_time.as_millis_f64(),
            r.timing.edge_time.as_millis_f64(),
            r.timing.uplink_bytes as f64 / 1e6,
            r.timing.uplink_time.as_millis_f64(),
        );
    };
    if depth > 1 {
        let clouds: Vec<_> = (0..frames).map(|_| gen.generate().cloud).collect();
        let t0 = std::time::Instant::now();
        let (results, report) = pipeline::run_stream(
            Arc::new(engine),
            sp,
            &clouds,
            pipeline::PipelineConfig {
                depth,
                tail_workers,
            },
        )?;
        let wall = t0.elapsed().as_secs_f64();
        for (i, r) in results.iter().enumerate() {
            print_frame(i, clouds[i].len(), r);
        }
        println!(
            "\npipelined {frames} frames in {wall:.2} s -> {:.2} frames/s wall",
            frames as f64 / wall.max(1e-9)
        );
        println!("\n{}", report.to_markdown());
    } else {
        for i in 0..frames {
            let scene = gen.generate();
            let r = engine.run_frame(&scene.cloud, sp)?;
            print_frame(i, scene.cloud.len(), &r);
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let frames: usize = args.get_parse("frames")?.unwrap_or(5);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let splits = paper::paper_splits(&engine)?;
    eprintln!("sweeping {} splits x {frames} frames …", splits.len());
    let sweep = paper::run_sweep(&engine, &splits, frames, seed)?;
    println!("{}", paper::table1_report(&sweep));
    println!("{}", paper::table2_report(&engine));
    println!("{}", paper::figures_report(&sweep));
    Ok(())
}

fn cmd_explain(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    println!("{}", paper::table2_report(&engine));
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let scene = SceneGenerator::with_seed(seed).generate();
    let estimates = adaptive::estimate_splits(&engine, &scene.cloud)?;
    println!("analytic cost of every split (one profile frame):\n");
    println!(
        "{:<18} {:>12} {:>12} {:>12}",
        "split", "uplink MB", "edge ms", "inference ms"
    );
    for e in &estimates {
        println!(
            "{:<18} {:>12.2} {:>12.1} {:>12.1}",
            e.label,
            e.uplink_bytes as f64 / 1e6,
            e.edge_time.as_millis_f64(),
            e.inference_time.as_millis_f64()
        );
    }
    let best = adaptive::choose_split(&engine, &scene.cloud, Objective::InferenceTime)?;
    println!("\nbest for inference time: {}", best.label);
    let best_edge = adaptive::choose_split(&engine, &scene.cloud, Objective::EdgeTime)?;
    println!("best for edge load:      {}", best_edge.label);
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let engine = load_engine(args)?;
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let frames: usize = args.get_parse("frames")?.unwrap_or(3);
    let mut gen = SceneGenerator::with_seed(seed);

    // measure per-module host means + the conv2 live-set size
    let mut host: std::collections::BTreeMap<String, f64> = Default::default();
    let mut conv2_bytes = 0usize;
    for _ in 0..frames {
        let scene = gen.generate();
        let (store, times) = engine.profile_frame(&scene.cloud)?;
        for (name, d) in &times {
            *host.entry(name.clone()).or_default() += d.as_secs_f64() * 1e3 / frames as f64;
        }
        let graph = engine.graph();
        let live = graph.live_ids(graph.split_after("conv2")?);
        conv2_bytes += splitpoint::tensor::codec::Packet::from_shared(
            live.iter()
                .map(|&id| {
                    (
                        graph.tensor_name(id).to_string(),
                        store.get(id).cloned().expect("profiled tensor present"),
                    )
                })
                .collect(),
        )
        .encoded_size(engine.config().codec)
            / frames;
    }

    // paper Table I targets on the 322 ms Jetson profile (DESIGN.md §6):
    // backbone3d's 108 ms is distributed over conv1..4 proportional to our
    // host means (the paper doesn't break the block down).
    let backbone_host: f64 = ["conv1", "conv2", "conv3", "conv4"]
        .iter()
        .map(|m| host.get(*m).copied().unwrap_or(0.0))
        .sum();
    let conv_factor = 322.0 * 0.3355415 / backbone_host;
    let targets: Vec<(&str, f64)> = vec![
        ("preprocess", 0.10),
        ("vfe", 322.0 * 0.0016869 - 0.10),
        ("bev_head", 322.0 * (0.0028388 + 0.0243162 + 0.0115625)),
        ("proposal", 2.0),
        ("roi_head", 322.0 * 0.6240541 - 2.0),
    ];

    println!("host per-module means over {frames} frame(s):");
    for (name, ms) in &host {
        println!("  {name:<12} {ms:>8.1} ms");
    }
    let bandwidth = conv2_bytes as f64 / 0.313; // paper: conv2 transfer 313 ms
    println!("\nconv2 live-set: {:.2} MB → bandwidth {:.2} MB/s (anchors Fig 9's 313 ms)",
        conv2_bytes as f64 / 1e6, bandwidth / 1e6);

    println!("\nper-module edge factors (Jetson Table I profile / host):");
    let mut factors: Vec<(String, f64)> = Vec::new();
    for m in ["conv1", "conv2", "conv3", "conv4"] {
        factors.push((m.to_string(), conv_factor));
    }
    for (m, target) in targets {
        let h = host.get(m).copied().unwrap_or(1.0).max(1e-6);
        factors.push((m.to_string(), target / h));
    }
    factors.sort_by(|a, b| a.0.cmp(&b.0));
    let mut json_factors = Vec::new();
    for (m, f) in &factors {
        println!("  {m:<12} {f:>8.3}");
        json_factors.push(format!("\"{m}\": {f:.4}"));
    }
    println!(
        "\nconfig snippet (server = edge / {:.1}):",
        splitpoint::config::SERVER_SPEEDUP
    );
    println!(
        "{{\"edge\": {{\"name\": \"jetson-orin-nano\", \"slowdown\": {conv_factor:.3}, \
         \"module_factors\": {{{}}}}}, \
         \"link\": {{\"bandwidth_bps\": {bandwidth:.0}, \"rtt_one_way\": 0.0002}}}}",
        json_factors.join(", ")
    );
    Ok(())
}

fn cmd_serve_server(args: &Args) -> Result<()> {
    let engine = Arc::new(load_engine(args)?);
    let addr = args.get_or("listen", "127.0.0.1:7070");
    let server = Server::spawn(addr, engine)?;
    println!("edge-server listening on {}", server.addr());
    println!("Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_serve_edge(args: &Args) -> Result<()> {
    let engine = Arc::new(load_engine(args)?);
    let addr = args.get_or("connect", "127.0.0.1:7070").to_string();
    let frames: usize = args.get_parse("frames")?.unwrap_or(10);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let depth: usize = args.get_parse("pipeline-depth")?.unwrap_or(1);
    let sp = engine.split()?;
    let mut client = EdgeClient::connect(addr.as_str(), engine.clone())
        .with_context(|| format!("is `splitpoint serve-server` running at {addr}?"))?;
    let mut gen = SceneGenerator::with_seed(seed);
    let print_frame = |i: usize, dets: usize, t: &splitpoint::coordinator::remote::RemoteTiming| {
        println!(
            "frame {i}: {dets} dets | edge {:.1} ms + rtt {:.1} ms (server {:.1} ms) = {:.1} ms, uplink {:.2} MB",
            t.edge_compute.as_millis_f64(),
            t.round_trip.as_millis_f64(),
            t.server_compute.as_millis_f64(),
            t.inference_time.as_millis_f64(),
            t.uplink_bytes as f64 / 1e6,
        );
    };
    if depth > 1 {
        let clouds: Vec<_> = (0..frames).map(|_| gen.generate().cloud).collect();
        let t0 = std::time::Instant::now();
        let results = client.run_stream(&clouds, sp, depth)?;
        let wall = t0.elapsed().as_secs_f64();
        for (i, (dets, t)) in results.iter().enumerate() {
            print_frame(i, dets.len(), t);
        }
        println!(
            "\npipelined {frames} frames at depth {depth} in {wall:.2} s -> {:.2} frames/s wall",
            frames as f64 / wall.max(1e-9)
        );
    } else {
        for i in 0..frames {
            let scene = gen.generate();
            let (dets, t) = client.run_frame(&scene.cloud, sp)?;
            print_frame(i, dets.len(), &t);
        }
    }
    client.shutdown()?;
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = cli.parse(&argv)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("explain-splits") => cmd_explain(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve-server") => cmd_serve_server(&args),
        Some("serve-edge") => cmd_serve_edge(&args),
        _ => {
            println!("{}", cli.help(None));
            Ok(())
        }
    }
}
