//! splitpoint CLI — leader entrypoint for the split-computing stack.
//!
//! Every subcommand is a thin shell over [`SplitSession::builder`]: the
//! CLI flags pick a frame source (`--source synthetic|kitti:<dir>|
//! replay:<file>`), a transport (in-process, or TCP for the serve-*
//! pair), and a split policy (`--policy fixed|adaptive|adaptive-edge`);
//! the session runs the stream.
//!
//! Subcommands:
//!   run             stream frames through the session (virtual clock)
//!   sweep           regenerate the paper's Figs 6–9 + Table I over N frames
//!   explain-splits  print Table II (live-set analysis) for every split point
//!   estimate        adaptive split selection: analytic cost of every split
//!   calibrate       fit the edge slowdown + link bandwidth to paper targets
//!   serve-server    edge-server process (TCP, realtime, concurrent sessions)
//!   serve-edge      edge-device process: stream a source to a server (TCP)
//!   server-stats    fetch a running serve-server's metrics snapshot
//!   chaos-proxy     deterministic link-fault TCP relay for resilience tests
//!   compare-dets    tolerance-diff two --dets-out files (lossy wire gates)

use std::path::Path;

use anyhow::{bail, Result};

use splitpoint::bench::paper;
use splitpoint::coordinator::adaptive::{self, Objective};
use splitpoint::coordinator::fault::{ChaosProxy, FaultProfile};
use splitpoint::coordinator::remote::fetch_stats;
use splitpoint::coordinator::session::{
    Adaptive, ServerSession, SessionFrame, SessionReport, SplitPolicy, SplitSession,
    SplitSessionBuilder,
};
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::postprocess::compare::{self, Tolerance};
use splitpoint::tensor::codec::WirePrecision;
use splitpoint::util::cli::{parse_simd, parse_threads, Args, Cli, CommandSpec, OptSpec};

fn cli() -> Cli {
    let common = || {
        vec![
            OptSpec { name: "artifacts", value: Some("dir"), help: "artifact dir (default: artifacts)" },
            OptSpec { name: "config", value: Some("file"), help: "system config JSON" },
            OptSpec { name: "split", value: Some("name"), help: "split point: raw|preprocess|vfe|conv1..conv4|bev_head|proposal|edge_only" },
            OptSpec { name: "source", value: Some("spec"), help: "frame source: synthetic | kitti:<dir> | replay:<file>.bin | replay:<corpus-dir> (default synthetic)" },
            OptSpec { name: "policy", value: Some("name"), help: "split policy: fixed | adaptive | adaptive-edge (default fixed)" },
            OptSpec { name: "policy-every", value: Some("n"), help: "frames between adaptive re-evaluations (default 8)" },
            OptSpec { name: "frames", value: Some("n"), help: "frame count (synthetic default 5; kitti default: all scans)" },
            OptSpec { name: "seed", value: Some("n"), help: "scene generator seed (default 1)" },
            OptSpec { name: "pipeline-depth", value: Some("n"), help: "staged pipeline depth; 1 = serial (default 1)" },
            OptSpec { name: "tail-workers", value: Some("n"), help: "parallel tail stages when pipelined (default 1)" },
            OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads; bit-identical at any count (default 1)" },
            OptSpec { name: "simd", value: Some("mode"), help: "kernel SIMD dispatch: auto | scalar | forced; bit-identical at any setting (default auto)" },
            OptSpec { name: "wire", value: Some("prec"), help: "uplink payload precision: f32 | f16 | int8 (f32 ships byte-identical v2 frames; default f32)" },
        ]
    };
    // session-streaming extras (run + serve-edge)
    let streaming = || {
        vec![
            OptSpec { name: "sensors", value: Some("n"), help: "multi-sensor fan-in: replicate the source n times, round-robin, per-sensor tagging (default 1)" },
            OptSpec { name: "sink", value: Some("spec"), help: "frame sink: record:<dir> writes the streamed clouds + manifest as a replay corpus" },
            OptSpec { name: "dets-out", value: Some("file"), help: "write per-frame detections (bit-exact hex) for cross-run diffing" },
            OptSpec { name: "report", value: None, help: "print the per-segment policy-decision table after the stream" },
            OptSpec { name: "fault", value: Some("profile"), help: "wrap the transport in a seeded link-fault injector: clean | jitter | bandwidth-step | stall | disconnect (default off)" },
            OptSpec { name: "fault-seed", value: Some("n"), help: "fault-schedule seed; same seed = same schedule (default 1)" },
            OptSpec { name: "sla", value: Some("spec"), help: "SLA objectives, comma-separated kind=threshold: latency-bound=<secs> | bytes-bound=<bytes/frame> | edge-power-bound=<secs> (default none)" },
        ]
    };
    Cli {
        bin: "splitpoint",
        about: "Split Computing for 3D point-cloud object detection (Noguchi & Azumi 2025 reproduction)",
        commands: vec![
            CommandSpec {
                name: "run",
                help: "stream a frame source through one session",
                opts: common().into_iter().chain(streaming()).collect(),
            },
            CommandSpec { name: "sweep", help: "regenerate paper Figs 6-9 + Tables I/II", opts: common() },
            CommandSpec { name: "explain-splits", help: "print Table II live-set analysis", opts: common() },
            CommandSpec { name: "estimate", help: "adaptive split selection (analytic cost model)", opts: common() },
            CommandSpec { name: "calibrate", help: "fit device/link constants to the paper's targets", opts: common() },
            CommandSpec {
                name: "serve-server",
                help: "run the edge-server process (TCP, concurrent sessions)",
                opts: vec![
                    OptSpec { name: "listen", value: Some("addr"), help: "bind address (default 127.0.0.1:7070)" },
                    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact dir (default: artifacts)" },
                    OptSpec { name: "config", value: Some("file"), help: "system config JSON" },
                    OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads for the server tail (default 1)" },
                    OptSpec { name: "simd", value: Some("mode"), help: "kernel SIMD dispatch: auto | scalar | forced (default auto)" },
                    OptSpec { name: "max-sessions", value: Some("n"), help: "concurrent session cap; extra connections are refused (default 64)" },
                    OptSpec { name: "pending-cap", value: Some("n"), help: "global in-flight tail-job cap; excess requests get Busy + retry hint (default 256)" },
                    OptSpec { name: "session-window", value: Some("n"), help: "per-session in-flight bound before TCP backpressure (default 32)" },
                    OptSpec { name: "tail-slots", value: Some("n"), help: "parallel tail lanes per cross-client batch (default 1)" },
                    OptSpec { name: "batch-frames", value: Some("n"), help: "max frames coalesced into one tail dispatch (default 8)" },
                    OptSpec { name: "drain-timeout", value: Some("secs"), help: "graceful-drain deadline on shutdown (default 10)" },
                    OptSpec { name: "stats-every", value: Some("secs"), help: "periodic stderr metrics summary; 0 = off (default 30)" },
                    OptSpec { name: "metrics-addr", value: Some("addr"), help: "serve Prometheus text metrics over HTTP at this address (default off)" },
                    OptSpec { name: "wire", value: Some("prec"), help: "default uplink precision for locally built sessions: f32 | f16 | int8 (TCP clients choose their own; default f32)" },
                ],
            },
            CommandSpec {
                name: "server-stats",
                help: "fetch a running serve-server's metrics snapshot",
                opts: vec![
                    OptSpec { name: "connect", value: Some("addr"), help: "server address (default 127.0.0.1:7070); with --prom, the server's --metrics-addr" },
                    OptSpec { name: "prom", value: None, help: "scrape the Prometheus /metrics endpoint instead of the protocol Stats snapshot" },
                ],
            },
            CommandSpec {
                name: "serve-edge",
                help: "run the edge-device process against a server (TCP)",
                opts: vec![
                    OptSpec { name: "connect", value: Some("addr"), help: "server address (default 127.0.0.1:7070)" },
                    OptSpec { name: "artifacts", value: Some("dir"), help: "artifact dir (default: artifacts)" },
                    OptSpec { name: "config", value: Some("file"), help: "system config JSON" },
                    OptSpec { name: "split", value: Some("name"), help: "split point (default from config)" },
                    OptSpec { name: "source", value: Some("spec"), help: "frame source: synthetic | kitti:<dir> | replay:<file>.bin" },
                    OptSpec { name: "policy", value: Some("name"), help: "split policy: fixed | adaptive | adaptive-edge" },
                    OptSpec { name: "policy-every", value: Some("n"), help: "frames between adaptive re-evaluations (default 8)" },
                    OptSpec { name: "frames", value: Some("n"), help: "frames to stream (synthetic default 10)" },
                    OptSpec { name: "seed", value: Some("n"), help: "scene generator seed (default 1)" },
                    OptSpec { name: "pipeline-depth", value: Some("n"), help: "max in-flight frames; overlap head(N+1) with server(N), window kept full across segments (default 1 = serial)" },
                    OptSpec { name: "threads", value: Some("n|max"), help: "kernel worker threads for the edge head (default 1)" },
                    OptSpec { name: "simd", value: Some("mode"), help: "kernel SIMD dispatch: auto | scalar | forced (default auto)" },
                    OptSpec { name: "retry-max", value: Some("n"), help: "Busy/reconnect retry budget per request; 0 = fail fast (default 5)" },
                    OptSpec { name: "resume", value: None, help: "resumable session: reconnect after link drops and resume with no lost or duplicated frames" },
                    OptSpec { name: "wire", value: Some("prec"), help: "uplink payload precision: f32 | f16 | int8 (f32 ships byte-identical v2 frames; default f32)" },
                ]
                .into_iter()
                .chain(streaming())
                .collect(),
            },
            CommandSpec {
                name: "compare-dets",
                help: "tolerance-diff two --dets-out files (gate for lossy wire precisions)",
                opts: vec![
                    OptSpec { name: "a", value: Some("file"), help: "reference --dets-out file (typically the f32 run)" },
                    OptSpec { name: "b", value: Some("file"), help: "candidate --dets-out file (typically the quantized run)" },
                    OptSpec { name: "out", value: Some("file"), help: "write the machine-readable JSON diff report here" },
                    OptSpec { name: "iou-min", value: Some("f"), help: "minimum BEV IoU for two boxes to pair (default 0.7; 1.0 with the other epsilons at 0 = bit-identical)" },
                    OptSpec { name: "score-eps", value: Some("f"), help: "maximum |score difference| within a pair (default 0.05)" },
                    OptSpec { name: "center-eps", value: Some("f"), help: "maximum center distance in meters within a pair (default 0.1)" },
                    OptSpec { name: "drop-below", value: Some("f"), help: "ignore detections under this score on both sides (default 0 = keep all)" },
                ],
            },
            CommandSpec {
                name: "chaos-proxy",
                help: "deterministic link-fault TCP relay (resilience testing)",
                opts: vec![
                    OptSpec { name: "listen", value: Some("addr"), help: "bind address clients dial (default 127.0.0.1:7474)" },
                    OptSpec { name: "connect", value: Some("addr"), help: "upstream serve-server address (default 127.0.0.1:7070)" },
                    OptSpec { name: "fault", value: Some("profile"), help: "fault profile: clean | jitter | bandwidth-step | stall | disconnect (default clean)" },
                    OptSpec { name: "fault-seed", value: Some("n"), help: "fault-schedule seed; same seed = same schedule (default 1)" },
                ],
            },
        ],
        global_opts: vec![],
    }
}

/// Shared CLI → builder wiring: artifacts, config, split override, and
/// the threads/depth/tail-workers budget (one `--threads` serves kernel
/// and stage parallelism; see `PipelineConfig::kernel_threads_for`).
fn session_builder(args: &Args) -> Result<SplitSessionBuilder> {
    let mut b = SplitSession::builder().artifacts(args.get_or("artifacts", "artifacts"));
    if let Some(p) = args.get("config") {
        b = b.config_file(Path::new(p))?;
    }
    if let Some(split) = args.get("split") {
        b = b.split(split);
    }
    let depth: usize = args.get_parse("pipeline-depth")?.unwrap_or(1);
    let tail_workers: usize = if depth > 1 {
        args.get_parse("tail-workers")?.unwrap_or(1)
    } else {
        1
    };
    if let Some(w) = args.get("wire") {
        b = b.wire_precision(WirePrecision::parse(w)?);
    }
    Ok(b
        .threads(parse_threads(args.get("threads"))?)
        .simd(parse_simd(args.get("simd"))?)
        .pipeline_depth(depth)
        .tail_workers(tail_workers))
}

/// `--policy` flag → policy object (`None` = builder default, i.e. fixed
/// at the configured split).
fn policy_from(args: &Args) -> Result<Option<Box<dyn SplitPolicy>>> {
    let every: usize = args.get_parse("policy-every")?.unwrap_or(8);
    Ok(match args.get("policy") {
        None | Some("fixed") => None,
        Some("adaptive") => Some(Box::new(Adaptive::new(Objective::InferenceTime).every(every))),
        Some("adaptive-edge") => Some(Box::new(Adaptive::new(Objective::EdgeTime).every(every))),
        Some(other) => bail!("unknown --policy '{other}' (want fixed, adaptive, or adaptive-edge)"),
    })
}

/// Assemble the full session for `run`/`serve-edge`: shared builder plus
/// source, policy, and (for serve-edge) the TCP transport.
fn build_session(
    args: &Args,
    default_frames: Option<usize>,
    tcp_addr: Option<&str>,
) -> Result<SplitSession> {
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let frames: Option<usize> = match args.get_parse("frames")? {
        Some(n) => Some(n),
        // synthetic sources need a length; directory sources default to
        // everything they hold
        None => match args.get("source") {
            Some(s) if !s.starts_with("synthetic") => None,
            _ => default_frames,
        },
    };
    let sensors: usize = args.get_parse("sensors")?.unwrap_or(1);
    let mut b = session_builder(args)?
        .sensors(sensors)
        .source_spec(args.get("source"), seed, frames)?
        .sink_spec(args.get("sink"))?;
    if let Some(p) = policy_from(args)? {
        b = b.policy(p);
    }
    if let Some(addr) = tcp_addr {
        b = b.tcp(addr);
        if let Some(n) = args.get_parse("retry-max")? {
            b = b.retry_max(n);
        }
        if args.has("resume") {
            b = b.resume(true);
        }
    }
    if let Some(profile) = args.get("fault") {
        let seed: u64 = args.get_parse("fault-seed")?.unwrap_or(1);
        b = b.fault(FaultProfile::parse(profile)?, seed);
    }
    if let Some(spec) = args.get("sla") {
        b = b.sla_specs(splitpoint::telemetry::sla::parse_specs(spec)?);
    }
    b.build()
}

/// `--dets-out` accumulator: a transport/split/policy-invariant bit-exact
/// rendering of every delivered frame's detections. Scores and box
/// coordinates are printed as raw f32 bit patterns, so two runs that
/// claim byte-identical detections diff clean with `cmp` — the CI
/// `tcp-e2e` and `replay-corpus` lanes diff these files across the
/// in-process/TCP transports and the record/replay pair. The split label
/// is deliberately omitted: detections are split-invariant, policies are
/// not.
#[derive(Default)]
struct DetsOut {
    path: Option<String>,
    buf: String,
}

impl DetsOut {
    fn from_args(args: &Args) -> DetsOut {
        DetsOut {
            path: args.get("dets-out").map(str::to_string),
            buf: String::new(),
        }
    }

    fn push(&mut self, f: &SessionFrame) {
        if self.path.is_none() {
            return;
        }
        use std::fmt::Write as _;
        let _ = writeln!(
            self.buf,
            "frame seq={} sensor={} src={} pts={} dets={}",
            f.seq,
            f.sensor_id,
            f.source_seq,
            f.points,
            f.output.detections.len()
        );
        for d in &f.output.detections {
            let boxx: Vec<String> =
                d.boxx.iter().map(|v| format!("{:08x}", v.to_bits())).collect();
            let _ = writeln!(
                self.buf,
                "  det class={} score={:08x} box={}",
                d.class,
                d.score.to_bits(),
                boxx.join(",")
            );
        }
    }

    fn finish(self) -> Result<()> {
        if let Some(path) = self.path {
            std::fs::write(&path, self.buf)
                .map_err(|e| anyhow::anyhow!("writing --dets-out {path}: {e}"))?;
        }
        Ok(())
    }
}

fn print_session_banner(session: &SplitSession) {
    let cfg = session.engine().config();
    println!(
        "edge={} x{}, server={} x{}",
        cfg.edge.name, cfg.edge.slowdown, cfg.server.name, cfg.server.slowdown
    );
    println!("{}\n", session.describe());
}

fn print_session_tail(report: &SessionReport, show_segments: bool) {
    println!("\n{}", report.summary());
    if show_segments {
        if let Some(table) = report.segments_table() {
            println!("\nper-segment policy decisions:\n\n{table}");
        }
    }
    if let Some(md) = &report.transport_report {
        println!("\n{md}");
    }
    if let Some(sla) = &report.sla {
        println!("\n{}", sla.line());
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut session = build_session(args, Some(5), None)?;
    print_session_banner(&session);
    let mut dets = DetsOut::from_args(args);
    let report = session.run_with(|f: SessionFrame| {
        dets.push(&f);
        println!(
            "frame {} [s{} {}]: {} pts, {} dets | inference {:.1} ms, edge {:.1} ms, uplink {:.2} MB / {:.1} ms",
            f.seq,
            f.sensor_id,
            f.split_label,
            f.points,
            f.output.detections.len(),
            f.output.inference_time.as_millis_f64(),
            f.output.edge_time.as_millis_f64(),
            f.output.uplink_bytes as f64 / 1e6,
            f.output
                .timing
                .as_ref()
                .map(|t| t.uplink_time.as_millis_f64())
                .unwrap_or(0.0),
        );
    })?;
    dets.finish()?;
    print_session_tail(&report, args.has("report"));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let engine = session_builder(args)?.build_engine()?;
    let frames: usize = args.get_parse("frames")?.unwrap_or(5);
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let splits = paper::paper_splits(&engine)?;
    eprintln!("sweeping {} splits x {frames} frames …", splits.len());
    let sweep = paper::run_sweep(&engine, &splits, frames, seed)?;
    println!("{}", paper::table1_report(&sweep));
    println!("{}", paper::table2_report(&engine));
    println!("{}", paper::figures_report(&sweep));
    Ok(())
}

fn cmd_explain(args: &Args) -> Result<()> {
    let engine = session_builder(args)?.build_engine()?;
    println!("{}", paper::table2_report(&engine));
    Ok(())
}

fn cmd_estimate(args: &Args) -> Result<()> {
    let engine = session_builder(args)?.build_engine()?;
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let scene = SceneGenerator::with_seed(seed).generate();
    let estimates = adaptive::estimate_splits(&engine, &scene.cloud)?;
    println!("analytic cost of every split (one profile frame):\n");
    println!(
        "{:<18} {:>12} {:>12} {:>12}",
        "split", "uplink MB", "edge ms", "inference ms"
    );
    for e in &estimates {
        println!(
            "{:<18} {:>12.2} {:>12.1} {:>12.1}",
            e.label,
            e.uplink_bytes as f64 / 1e6,
            e.edge_time.as_millis_f64(),
            e.inference_time.as_millis_f64()
        );
    }
    let best = adaptive::choose_split(&engine, &scene.cloud, Objective::InferenceTime)?;
    println!("\nbest for inference time: {}", best.label);
    let best_edge = adaptive::choose_split(&engine, &scene.cloud, Objective::EdgeTime)?;
    println!("best for edge load:      {}", best_edge.label);
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let engine = session_builder(args)?.build_engine()?;
    let seed: u64 = args.get_parse("seed")?.unwrap_or(1);
    let frames: usize = args.get_parse("frames")?.unwrap_or(3);
    let mut gen = SceneGenerator::with_seed(seed);

    // measure per-module host means + the conv2 live-set size
    let mut host: std::collections::BTreeMap<String, f64> = Default::default();
    let mut conv2_bytes = 0usize;
    for _ in 0..frames {
        let scene = gen.generate();
        let (store, times) = engine.profile_frame(&scene.cloud)?;
        for (name, d) in &times {
            *host.entry(name.clone()).or_default() += d.as_secs_f64() * 1e3 / frames as f64;
        }
        let graph = engine.graph();
        let live = graph.live_ids(graph.split_after("conv2")?);
        conv2_bytes += splitpoint::tensor::codec::Packet::from_shared(
            live.iter()
                .map(|&id| {
                    (
                        graph.tensor_name(id).to_string(),
                        store.get(id).cloned().expect("profiled tensor present"),
                    )
                })
                .collect(),
        )
        .encoded_size(engine.config().codec)
            / frames;
    }

    // paper Table I targets on the 322 ms Jetson profile (DESIGN.md §6):
    // backbone3d's 108 ms is distributed over conv1..4 proportional to our
    // host means (the paper doesn't break the block down).
    let backbone_host: f64 = ["conv1", "conv2", "conv3", "conv4"]
        .iter()
        .map(|m| host.get(*m).copied().unwrap_or(0.0))
        .sum();
    let conv_factor = 322.0 * 0.3355415 / backbone_host;
    let targets: Vec<(&str, f64)> = vec![
        ("preprocess", 0.10),
        ("vfe", 322.0 * 0.0016869 - 0.10),
        ("bev_head", 322.0 * (0.0028388 + 0.0243162 + 0.0115625)),
        ("proposal", 2.0),
        ("roi_head", 322.0 * 0.6240541 - 2.0),
    ];

    println!("host per-module means over {frames} frame(s):");
    for (name, ms) in &host {
        println!("  {name:<12} {ms:>8.1} ms");
    }
    let bandwidth = conv2_bytes as f64 / 0.313; // paper: conv2 transfer 313 ms
    println!("\nconv2 live-set: {:.2} MB → bandwidth {:.2} MB/s (anchors Fig 9's 313 ms)",
        conv2_bytes as f64 / 1e6, bandwidth / 1e6);

    println!("\nper-module edge factors (Jetson Table I profile / host):");
    let mut factors: Vec<(String, f64)> = Vec::new();
    for m in ["conv1", "conv2", "conv3", "conv4"] {
        factors.push((m.to_string(), conv_factor));
    }
    for (m, target) in targets {
        let h = host.get(m).copied().unwrap_or(1.0).max(1e-6);
        factors.push((m.to_string(), target / h));
    }
    factors.sort_by(|a, b| a.0.cmp(&b.0));
    let mut json_factors = Vec::new();
    for (m, f) in &factors {
        println!("  {m:<12} {f:>8.3}");
        json_factors.push(format!("\"{m}\": {f:.4}"));
    }
    println!(
        "\nconfig snippet (server = edge / {:.1}):",
        splitpoint::config::SERVER_SPEEDUP
    );
    println!(
        "{{\"edge\": {{\"name\": \"jetson-orin-nano\", \"slowdown\": {conv_factor:.3}, \
         \"module_factors\": {{{}}}}}, \
         \"link\": {{\"bandwidth_bps\": {bandwidth:.0}, \"rtt_one_way\": 0.0002}}}}",
        json_factors.join(", ")
    );
    Ok(())
}

fn cmd_serve_server(args: &Args) -> Result<()> {
    let mut b = ServerSession::builder()
        .listen(args.get_or("listen", "127.0.0.1:7070"))
        .artifacts(args.get_or("artifacts", "artifacts"))
        .threads(parse_threads(args.get("threads"))?)
        .simd(parse_simd(args.get("simd"))?);
    if let Some(p) = args.get("config") {
        b = b.config_file(Path::new(p))?;
    }
    if let Some(w) = args.get("wire") {
        b = b.wire_precision(WirePrecision::parse(w)?);
    }
    if let Some(n) = args.get_parse("max-sessions")? {
        b = b.max_sessions(n);
    }
    if let Some(n) = args.get_parse("pending-cap")? {
        b = b.pending_cap(n);
    }
    if let Some(n) = args.get_parse("session-window")? {
        b = b.session_window(n);
    }
    if let Some(n) = args.get_parse("tail-slots")? {
        b = b.tail_slots(n);
    }
    if let Some(n) = args.get_parse("batch-frames")? {
        b = b.batch(n, std::time::Duration::ZERO);
    }
    if let Some(secs) = args.get_parse::<u64>("drain-timeout")? {
        b = b.drain_timeout(std::time::Duration::from_secs(secs));
    }
    let stats_every: u64 = args.get_parse("stats-every")?.unwrap_or(30);
    b = b.stats_interval(std::time::Duration::from_secs(stats_every));
    if let Some(addr) = args.get("metrics-addr") {
        b = b.metrics_addr(addr);
    }
    let server = b.build()?;
    println!(
        "edge-server listening on {} (tail-role engine, concurrent sessions)",
        server.addr()
    );
    if let Some(addr) = server.metrics_addr() {
        println!("metrics: http://{addr}/metrics (Prometheus text 0.0.4)");
    }
    println!("Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_server_stats(args: &Args) -> Result<()> {
    let addr = args.get_or("connect", "127.0.0.1:7070");
    if args.has("prom") {
        print!("{}", splitpoint::telemetry::scrape(addr)?);
    } else {
        print!("{}", fetch_stats(addr)?);
    }
    Ok(())
}

fn cmd_chaos_proxy(args: &Args) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:7474");
    let upstream = args.get_or("connect", "127.0.0.1:7070");
    let profile = FaultProfile::parse(args.get_or("fault", "clean"))?;
    let seed: u64 = args.get_parse("fault-seed")?.unwrap_or(1);
    let proxy = ChaosProxy::spawn(listen, upstream, profile, seed)?;
    println!(
        "chaos-proxy relaying {} -> {} (profile {}, seed {seed})",
        proxy.addr(),
        upstream,
        args.get_or("fault", "clean"),
    );
    println!("Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_serve_edge(args: &Args) -> Result<()> {
    let addr = args.get_or("connect", "127.0.0.1:7070").to_string();
    let mut session = build_session(args, Some(10), Some(addr.as_str()))?;
    print_session_banner(&session);
    let mut dets = DetsOut::from_args(args);
    let report = session.run_with(|f: SessionFrame| {
        dets.push(&f);
        println!(
            "frame {} [s{} {}]: {} dets | edge {:.1} ms + rtt {:.1} ms (server {:.1} ms) = {:.1} ms, uplink {:.2} MB",
            f.seq,
            f.sensor_id,
            f.split_label,
            f.output.detections.len(),
            f.output.edge_time.as_millis_f64(),
            f.output.round_trip.as_millis_f64(),
            f.output.server_time.as_millis_f64(),
            f.output.inference_time.as_millis_f64(),
            f.output.uplink_bytes as f64 / 1e6,
        );
    })?;
    dets.finish()?;
    print_session_tail(&report, args.has("report"));
    Ok(())
}

fn cmd_compare_dets(args: &Args) -> Result<()> {
    let path_a = args.get("a").ok_or_else(|| anyhow::anyhow!("--a <file> is required"))?;
    let path_b = args.get("b").ok_or_else(|| anyhow::anyhow!("--b <file> is required"))?;
    let read = |p: &str| -> Result<Vec<compare::FrameDets>> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("reading --dets-out file {p}: {e}"))?;
        compare::parse_dets(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e:#}"))
    };
    let defaults = Tolerance::default();
    let tol = Tolerance {
        iou_min: args.get_parse("iou-min")?.unwrap_or(defaults.iou_min),
        score_eps: args.get_parse("score-eps")?.unwrap_or(defaults.score_eps),
        center_eps: args.get_parse("center-eps")?.unwrap_or(defaults.center_eps),
        drop_below: args.get_parse("drop-below")?.unwrap_or(defaults.drop_below),
    };
    let report = compare::compare_runs(&read(path_a)?, &read(path_b)?, &tol)?;
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().pretty())
            .map_err(|e| anyhow::anyhow!("writing --out {out}: {e}"))?;
    }
    println!("{}", report.summary());
    for line in &report.mismatched_frames {
        println!("  {line}");
    }
    if !report.pass() {
        bail!("detections differ beyond tolerance ({path_a} vs {path_b})");
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = cli.parse(&argv)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("explain-splits") => cmd_explain(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve-server") => cmd_serve_server(&args),
        Some("server-stats") => cmd_server_stats(&args),
        Some("serve-edge") => cmd_serve_edge(&args),
        Some("chaos-proxy") => cmd_chaos_proxy(&args),
        Some("compare-dets") => cmd_compare_dets(&args),
        _ => {
            println!("{}", cli.help(None));
            Ok(())
        }
    }
}
