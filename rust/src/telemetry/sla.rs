//! Declarative SLA objectives over the live metrics.
//!
//! The paper's three axes — inference latency, uplink bytes, edge
//! compute (the power proxy) — become three declarative objectives:
//!
//! * `latency-bound=<secs>` — mean per-frame inference time (floored by
//!   the measured link RTT: a frame can never beat the wire);
//! * `bytes-bound=<bytes>` — mean per-frame uplink bytes;
//! * `edge-power-bound=<secs>` — mean per-frame edge compute time.
//!
//! An [`SlaEvaluator`] accumulates per-frame samples
//! ([`SlaEvaluator::observe_frame`]) and is evaluated periodically
//! (segment boundaries in a session) against the window plus the link's
//! [`LinkHealth`]. Breach state is exported as metrics
//! (`sp_sla_value` / `sp_sla_threshold` / `sp_sla_breached` /
//! `sp_sla_breaches_total`, labeled `objective=<name>`) and surfaced to
//! split policies through `PolicyContext::sla`, so a policy sees
//! *objective pressure*, not just raw link samples.

use anyhow::{bail, Result};

use super::{Counter, Gauge, Registry};
use crate::coordinator::fault::LinkHealth;

use std::sync::Arc;

/// Which axis an objective bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlaKind {
    /// Mean per-frame inference latency, seconds.
    LatencyBound,
    /// Mean per-frame uplink, bytes.
    BytesBound,
    /// Mean per-frame edge compute, seconds (the paper's power proxy).
    EdgePowerBound,
}

impl SlaKind {
    pub const ALL: [SlaKind; 3] = [
        SlaKind::LatencyBound,
        SlaKind::BytesBound,
        SlaKind::EdgePowerBound,
    ];

    /// Stable objective name (the `objective` label value).
    pub fn name(self) -> &'static str {
        match self {
            SlaKind::LatencyBound => "latency-bound",
            SlaKind::BytesBound => "bytes-bound",
            SlaKind::EdgePowerBound => "edge-power-bound",
        }
    }

    pub fn parse(s: &str) -> Result<SlaKind> {
        match s {
            "latency-bound" => Ok(SlaKind::LatencyBound),
            "bytes-bound" => Ok(SlaKind::BytesBound),
            "edge-power-bound" => Ok(SlaKind::EdgePowerBound),
            other => bail!(
                "unknown SLA objective '{other}' \
                 (want latency-bound, bytes-bound, or edge-power-bound)"
            ),
        }
    }
}

/// One declared objective: a kind and its threshold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaSpec {
    pub kind: SlaKind,
    pub threshold: f64,
}

impl SlaSpec {
    /// Parse `kind=threshold`, e.g. `latency-bound=0.25`.
    pub fn parse(s: &str) -> Result<SlaSpec> {
        let (kind, value) = s
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("SLA spec '{s}' is not 'objective=threshold'"))?;
        let threshold: f64 = value
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("SLA threshold '{value}' is not a number"))?;
        if !threshold.is_finite() || threshold <= 0.0 {
            bail!("SLA threshold must be finite and positive, got {threshold}");
        }
        Ok(SlaSpec {
            kind: SlaKind::parse(kind.trim())?,
            threshold,
        })
    }
}

/// Parse a comma-separated objective list (the `--sla` flag):
/// `latency-bound=0.25,bytes-bound=500000`.
pub fn parse_specs(csv: &str) -> Result<Vec<SlaSpec>> {
    csv.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(SlaSpec::parse)
        .collect()
}

/// One objective's state at the last evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaStatus {
    pub kind: SlaKind,
    /// Windowed value at the last evaluation.
    pub value: f64,
    pub threshold: f64,
    pub breached: bool,
}

/// Every declared objective's last-evaluated state; what policies see in
/// `PolicyContext::sla` and what `run --report` prints.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SlaVerdict {
    pub statuses: Vec<SlaStatus>,
}

impl SlaVerdict {
    pub fn is_empty(&self) -> bool {
        self.statuses.is_empty()
    }

    /// True when any declared objective is currently breached.
    pub fn any_breached(&self) -> bool {
        self.statuses.iter().any(|s| s.breached)
    }

    /// One deterministic summary line, e.g.
    /// `sla: latency-bound ok (0.0123 vs 0.2500) | bytes-bound BREACHED
    /// (712340 vs 500000)`.
    pub fn line(&self) -> String {
        if self.statuses.is_empty() {
            return "sla: no objectives declared".to_string();
        }
        let parts: Vec<String> = self
            .statuses
            .iter()
            .map(|s| {
                let state = if s.breached { "BREACHED" } else { "ok" };
                match s.kind {
                    SlaKind::BytesBound => format!(
                        "{} {state} ({:.0} vs {:.0})",
                        s.kind.name(),
                        s.value,
                        s.threshold
                    ),
                    _ => format!(
                        "{} {state} ({:.4} vs {:.4})",
                        s.kind.name(),
                        s.value,
                        s.threshold
                    ),
                }
            })
            .collect();
        format!("sla: {}", parts.join(" | "))
    }
}

/// Per-objective registry exports.
struct SlaExport {
    value: Arc<Gauge>,
    breached: Arc<Gauge>,
    breaches_total: Arc<Counter>,
}

/// Windowed evaluator for a set of declared objectives.
///
/// `observe_frame` accumulates one frame's samples (relaxed cost: plain
/// field adds on the session thread); `evaluate` folds the window plus
/// the current [`LinkHealth`] into an [`SlaVerdict`], updates the
/// exported metrics, and resets the window. With an empty window the
/// previous verdict is retained (no frames → no new evidence).
pub struct SlaEvaluator {
    specs: Vec<SlaSpec>,
    exports: Vec<SlaExport>,
    frames: u64,
    inference_sum: f64,
    uplink_sum: f64,
    edge_sum: f64,
    verdict: SlaVerdict,
}

impl std::fmt::Debug for SlaEvaluator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SlaEvaluator")
            .field("specs", &self.specs)
            .field("frames", &self.frames)
            .field("verdict", &self.verdict)
            .finish()
    }
}

impl SlaEvaluator {
    /// Declare `specs` and register their exports in `registry`.
    pub fn new(specs: Vec<SlaSpec>, registry: &Registry) -> SlaEvaluator {
        let exports = specs
            .iter()
            .map(|spec| {
                let labels = [("objective", spec.kind.name())];
                let threshold = registry.gauge(
                    "sp_sla_threshold",
                    "Declared SLA threshold per objective",
                    &labels,
                );
                threshold.set(spec.threshold);
                SlaExport {
                    value: registry.gauge(
                        "sp_sla_value",
                        "Last evaluated windowed value per SLA objective",
                        &labels,
                    ),
                    breached: registry.gauge(
                        "sp_sla_breached",
                        "1 when the SLA objective is currently breached",
                        &labels,
                    ),
                    breaches_total: registry.counter(
                        "sp_sla_breaches_total",
                        "Evaluations that found the SLA objective breached",
                        &labels,
                    ),
                }
            })
            .collect();
        SlaEvaluator {
            specs,
            exports,
            frames: 0,
            inference_sum: 0.0,
            uplink_sum: 0.0,
            edge_sum: 0.0,
            verdict: SlaVerdict::default(),
        }
    }

    pub fn specs(&self) -> &[SlaSpec] {
        &self.specs
    }

    /// Accumulate one delivered frame into the current window.
    pub fn observe_frame(&mut self, inference_secs: f64, uplink_bytes: u64, edge_secs: f64) {
        self.frames += 1;
        self.inference_sum += inference_secs;
        self.uplink_sum += uplink_bytes as f64;
        self.edge_sum += edge_secs;
    }

    /// Fold the window + link health into a fresh verdict, update the
    /// exported metrics, and reset the window.
    pub fn evaluate(&mut self, health: &LinkHealth) -> SlaVerdict {
        if self.frames == 0 && self.verdict.statuses.len() == self.specs.len() {
            return self.verdict.clone();
        }
        let n = self.frames.max(1) as f64;
        let rtt = health.rtt.map(|t| t.as_secs_f64()).unwrap_or(0.0);
        let statuses: Vec<SlaStatus> = self
            .specs
            .iter()
            .map(|spec| {
                let value = match spec.kind {
                    // a frame can never beat the measured wire RTT, so an
                    // inflated link breaches the latency bound even while
                    // the compute window looks healthy
                    SlaKind::LatencyBound => (self.inference_sum / n).max(rtt),
                    SlaKind::BytesBound => self.uplink_sum / n,
                    SlaKind::EdgePowerBound => self.edge_sum / n,
                };
                SlaStatus {
                    kind: spec.kind,
                    value,
                    threshold: spec.threshold,
                    breached: value > spec.threshold,
                }
            })
            .collect();
        for (status, export) in statuses.iter().zip(&self.exports) {
            export.value.set(status.value);
            export.breached.set(if status.breached { 1.0 } else { 0.0 });
            if status.breached {
                export.breaches_total.inc();
            }
        }
        self.frames = 0;
        self.inference_sum = 0.0;
        self.uplink_sum = 0.0;
        self.edge_sum = 0.0;
        self.verdict = SlaVerdict { statuses };
        self.verdict.clone()
    }

    /// The last evaluation's verdict.
    pub fn verdict(&self) -> &SlaVerdict {
        &self.verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SimTime;

    #[test]
    fn parse_specs_roundtrip() {
        let specs = parse_specs("latency-bound=0.25, bytes-bound=500000").expect("parse");
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].kind, SlaKind::LatencyBound);
        assert_eq!(specs[0].threshold, 0.25);
        assert_eq!(specs[1].kind, SlaKind::BytesBound);
        assert!(parse_specs("latency-bound=-1").is_err());
        assert!(parse_specs("latency-bound=abc").is_err());
        assert!(parse_specs("warp-bound=1").is_err());
    }

    #[test]
    fn evaluate_flags_breaches_and_resets_window() {
        let reg = Registry::new();
        let specs = parse_specs("latency-bound=0.1,bytes-bound=1000").expect("parse");
        let mut eval = SlaEvaluator::new(specs, &reg);
        eval.observe_frame(0.05, 500, 0.01);
        eval.observe_frame(0.07, 700, 0.01);
        let v = eval.evaluate(&LinkHealth::default());
        assert!(!v.any_breached());
        assert_eq!(v.statuses[0].value, 0.06);
        assert_eq!(v.statuses[1].value, 600.0);

        // breach the bytes bound in the next window
        eval.observe_frame(0.05, 5000, 0.01);
        let v = eval.evaluate(&LinkHealth::default());
        assert!(v.any_breached());
        assert!(!v.statuses[0].breached);
        assert!(v.statuses[1].breached);
        assert!(v.line().contains("bytes-bound BREACHED"));
        assert!(reg.render().contains("sp_sla_breaches_total{objective=\"bytes-bound\"} 1"));
    }

    #[test]
    fn rtt_floors_the_latency_value() {
        let reg = Registry::new();
        let mut eval =
            SlaEvaluator::new(parse_specs("latency-bound=0.1").expect("parse"), &reg);
        eval.observe_frame(0.01, 0, 0.0);
        let health = LinkHealth {
            rtt: Some(SimTime::from_secs_f64(0.5)),
            ..LinkHealth::default()
        };
        let v = eval.evaluate(&health);
        assert!(v.statuses[0].breached, "inflated RTT must breach latency bound");
        assert_eq!(v.statuses[0].value, 0.5);
    }

    #[test]
    fn empty_window_retains_last_verdict() {
        let reg = Registry::new();
        let mut eval =
            SlaEvaluator::new(parse_specs("edge-power-bound=0.01").expect("parse"), &reg);
        eval.observe_frame(0.0, 0, 0.5);
        let first = eval.evaluate(&LinkHealth::default());
        assert!(first.any_breached());
        let second = eval.evaluate(&LinkHealth::default());
        assert_eq!(first, second);
    }
}
