//! Process-wide telemetry plane: stable-named metrics, a Prometheus
//! text-format exporter, and declarative SLA objectives ([`sla`]).
//!
//! The paper's whole contribution is a latency/bytes/edge-power
//! trade-off; operating a split system (rather than benchmarking it)
//! needs that trade-off observable continuously. This module is the
//! registry every layer reports through:
//!
//! * [`Counter`] / [`Gauge`] are single relaxed `AtomicU64` cells;
//! * [`Histogram`] is a fixed-bucket distribution (the shape of
//!   [`crate::metrics::OccupancyHist`], generalized to f64 bounds);
//! * [`Registry`] interns `(name, labels)` once at registration and
//!   hands back an `Arc` handle — the hot path is a single relaxed
//!   atomic op, zero alloc, zero lock, so instrumented code stays
//!   bitwise-identical in output and unmeasurable in cost;
//! * [`Registry::render`] emits Prometheus text exposition format 0.0.4,
//!   served over HTTP by [`MetricsServer`] (`serve-server
//!   --metrics-addr`) and scraped by [`scrape`] (`server-stats --prom`).
//!
//! Metric names are a **compatibility surface**: dashboards and the CI
//! soak gate grep for them. The full stable-name table lives in
//! `docs/METRICS.md`; rename a metric only with a deprecation note
//! there.

use std::collections::BTreeMap;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

pub mod sla;

// ------------------------------------------------------------ instruments

/// Monotonic counter: one relaxed `AtomicU64`.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Raise the counter to `total` if it is below it (monotonic merge,
    /// via `fetch_max`). For syncing an externally-accumulated cumulative
    /// total (e.g. [`LinkHealth`](crate::coordinator::fault::LinkHealth)
    /// counters) into the registry without double-counting.
    pub fn merge_total(&self, total: u64) {
        self.0.fetch_max(total, Ordering::Relaxed);
    }
}

/// Last-value gauge: an f64 stored as its bit pattern in an `AtomicU64`.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: per-bucket relaxed counters plus a count and a
/// fixed-point sum (micro-units), so rendering is deterministic — the
/// same observations always produce the same text.
///
/// Bucket `i` counts observations `v <= bounds[i]`; one extra implicit
/// `+Inf` bucket catches the rest (rendered cumulatively, per the
/// Prometheus histogram convention).
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// sum of observations in micro-units (`round(v * 1e6)`), kept in
    /// fixed point so concurrent observers never lose precision races
    sum_micros: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        let mut buckets = Vec::with_capacity(bounds.len() + 1);
        for _ in 0..=bounds.len() {
            buckets.push(AtomicU64::new(0));
        }
        Histogram {
            bounds: bounds.to_vec(),
            buckets,
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    /// Record one observation: three relaxed atomic adds, no lock.
    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let micros = (v.max(0.0) * 1e6).round() as u64;
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Default latency bucket bounds (seconds), 0.5 ms – 10 s.
pub fn latency_buckets() -> Vec<f64> {
    vec![
        0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    ]
}

/// Default queue-depth bucket bounds — the power-of-two shape of
/// [`crate::metrics::OccupancyHist`].
pub fn depth_buckets() -> Vec<f64> {
    vec![0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0]
}

// ------------------------------------------------------------ registry

/// What a metric family is, for the `# TYPE` line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

#[derive(Clone)]
enum Handle {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Family {
    help: String,
    kind: MetricKind,
    /// label-string → instrument, sorted so rendering is deterministic
    metrics: BTreeMap<String, Handle>,
}

/// A collector runs just before rendering, pulling lazy values (live
/// gauges, externally-accumulated totals) into registered instruments.
type Collector = Arc<dyn Fn() + Send + Sync>;

/// Registry of stable-named metrics. `(name, sorted labels)` is interned
/// once at registration; repeated registration of the same pair returns
/// the same handle, so call sites never need to coordinate.
///
/// One process-wide instance lives behind [`global`]; the concurrent
/// split server keeps its own per-instance registry (so two servers in
/// one test process cannot mix counters).
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
    collectors: Mutex<Vec<Collector>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let families = self.families.lock().unwrap();
        f.debug_struct("Registry")
            .field("families", &families.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// Render one label set as `key="value",…` (no braces), escaping the
/// characters the exposition format requires.
fn label_string(labels: &[(&str, &str)]) -> String {
    let mut pairs: Vec<(&str, &str)> = labels.to_vec();
    pairs.sort_unstable();
    let mut out = String::new();
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            families: Mutex::new(BTreeMap::new()),
            collectors: Mutex::new(Vec::new()),
        }
    }

    fn intern(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Handle,
    ) -> Handle {
        let mut families = self.families.lock().unwrap();
        let family = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            metrics: BTreeMap::new(),
        });
        if family.kind != kind {
            // kind clash: hand back a detached instrument instead of
            // panicking — the misnamed metric simply never renders
            return make();
        }
        family
            .metrics
            .entry(label_string(labels))
            .or_insert_with(make)
            .clone()
    }

    /// Get-or-register a counter. Same `(name, labels)` → same handle.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let h = self.intern(name, help, MetricKind::Counter, labels, || {
            Handle::Counter(Arc::new(Counter::default()))
        });
        match h {
            Handle::Counter(c) => c,
            _ => Arc::new(Counter::default()),
        }
    }

    /// Get-or-register a gauge. Same `(name, labels)` → same handle.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let h = self.intern(name, help, MetricKind::Gauge, labels, || {
            Handle::Gauge(Arc::new(Gauge::default()))
        });
        match h {
            Handle::Gauge(g) => g,
            _ => Arc::new(Gauge::default()),
        }
    }

    /// Get-or-register a histogram with explicit bucket bounds. Same
    /// `(name, labels)` → same handle (the first registration's bounds
    /// win).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Arc<Histogram> {
        let h = self.intern(name, help, MetricKind::Histogram, labels, || {
            Handle::Histogram(Arc::new(Histogram::new(bounds)))
        });
        match h {
            Handle::Histogram(hist) => hist,
            _ => Arc::new(Histogram::new(bounds)),
        }
    }

    /// Drop one `(name, labels)` instrument (e.g. a finished session's
    /// per-session counters). Handles already held keep working; the
    /// metric just stops rendering.
    pub fn unregister(&self, name: &str, labels: &[(&str, &str)]) {
        let mut families = self.families.lock().unwrap();
        if let Some(family) = families.get_mut(name) {
            family.metrics.remove(&label_string(labels));
            if family.metrics.is_empty() {
                families.remove(name);
            }
        }
    }

    /// Register a pre-render hook (see [`Collector`]). Collectors run
    /// outside the registry lock, so they may register and update
    /// instruments freely.
    pub fn register_collector(&self, f: impl Fn() + Send + Sync + 'static) {
        self.collectors.lock().unwrap().push(Arc::new(f));
    }

    /// Render the whole registry in Prometheus text exposition format
    /// 0.0.4. Deterministic: families and label sets render sorted, and
    /// every value has a canonical formatting (see the golden test).
    pub fn render(&self) -> String {
        // run collectors without holding the families lock — they update
        // (and may register) instruments
        let collectors: Vec<Collector> = self.collectors.lock().unwrap().clone();
        for c in &collectors {
            c();
        }
        let families = self.families.lock().unwrap();
        let mut out = String::new();
        use std::fmt::Write as _;
        for (name, family) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", family.help);
            let _ = writeln!(out, "# TYPE {name} {}", family.kind.as_str());
            for (labels, handle) in &family.metrics {
                match handle {
                    Handle::Counter(c) => {
                        let _ = writeln!(out, "{name}{} {}", braced(labels), c.get());
                    }
                    Handle::Gauge(g) => {
                        let _ = writeln!(out, "{name}{} {}", braced(labels), g.get());
                    }
                    Handle::Histogram(h) => {
                        let mut cum = 0u64;
                        for (i, bound) in h.bounds.iter().enumerate() {
                            cum += h.buckets[i].load(Ordering::Relaxed);
                            let le = join_labels(labels, &format!("le=\"{bound}\""));
                            let _ = writeln!(out, "{name}_bucket{{{le}}} {cum}");
                        }
                        cum += h.buckets[h.bounds.len()].load(Ordering::Relaxed);
                        let le = join_labels(labels, "le=\"+Inf\"");
                        let _ = writeln!(out, "{name}_bucket{{{le}}} {cum}");
                        let _ = writeln!(out, "{name}_sum{} {}", braced(labels), h.sum());
                        let _ = writeln!(out, "{name}_count{} {}", braced(labels), h.count());
                    }
                }
            }
        }
        out
    }
}

/// `a="b"` → `{a="b"}`; empty label string → nothing.
fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

/// Join a label string with one extra pair (the histogram `le` label).
fn join_labels(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        extra.to_string()
    } else {
        format!("{labels},{extra}")
    }
}

/// The process-wide registry: client/session/pipeline/runtime metrics
/// report here, and [`SessionReport::prometheus`]
/// (crate::coordinator::session::SessionReport) renders it for offline
/// runs.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ------------------------------------------------------------ HTTP export

/// Tiny blocking `/metrics` endpoint: one listener thread, one request
/// per connection, Prometheus text format. This is deliberately not a
/// web server — it answers every request with the rendered registry and
/// closes, which is exactly what a scraper needs.
#[derive(Debug)]
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` and serve `registry` until [`MetricsServer::shutdown`]
    /// (or drop).
    pub fn spawn(addr: &str, registry: Arc<Registry>) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let thread = std::thread::Builder::new()
            .name("sp-metrics-http".into())
            .spawn(move || {
                while !thread_stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                            // best-effort read of the request line; any
                            // request gets the same answer
                            let mut buf = [0u8; 1024];
                            let _ = stream.read(&mut buf);
                            let body = registry.render();
                            let resp = format!(
                                "HTTP/1.1 200 OK\r\n\
                                 Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
                                 Content-Length: {}\r\n\
                                 Connection: close\r\n\r\n{body}",
                                body.len(),
                            );
                            let _ = stream.write_all(resp.as_bytes());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(MetricsServer {
            addr: local,
            stop,
            thread: Some(thread),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and join it. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Fetch a [`MetricsServer`]'s rendered registry over HTTP (the client
/// half of `server-stats --prom`).
pub fn scrape<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<String> {
    let mut stream =
        TcpStream::connect(&addr).with_context(|| format!("connecting metrics endpoint {addr:?}"))?;
    let req = format!("GET /metrics HTTP/1.1\r\nHost: {addr:?}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .context("malformed HTTP response from metrics endpoint")?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        bail!("metrics endpoint answered '{status}'");
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("t_total", "help", &[("k", "v")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same (name, labels) → same cell
        let c2 = reg.counter("t_total", "help", &[("k", "v")]);
        c2.inc();
        assert_eq!(c.get(), 6);
        // different labels → different cell
        let c3 = reg.counter("t_total", "help", &[("k", "w")]);
        assert_eq!(c3.get(), 0);

        let g = reg.gauge("t_gauge", "help", &[]);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn merge_total_is_monotonic() {
        let c = Counter::default();
        c.merge_total(10);
        c.merge_total(7); // stale snapshot: no effect
        assert_eq!(c.get(), 10);
        c.merge_total(12);
        assert_eq!(c.get(), 12);
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_render() {
        let reg = Registry::new();
        let h = reg.histogram("t_lat", "help", &[], &[0.01, 0.1, 1.0]);
        h.observe(0.005);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = reg.render();
        assert!(text.contains("t_lat_bucket{le=\"0.01\"} 1"));
        assert!(text.contains("t_lat_bucket{le=\"0.1\"} 2"));
        assert!(text.contains("t_lat_bucket{le=\"1\"} 3"));
        assert!(text.contains("t_lat_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("t_lat_count 4"));
        assert!(text.contains("t_lat_sum 5.555"));
    }

    #[test]
    fn kind_clash_returns_detached_handle() {
        let reg = Registry::new();
        let c = reg.counter("t_thing", "help", &[]);
        c.inc();
        // a gauge under the same name must not corrupt the counter
        let g = reg.gauge("t_thing", "help", &[]);
        g.set(9.0);
        assert_eq!(c.get(), 1);
        assert!(reg.render().contains("t_thing 1"));
    }

    #[test]
    fn unregister_removes_one_label_set() {
        let reg = Registry::new();
        reg.counter("t_total", "help", &[("session", "1")]).inc();
        reg.counter("t_total", "help", &[("session", "2")]).inc();
        reg.unregister("t_total", &[("session", "1")]);
        let text = reg.render();
        assert!(!text.contains("session=\"1\""));
        assert!(text.contains("session=\"2\""));
    }

    #[test]
    fn collectors_run_before_render() {
        let reg = Arc::new(Registry::new());
        let g = reg.gauge("t_live", "help", &[]);
        let src = Arc::new(AtomicU64::new(0));
        let src2 = src.clone();
        reg.register_collector(move || g.set(src2.load(Ordering::Relaxed) as f64));
        src.store(7, Ordering::Relaxed);
        assert!(reg.render().contains("t_live 7"));
    }

    #[test]
    fn labels_render_sorted_and_escaped() {
        let reg = Registry::new();
        reg.counter("t_total", "help", &[("z", "a\"b\\c"), ("a", "x")])
            .inc();
        let text = reg.render();
        assert!(text.contains("t_total{a=\"x\",z=\"a\\\"b\\\\c\"} 1"));
    }

    #[test]
    fn http_endpoint_serves_render() {
        let reg = Arc::new(Registry::new());
        reg.counter("t_http_total", "help", &[]).add(3);
        let mut srv = MetricsServer::spawn("127.0.0.1:0", reg).expect("spawn metrics server");
        let body = scrape(srv.addr()).expect("scrape");
        assert!(body.contains("# TYPE t_http_total counter"));
        assert!(body.contains("t_http_total 3"));
        srv.shutdown();
    }
}
