//! Timing metrics: virtual clock, per-phase stopwatches, summary stats and
//! report printers. Every paper figure is a view over these records.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Duration;

/// Simulated-time durations are tracked in nanoseconds on a virtual clock
/// so device slowdown factors and link transfer times compose exactly and
/// deterministically (DESIGN.md §3: device profiles).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct SimTime {
    pub nanos: u128,
}

impl SimTime {
    pub const ZERO: SimTime = SimTime { nanos: 0 };

    pub fn from_duration(d: Duration) -> SimTime {
        SimTime { nanos: d.as_nanos() }
    }

    pub fn from_secs_f64(s: f64) -> SimTime {
        SimTime {
            nanos: (s.max(0.0) * 1e9) as u128,
        }
    }

    pub fn as_secs_f64(self) -> f64 {
        self.nanos as f64 / 1e9
    }

    pub fn as_millis_f64(self) -> f64 {
        self.nanos as f64 / 1e6
    }

    pub fn scaled(self, factor: f64) -> SimTime {
        SimTime {
            nanos: (self.nanos as f64 * factor) as u128,
        }
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime {
            nanos: self.nanos.saturating_sub(other.nanos),
        }
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime {
            nanos: self.nanos + rhs.nanos,
        }
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.nanos += rhs.nanos;
    }
}

impl std::iter::Sum for SimTime {
    fn sum<I: Iterator<Item = SimTime>>(iter: I) -> SimTime {
        iter.fold(SimTime::ZERO, |a, b| a + b)
    }
}

/// Summary statistics over a series of samples (milliseconds).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats::default()
    }

    pub fn push(&mut self, ms: f64) {
        self.samples.push(ms);
    }

    pub fn push_time(&mut self, t: SimTime) {
        self.push(t.as_millis_f64());
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q / 100.0) * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Exact histogram over small non-negative integer observations — queue
/// depths, in-flight frame counts. The pipelined engine records one sample
/// per dequeue, so `fraction_at_least(1)` reads directly as "how often the
/// next frame was already waiting", i.e. how saturated a stage ran.
#[derive(Debug, Clone, Default)]
pub struct OccupancyHist {
    /// counts[v] = number of samples observing exactly depth v
    counts: Vec<u64>,
    total: u64,
}

impl OccupancyHist {
    pub fn new() -> OccupancyHist {
        OccupancyHist::default()
    }

    pub fn record(&mut self, value: usize) {
        if self.counts.len() <= value {
            self.counts.resize(value + 1, 0);
        }
        self.counts[value] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Per-depth sample counts (index = observed depth).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let weighted: u64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(v, &n)| v as u64 * n)
            .sum();
        weighted as f64 / self.total as f64
    }

    /// Largest depth ever observed.
    pub fn max(&self) -> usize {
        self.counts
            .iter()
            .rposition(|&n| n > 0)
            .unwrap_or(0)
    }

    /// Fraction of samples with depth >= `v` (in [0, 1]).
    pub fn fraction_at_least(&self, v: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let at_least: u64 = self.counts.iter().skip(v).sum();
        at_least as f64 / self.total as f64
    }
}

/// Named series collector: one `Stats` per label, insertion-stable output.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    series: BTreeMap<String, Stats>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, label: &str, ms: f64) {
        self.series.entry(label.to_string()).or_default().push(ms);
    }

    pub fn record_time(&mut self, label: &str, t: SimTime) {
        self.record(label, t.as_millis_f64());
    }

    pub fn get(&self, label: &str) -> Option<&Stats> {
        self.series.get(label)
    }

    pub fn labels(&self) -> Vec<&str> {
        self.series.keys().map(String::as_str).collect()
    }

    pub fn merge(&mut self, other: &Recorder) {
        for (k, s) in &other.series {
            let e = self.series.entry(k.clone()).or_default();
            for &x in &s.samples {
                e.push(x);
            }
        }
    }

    /// Markdown table of all series.
    pub fn to_markdown(&self, title: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {title}\n");
        let _ = writeln!(
            out,
            "| series | n | mean ms | std | p50 | p95 | p99 | min | max |"
        );
        let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|");
        for (k, s) in &self.series {
            let _ = writeln!(
                out,
                "| {k} | {} | {:.2} | {:.2} | {:.2} | {:.2} | {:.2} | {:.2} | {:.2} |",
                s.count(),
                s.mean(),
                s.std(),
                s.p50(),
                s.p95(),
                s.p99(),
                s.min(),
                s.max()
            );
        }
        out
    }

    /// CSV (label, n, mean, p50, p95, p99).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,n,mean_ms,std_ms,p50_ms,p95_ms,p99_ms\n");
        for (k, s) in &self.series {
            let _ = writeln!(
                out,
                "{k},{},{:.4},{:.4},{:.4},{:.4},{:.4}",
                s.count(),
                s.mean(),
                s.std(),
                s.p50(),
                s.p95(),
                s.p99()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_arithmetic() {
        let a = SimTime::from_secs_f64(0.5);
        let b = SimTime::from_secs_f64(0.25);
        assert!(((a + b).as_secs_f64() - 0.75).abs() < 1e-12);
        assert!((a.scaled(4.0).as_secs_f64() - 2.0).abs() < 1e-9);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
        assert!((a.as_millis_f64() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.p50() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Stats::new();
        s.push(0.0);
        s.push(10.0);
        assert!((s.percentile(50.0) - 5.0).abs() < 1e-12);
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn recorder_merges_and_reports() {
        let mut a = Recorder::new();
        a.record("x", 1.0);
        let mut b = Recorder::new();
        b.record("x", 3.0);
        b.record("y", 2.0);
        a.merge(&b);
        assert_eq!(a.get("x").unwrap().count(), 2);
        let md = a.to_markdown("t");
        assert!(md.contains("| x | 2 |"));
        assert!(a.to_csv().contains("y,1"));
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
    }

    #[test]
    fn occupancy_hist_counts_and_moments() {
        let mut h = OccupancyHist::new();
        assert!(h.is_empty());
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.fraction_at_least(1), 0.0);
        for v in [0, 0, 1, 2, 2, 2] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.counts(), &[2, 1, 3]);
        assert!((h.mean() - 7.0 / 6.0).abs() < 1e-12);
        assert_eq!(h.max(), 2);
        assert!((h.fraction_at_least(1) - 4.0 / 6.0).abs() < 1e-12);
        assert!((h.fraction_at_least(3)).abs() < 1e-12);
    }
}
