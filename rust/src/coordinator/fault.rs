//! Hostile-network fault layer: seeded link-fault injection and the
//! client retry/backoff policy.
//!
//! Split computing lives or dies on the edge↔server link, yet every test
//! up to PR 7 ran over a cooperative loopback. This module makes the link
//! hostile *deterministically*: every delay, stall and cut is replayable
//! from a single seed, so a failing CI profile reproduces locally.
//!
//! Three injection surfaces share one schedule vocabulary
//! ([`FaultProfile`] + [`Pacer`]):
//!
//! * [`ChaosProxy`] — a raw TCP relay between real `serve-edge` /
//!   `serve-server` processes. The only surface that can inject *hard
//!   disconnects*; the resilient client reconnects through it and resumes
//!   its session.
//! * [`FaultTransport`] — wraps any [`Transport`] in-process and injects
//!   delay-class faults (jitter, bandwidth steps, stalls) around frame
//!   delivery. Disconnects are stripped: an in-process link cannot drop.
//! * [`RetryPolicy`] / [`Backoff`] — the client-side answer: bounded
//!   exponential backoff with seeded jitter, shared by the `Busy` retry
//!   path and the reconnect loop in `coordinator::remote`.
//!
//! Everything here is **off by default**: a session without `--fault`
//! never constructs a pacer, and a client without `--resume` sends
//! byte-identical wire traffic to PR 7.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::pipeline::PipelineConfig;
use crate::coordinator::session::{FrameOutput, Transport};
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::util::rng::Rng;

// ------------------------------------------------------------ link health

/// Client-side link telemetry fed back into the policy plane
/// (`PolicyContext::health`) and the session report: how hard the
/// transport had to fight the link to deliver the stream.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkHealth {
    /// `Busy` rejections retried after backoff.
    pub retries: u64,
    /// Transparent reconnect + session-resume cycles.
    pub reconnects: u64,
    /// Total time spent sleeping in backoff (retry + reconnect).
    pub backoff_time: SimTime,
    /// Injected stall time, when a [`FaultTransport`] is in the path.
    pub stall_time: SimTime,
    /// Smoothed round-trip time from queue-free frames, if measured.
    pub rtt: Option<SimTime>,
}

impl LinkHealth {
    /// True when nothing degraded: no retries, reconnects or stalls.
    pub fn is_clean(&self) -> bool {
        self.retries == 0 && self.reconnects == 0 && self.stall_time == SimTime::ZERO
    }
}

// ------------------------------------------------------------ retry policy

/// Bounded exponential backoff with seeded jitter. `backoff(stream)`
/// forks one deterministic [`Backoff`] schedule per logical stream
/// (request id, reconnect loop), so retry timing is reproducible from
/// `(seed, stream)` while distinct streams still decorrelate.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Attempts after the first failure before giving up.
    pub max_retries: u32,
    /// First-retry delay; doubles each attempt.
    pub base: Duration,
    /// Hard ceiling on any single delay.
    pub cap: Duration,
    /// Jitter seed; same seed → same schedule.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 5,
            base: Duration::from_millis(20),
            cap: Duration::from_secs(2),
            seed: 0x5350_4652, // "SPFR", matching the wire magic
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries (the pre-PR 8 fatal behavior).
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            ..RetryPolicy::default()
        }
    }

    /// Start a backoff schedule for one logical stream.
    pub fn backoff(&self, stream: u64) -> Backoff {
        Backoff {
            attempt: 0,
            max: self.max_retries,
            base: self.base,
            cap: self.cap,
            rng: Rng::new(self.seed ^ stream.rotate_left(17)),
        }
    }
}

/// One in-progress retry schedule; see [`RetryPolicy::backoff`].
#[derive(Debug, Clone)]
pub struct Backoff {
    attempt: u32,
    max: u32,
    base: Duration,
    cap: Duration,
    rng: Rng,
}

impl Backoff {
    /// The next delay to sleep before retrying, or `None` once the
    /// attempt budget is exhausted. Delay `k` is jittered uniformly in
    /// `[0.5, 1.0) × min(cap, base · 2^k)`.
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.attempt >= self.max {
            return None;
        }
        let exp = self.base.as_secs_f64() * 2f64.powi(self.attempt.min(30) as i32);
        let full = exp.min(self.cap.as_secs_f64());
        let jittered = self.rng.uniform(0.5, 1.0) * full;
        self.attempt += 1;
        Some(Duration::from_secs_f64(jittered))
    }

    /// Attempts consumed so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Total attempts this schedule allows.
    pub fn max_retries(&self) -> u32 {
        self.max
    }
}

// ------------------------------------------------------------ profiles

/// Alternating bandwidth bands: the pacer throttles to `hi_bps` for
/// `step_bytes`, then `lo_bps` for the next `step_bytes`, and so on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthStep {
    pub hi_bps: f64,
    pub lo_bps: f64,
    pub step_bytes: u64,
}

/// Periodic short stalls: every `every_bytes` forwarded, pause the link
/// for `pause` before the next chunk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StallSpec {
    pub every_bytes: u64,
    pub pause: Duration,
}

/// Hard mid-stream disconnects. The first connection is cut after
/// `first_bytes`; each subsequent connection's budget doubles (capped),
/// so a resuming client is guaranteed to make progress even when a
/// single frame exceeds the early budgets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DisconnectSpec {
    pub first_bytes: u64,
}

/// A composable, seed-replayable link-fault schedule. Fields compose:
/// a profile may jitter *and* stall. [`FaultProfile::clean`] (the
/// default) injects nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultProfile {
    pub name: &'static str,
    /// Per-chunk uniform delay in `[0, jitter_max)`.
    pub jitter_max: Duration,
    pub bandwidth: Option<BandwidthStep>,
    pub stall: Option<StallSpec>,
    pub disconnect: Option<DisconnectSpec>,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile::clean()
    }
}

/// Profile names accepted by [`FaultProfile::parse`] / `--fault`.
pub const PROFILE_NAMES: [&str; 5] = ["clean", "jitter", "bandwidth-step", "stall", "disconnect"];

impl FaultProfile {
    /// No injection at all — the identity schedule.
    pub fn clean() -> FaultProfile {
        FaultProfile {
            name: "clean",
            jitter_max: Duration::ZERO,
            bandwidth: None,
            stall: None,
            disconnect: None,
        }
    }

    /// Small random per-chunk delays (radio-link delay variance).
    pub fn jitter() -> FaultProfile {
        FaultProfile {
            jitter_max: Duration::from_millis(2),
            name: "jitter",
            ..FaultProfile::clean()
        }
    }

    /// Bandwidth alternating between a fast and a slow band every 64 KB —
    /// the regime shift the adaptive policy is supposed to track.
    pub fn bandwidth_step() -> FaultProfile {
        FaultProfile {
            name: "bandwidth-step",
            bandwidth: Some(BandwidthStep {
                hi_bps: 64e6,
                lo_bps: 8e6,
                step_bytes: 64 * 1024,
            }),
            ..FaultProfile::clean()
        }
    }

    /// A 100 ms link freeze every 128 KB (handover / contention bursts).
    pub fn stall() -> FaultProfile {
        FaultProfile {
            name: "stall",
            stall: Some(StallSpec {
                every_bytes: 128 * 1024,
                pause: Duration::from_millis(100),
            }),
            ..FaultProfile::clean()
        }
    }

    /// Hard mid-stream connection cuts with an escalating byte budget.
    pub fn disconnect() -> FaultProfile {
        FaultProfile {
            name: "disconnect",
            disconnect: Some(DisconnectSpec {
                first_bytes: 48 * 1024,
            }),
            ..FaultProfile::clean()
        }
    }

    /// Look up a preset by its `--fault` name.
    pub fn parse(name: &str) -> Result<FaultProfile> {
        match name {
            "clean" => Ok(FaultProfile::clean()),
            "jitter" => Ok(FaultProfile::jitter()),
            "bandwidth-step" | "bandwidth_step" => Ok(FaultProfile::bandwidth_step()),
            "stall" => Ok(FaultProfile::stall()),
            "disconnect" => Ok(FaultProfile::disconnect()),
            other => bail!(
                "unknown fault profile {other:?}; expected one of {}",
                PROFILE_NAMES.join(", ")
            ),
        }
    }

    /// True when this profile injects nothing.
    pub fn is_clean(&self) -> bool {
        self.jitter_max == Duration::ZERO
            && self.bandwidth.is_none()
            && self.stall.is_none()
            && self.disconnect.is_none()
    }

    /// This profile with disconnects stripped (for surfaces that cannot
    /// drop a connection, like [`FaultTransport`]).
    pub fn without_disconnect(mut self) -> FaultProfile {
        self.disconnect = None;
        self
    }
}

// ------------------------------------------------------------ pacer

/// What to do with the next chunk of bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pace {
    /// Sleep this long, then forward the whole chunk.
    Forward(Duration),
    /// Forward only the first `n` bytes, then hard-cut the connection.
    Cut(usize),
}

/// Per-connection-direction pacing state: turns a [`FaultProfile`] plus a
/// seed into a deterministic, byte-triggered schedule of delays and cuts.
/// All triggers are byte counters, not wall-clock probabilities — the
/// schedule replays exactly for the same byte stream.
#[derive(Debug, Clone)]
pub struct Pacer {
    profile: FaultProfile,
    rng: Rng,
    /// Bytes admitted so far on this connection.
    sent: u64,
    since_stall: u64,
    /// Bytes until the forced cut; `None` = never cut.
    budget: Option<u64>,
}

/// Ceiling on the escalating disconnect budget (see [`DisconnectSpec`]).
const MAX_CUT_BUDGET: u64 = 16 * 1024 * 1024;

impl Pacer {
    /// `reconnects` is how many connections came before this one — the
    /// disconnect budget escalates `first_bytes · 2^reconnects` (capped)
    /// so resumed sessions always make forward progress.
    pub fn new(profile: &FaultProfile, seed: u64, reconnects: u64) -> Pacer {
        let budget = profile.disconnect.map(|d| {
            let scale = 1u64 << reconnects.min(8);
            d.first_bytes.saturating_mul(scale).min(MAX_CUT_BUDGET)
        });
        Pacer {
            profile: profile.clone(),
            rng: Rng::new(seed),
            sent: 0,
            since_stall: 0,
            budget,
        }
    }

    /// Schedule the next `len`-byte chunk.
    pub fn pace(&mut self, len: usize) -> Pace {
        if let Some(budget) = self.budget {
            let left = budget.saturating_sub(self.sent);
            if len as u64 >= left {
                self.sent = budget;
                return Pace::Cut(left as usize);
            }
        }
        let mut delay = Duration::ZERO;
        if self.profile.jitter_max > Duration::ZERO {
            let jit = self.rng.uniform(0.0, self.profile.jitter_max.as_secs_f64());
            delay += Duration::from_secs_f64(jit);
        }
        if let Some(bw) = self.profile.bandwidth {
            let band = (self.sent / bw.step_bytes) % 2;
            let bps = if band == 0 { bw.hi_bps } else { bw.lo_bps };
            delay += Duration::from_secs_f64(len as f64 / bps);
        }
        if let Some(st) = self.profile.stall {
            self.since_stall += len as u64;
            if self.since_stall >= st.every_bytes {
                self.since_stall %= st.every_bytes;
                delay += st.pause;
            }
        }
        self.sent += len as u64;
        Pace::Forward(delay)
    }
}

// ------------------------------------------------------------ chaos proxy

/// A fault-injecting TCP relay for real `serve-edge` ↔ `serve-server`
/// deployments: listens on one address, dials the upstream server per
/// client connection, and pumps bytes both ways through a seeded
/// [`Pacer`]. Disconnect profiles hard-cut both sockets mid-stream; the
/// proxy keeps listening, so a resuming client reconnects through it and
/// the next connection gets a doubled byte budget.
pub struct ChaosProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    pumps: Arc<Mutex<Vec<JoinHandle<()>>>>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind `listen` (port 0 allocates) and relay every connection to
    /// `upstream` under `profile`. Connection `i` derives its pacer seeds
    /// from `seed` and `i`, so the whole fault schedule replays from one
    /// seed.
    pub fn spawn(
        listen: impl ToSocketAddrs,
        upstream: impl ToSocketAddrs,
        profile: FaultProfile,
        seed: u64,
    ) -> Result<ChaosProxy> {
        let upstream: SocketAddr = upstream
            .to_socket_addrs()
            .context("resolving chaos-proxy upstream")?
            .next()
            .context("chaos-proxy upstream resolved to no address")?;
        let listener = TcpListener::bind(listen).context("binding chaos-proxy listener")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let pumps: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let stop = Arc::clone(&stop);
            let accepted = Arc::clone(&accepted);
            let conns = Arc::clone(&conns);
            let pumps = Arc::clone(&pumps);
            thread::Builder::new()
                .name("sp-chaos-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        let (client, _) = match listener.accept() {
                            Ok(pair) => pair,
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                thread::sleep(Duration::from_millis(5));
                                continue;
                            }
                            Err(_) => break,
                        };
                        let i = accepted.fetch_add(1, Ordering::AcqRel);
                        let server = match TcpStream::connect(upstream) {
                            Ok(s) => s,
                            Err(e) => {
                                eprintln!("[chaos-proxy] upstream dial failed: {e}");
                                let _ = client.shutdown(Shutdown::Both);
                                continue;
                            }
                        };
                        let _ = client.set_nodelay(true);
                        let _ = server.set_nodelay(true);
                        // one independent seed stream per connection+direction
                        let mut conn_rng = Rng::new(seed ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
                        let up_pacer = Pacer::new(&profile, conn_rng.next_u64(), i);
                        let down_pacer = Pacer::new(&profile, conn_rng.next_u64(), i);
                        let spawned = Self::spawn_pumps(
                            &client, &server, up_pacer, down_pacer, &stop, &conns, &pumps,
                        );
                        if let Err(e) = spawned {
                            eprintln!("[chaos-proxy] pump spawn failed: {e}");
                            let _ = client.shutdown(Shutdown::Both);
                            let _ = server.shutdown(Shutdown::Both);
                        }
                    }
                })
                .context("spawning chaos-proxy accept thread")?
        };

        Ok(ChaosProxy {
            addr,
            stop,
            accepted,
            conns,
            pumps,
            accept: Some(accept),
        })
    }

    fn spawn_pumps(
        client: &TcpStream,
        server: &TcpStream,
        up_pacer: Pacer,
        down_pacer: Pacer,
        stop: &Arc<AtomicBool>,
        conns: &Arc<Mutex<Vec<TcpStream>>>,
        pumps: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    ) -> Result<()> {
        {
            let mut held = conns.lock().unwrap();
            held.push(client.try_clone()?);
            held.push(server.try_clone()?);
        }
        let up = Self::spawn_pump(
            "sp-chaos-up",
            client.try_clone()?,
            server.try_clone()?,
            up_pacer,
            Arc::clone(stop),
        )?;
        let down = Self::spawn_pump(
            "sp-chaos-down",
            server.try_clone()?,
            client.try_clone()?,
            down_pacer,
            Arc::clone(stop),
        )?;
        let mut held = pumps.lock().unwrap();
        held.push(up);
        held.push(down);
        Ok(())
    }

    fn spawn_pump(
        name: &str,
        mut from: TcpStream,
        mut to: TcpStream,
        mut pacer: Pacer,
        stop: Arc<AtomicBool>,
    ) -> Result<JoinHandle<()>> {
        thread::Builder::new()
            .name(name.into())
            .spawn(move || {
                let mut buf = [0u8; 16 * 1024];
                loop {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let n = match from.read(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => n,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => break,
                    };
                    match pacer.pace(n) {
                        Pace::Forward(delay) => {
                            if !delay.is_zero() {
                                thread::sleep(delay);
                            }
                            if to.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                        Pace::Cut(keep) => {
                            if keep > 0 {
                                let _ = to.write_all(&buf[..keep]);
                            }
                            break;
                        }
                    }
                }
                // either direction ending (EOF, error or cut) kills the
                // whole relay pair — a half-open chaos link helps nobody
                let _ = from.shutdown(Shutdown::Both);
                let _ = to.shutdown(Shutdown::Both);
            })
            .with_context(|| format!("spawning chaos-proxy pump {name}"))
    }

    /// The address clients should dial (resolved, so port 0 works).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted so far — under a disconnect profile this is
    /// `1 + reconnects` observed through the proxy.
    pub fn connections(&self) -> u64 {
        self.accepted.load(Ordering::Acquire)
    }

    /// Stop relaying: close every live connection, stop accepting, and
    /// join all pump threads. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        for sock in self.conns.lock().unwrap().drain(..) {
            let _ = sock.shutdown(Shutdown::Both);
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        let pumps: Vec<_> = self.pumps.lock().unwrap().drain(..).collect();
        for pump in pumps {
            let _ = pump.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ------------------------------------------------------------ transport wrap

/// Delay-class fault injection around any [`Transport`]: jitter,
/// bandwidth steps and stalls are applied as real sleeps keyed to each
/// delivered frame's uplink bytes. Disconnects are stripped at
/// construction — only the [`ChaosProxy`] can cut a connection.
/// Detections pass through untouched, so outputs stay bitwise identical
/// to the unwrapped transport.
pub struct FaultTransport {
    inner: Box<dyn Transport>,
    profile: FaultProfile,
    pacer: Pacer,
    injected: SimTime,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, profile: FaultProfile, seed: u64) -> FaultTransport {
        let profile = profile.without_disconnect();
        let pacer = Pacer::new(&profile, seed, 0);
        FaultTransport {
            inner,
            profile,
            pacer,
            injected: SimTime::ZERO,
        }
    }
}

impl Transport for FaultTransport {
    fn describe(&self) -> String {
        format!("{} (fault:{})", self.inner.describe(), self.profile.name)
    }

    fn submit(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        cloud: PointCloud,
        pipe: PipelineConfig,
    ) -> Result<()> {
        self.inner.submit(engine, sp, cloud, pipe)
    }

    fn recv(&mut self, engine: &Arc<Engine>) -> Result<FrameOutput> {
        let out = self.inner.recv(engine)?;
        if !self.profile.is_clean() {
            if let Pace::Forward(delay) = self.pacer.pace(out.uplink_bytes.max(1)) {
                if !delay.is_zero() {
                    thread::sleep(delay);
                    self.injected += SimTime::from_duration(delay);
                }
            }
        }
        Ok(out)
    }

    fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    fn bandwidth_bps(&self) -> Option<f64> {
        self.inner.bandwidth_bps()
    }

    fn report(&self) -> Option<String> {
        self.inner.report()
    }

    fn needs_queue_free_samples(&self) -> bool {
        self.inner.needs_queue_free_samples()
    }

    fn link_health(&self) -> LinkHealth {
        let mut health = self.inner.link_health();
        health.stall_time += self.injected;
        health
    }

    fn close(&mut self) -> Result<()> {
        self.inner.close()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_same_seed_reproduces_the_schedule() {
        let policy = RetryPolicy::default();
        let delays = |stream| {
            let mut b = policy.backoff(stream);
            std::iter::from_fn(move || b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(delays(7), delays(7));
        assert_eq!(delays(7).len(), policy.max_retries as usize);
    }

    #[test]
    fn backoff_streams_decorrelate() {
        let policy = RetryPolicy::default();
        let first = policy.backoff(1).next_delay().unwrap();
        let second = policy.backoff(2).next_delay().unwrap();
        assert_ne!(first, second);
    }

    #[test]
    fn backoff_grows_until_the_cap_bounds_it() {
        let policy = RetryPolicy {
            max_retries: 10,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(200),
            seed: 3,
        };
        let mut b = policy.backoff(0);
        let mut prev = Duration::ZERO;
        for k in 0..10 {
            let d = b.next_delay().expect("within budget");
            assert!(d <= policy.cap, "attempt {k}: {d:?} exceeds cap");
            // jitter is [0.5, 1.0)× so pre-cap delays strictly increase
            if k < 5 {
                assert!(d > prev, "attempt {k}: {d:?} not above {prev:?}");
            }
            prev = d;
        }
        assert_eq!(b.next_delay(), None, "budget exhausted");
        assert_eq!(b.attempts(), 10);
    }

    #[test]
    fn retry_none_never_sleeps() {
        assert_eq!(RetryPolicy::none().backoff(0).next_delay(), None);
    }

    #[test]
    fn profile_parse_covers_every_preset() {
        for name in PROFILE_NAMES {
            let p = FaultProfile::parse(name).unwrap();
            assert_eq!(p.name, name);
            assert_eq!(p.is_clean(), name == "clean");
        }
        assert!(FaultProfile::parse("lossy").is_err());
    }

    #[test]
    fn pacer_cuts_exactly_at_the_byte_budget() {
        let profile = FaultProfile {
            disconnect: Some(DisconnectSpec { first_bytes: 100 }),
            ..FaultProfile::disconnect()
        };
        let mut p = Pacer::new(&profile, 1, 0);
        assert_eq!(p.pace(60), Pace::Forward(Duration::ZERO));
        assert_eq!(p.pace(60), Pace::Cut(40));
        assert_eq!(p.pace(10), Pace::Cut(0), "stays cut");
    }

    #[test]
    fn pacer_budget_escalates_per_reconnect() {
        let profile = FaultProfile::disconnect();
        let first = Pacer::new(&profile, 1, 0).budget.unwrap();
        let third = Pacer::new(&profile, 1, 2).budget.unwrap();
        assert_eq!(third, first * 4);
        let late = Pacer::new(&profile, 1, 60).budget.unwrap();
        assert_eq!(late, MAX_CUT_BUDGET, "budget is capped");
    }

    #[test]
    fn pacer_stall_triggers_on_byte_thresholds() {
        let profile = FaultProfile {
            stall: Some(StallSpec {
                every_bytes: 100,
                pause: Duration::from_millis(50),
            }),
            ..FaultProfile::stall()
        };
        let mut p = Pacer::new(&profile, 1, 0);
        match p.pace(99) {
            Pace::Forward(d) => assert_eq!(d, Duration::ZERO),
            other => panic!("unexpected {other:?}"),
        }
        match p.pace(1) {
            Pace::Forward(d) => assert_eq!(d, Duration::from_millis(50)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn pacer_bandwidth_bands_alternate() {
        let profile = FaultProfile {
            bandwidth: Some(BandwidthStep {
                hi_bps: 1e6,
                lo_bps: 1e5,
                step_bytes: 1000,
            }),
            ..FaultProfile::bandwidth_step()
        };
        let mut p = Pacer::new(&profile, 1, 0);
        let hi = match p.pace(1000) {
            Pace::Forward(d) => d,
            other => panic!("unexpected {other:?}"),
        };
        let lo = match p.pace(1000) {
            Pace::Forward(d) => d,
            other => panic!("unexpected {other:?}"),
        };
        assert!(lo > hi * 5, "slow band {lo:?} vs fast band {hi:?}");
    }

    #[test]
    fn pacer_schedule_replays_from_seed() {
        let profile = FaultProfile::jitter();
        let mut a = Pacer::new(&profile, 42, 0);
        let mut b = Pacer::new(&profile, 42, 0);
        for len in [100, 5000, 1, 16 * 1024] {
            assert_eq!(a.pace(len), b.pace(len));
        }
    }

    #[test]
    fn chaos_proxy_relays_bytes_under_a_clean_profile() {
        let upstream = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream_addr = upstream.local_addr().unwrap();
        let echo = thread::spawn(move || {
            let (mut sock, _) = upstream.accept().unwrap();
            let mut buf = [0u8; 4];
            sock.read_exact(&mut buf).unwrap();
            for b in &mut buf {
                *b ^= 0xff;
            }
            sock.write_all(&buf).unwrap();
        });
        let mut proxy =
            ChaosProxy::spawn("127.0.0.1:0", upstream_addr, FaultProfile::clean(), 1).unwrap();
        let mut client = TcpStream::connect(proxy.addr()).unwrap();
        client.write_all(&[1, 2, 3, 4]).unwrap();
        let mut reply = [0u8; 4];
        client.read_exact(&mut reply).unwrap();
        assert_eq!(reply, [0xfe, 0xfd, 0xfc, 0xfb]);
        assert_eq!(proxy.connections(), 1);
        echo.join().unwrap();
        proxy.shutdown();
    }

    #[test]
    fn chaos_proxy_cuts_then_accepts_a_reconnect() {
        let upstream = TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream_addr = upstream.local_addr().unwrap();
        let sink = thread::spawn(move || {
            // swallow whatever arrives on each of two connections
            for _ in 0..2 {
                let (mut sock, _) = upstream.accept().unwrap();
                let mut buf = [0u8; 1024];
                while matches!(sock.read(&mut buf), Ok(n) if n > 0) {}
            }
        });
        let profile = FaultProfile {
            disconnect: Some(DisconnectSpec { first_bytes: 64 }),
            ..FaultProfile::disconnect()
        };
        let mut proxy = ChaosProxy::spawn("127.0.0.1:0", upstream_addr, profile, 1).unwrap();

        // first connection: the cut lands mid-stream
        let mut client = TcpStream::connect(proxy.addr()).unwrap();
        let mut died = false;
        for _ in 0..100 {
            if client.write_all(&[0u8; 64]).is_err() {
                died = true;
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(died, "disconnect profile never cut the stream");

        // reconnect goes through (budget doubled on connection 2)
        let mut again = TcpStream::connect(proxy.addr()).unwrap();
        again.write_all(&[0u8; 64]).unwrap();
        assert!(proxy.connections() >= 2);
        drop(client);
        drop(again);
        proxy.shutdown();
        sink.join().unwrap();
    }
}
