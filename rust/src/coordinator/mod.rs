//! The split-computing coordinator (the paper's L3 contribution).
//!
//! * [`session`] — the public facade: `SplitSession` assembled from a
//!   frame source, a transport, and a split policy
//! * [`engine`] — per-frame split execution on the calibrated virtual clock
//! * [`link`] — bandwidth/RTT link model + live EWMA bandwidth estimator
//! * [`pipeline`] — staged multi-frame scheduler: overlap preprocess(N+1)
//!   with transfer/tail(N) on bounded worker queues
//! * [`transport`] / [`remote`] — real TCP edge/server deployment: the
//!   concurrent multi-client `Server` plus the edge-side clients
//! * [`batcher`] — deadline-flush batching: multi-LiDAR fan-in and the
//!   server's cross-client tail coalescing
//! * [`shutdown`] — the drain-vs-abort teardown contract every
//!   connection-holding handle implements
//! * [`adaptive`] — analytic split-point selection (extension)
//! * [`fault`] — deterministic link-fault injection (profiles, chaos
//!   proxy, transport wrapper) and the retry/backoff policy

pub mod adaptive;
pub mod batcher;
pub mod engine;
pub mod fault;
pub mod link;
pub mod pipeline;
pub mod remote;
pub mod session;
pub mod shutdown;
pub mod transport;

pub use engine::{
    Engine, EngineRole, FrameResult, HeadFrame, Side, TimingBreakdown, TransferredFrame,
};
pub use fault::{ChaosProxy, FaultProfile, FaultTransport, LinkHealth, RetryPolicy};
pub use link::{BandwidthEstimator, LinkModel};
pub use pipeline::{Pipeline, PipelineConfig, PipelineReport};
pub use remote::{ClientOptions, Server, ServerConfig, ServerStats};
pub use session::{ServerSession, ServerSessionBuilder, SplitSession, SplitSessionBuilder};
pub use shutdown::{Shutdown, ShutdownMode};
