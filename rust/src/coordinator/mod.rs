//! The split-computing coordinator (the paper's L3 contribution).
//!
//! * [`engine`] — per-frame split execution on the calibrated virtual clock
//! * [`link`] — bandwidth/RTT link model
//! * [`pipeline`] — staged multi-frame scheduler: overlap preprocess(N+1)
//!   with transfer/tail(N) on bounded worker queues
//! * [`transport`] / [`remote`] — real TCP edge/server deployment
//! * [`batcher`] — multi-LiDAR frame batching (paper §VI future work)
//! * [`adaptive`] — analytic split-point selection (extension)

pub mod adaptive;
pub mod batcher;
pub mod engine;
pub mod link;
pub mod pipeline;
pub mod remote;
pub mod transport;

pub use engine::{Engine, FrameResult, HeadFrame, Side, TimingBreakdown, TransferredFrame};
pub use link::LinkModel;
pub use pipeline::{Pipeline, PipelineConfig, PipelineReport};
