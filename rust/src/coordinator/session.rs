//! `SplitSession` — the public facade over the whole split-computing
//! stack.
//!
//! The paper's headline result is that the *right* split point is a
//! deployment decision (voxelization-split vs in-network splits, shifting
//! with link bandwidth), yet the original entry points hard-wired one
//! concrete assembly per subcommand. A session decomposes the run loop
//! into three swappable axes:
//!
//! * **[`FrameSource`]** — where frames come from: synthetic scenes
//!   ([`SceneSource`]), a KITTI `.bin` directory ([`KittiSource`]), or a
//!   recorded replay ([`ReplaySource`]).
//! * **[`Transport`]** — where the tail half runs: [`InProcess`] (the
//!   calibrated virtual clock, optionally through the staged pipeline) or
//!   [`Tcp`] (a real edge-server process). Both feed an EWMA
//!   [`BandwidthEstimator`] from observed transfers.
//! * **[`SplitPolicy`]** — which split each segment of the stream uses:
//!   [`Fixed`], or [`Adaptive`] re-costing every split from the live
//!   bandwidth estimate with switch hysteresis.
//!
//! ```no_run
//! use splitpoint::coordinator::session::SplitSession;
//!
//! let (frames, report) = SplitSession::builder()
//!     .artifacts("artifacts")
//!     .synthetic(1, 16)
//!     .pipeline_depth(4)
//!     .build()?
//!     .run()?;
//! println!("{} frames, {}", frames.len(), report.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Equivalence contract (pinned by `rust/tests/session.rs`): a session is
//! an *assembly*, never a semantic change. Per-frame detections are
//! byte-identical to calling [`Engine::run_frame`] at the same split —
//! whatever the source, transport, pipeline depth, or policy schedule.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::config::SystemConfig;
use crate::coordinator::adaptive::{self, Objective};
use crate::coordinator::engine::{Engine, EngineRole, FrameResult, TimingBreakdown};
use crate::coordinator::link::BandwidthEstimator;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, PipelineReport};
use crate::coordinator::remote::{EdgeClient, Server};
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::model::manifest::Manifest;
use crate::pointcloud::kitti::KittiSource;
use crate::pointcloud::scene::SceneSource;
use crate::pointcloud::{FrameSource, PointCloud, ReplaySource};
use crate::postprocess::Detection;
use crate::runtime::XlaRuntime;

/// Frames pulled from the source per policy segment, independent of the
/// policy's re-evaluation interval — bounds session memory on unbounded
/// sources while keeping the staged pipeline warm inside a segment.
///
/// Known trades at segment boundaries (both ROADMAP follow-ons):
/// * the session pre-reads a segment before executing it, so source I/O
///   and compute alternate rather than overlap across the boundary (for
///   maximal read/compute overlap on a fixed split, drive
///   [`crate::coordinator::pipeline::run_source`] directly — its bounded
///   input queue backpressures the reader frame by frame);
/// * the TCP transport drains its in-flight window at every boundary
///   (`EdgeClient::run_stream` is one-shot), costing ~depth×RTT of idle
///   wire per `SEGMENT_MAX` frames on a fixed-policy stream. The
///   in-process transport avoids this with its warm cached pipeline.
const SEGMENT_MAX: usize = 32;

// ------------------------------------------------------------ transports

/// One frame's outcome, transport-agnostic: detections plus the timing
/// facts every transport can report. `timing` carries the full
/// virtual-clock breakdown when the transport has one (in-process);
/// wall-clock transports leave it `None`.
#[derive(Debug, Clone)]
pub struct FrameOutput {
    pub detections: Vec<Detection>,
    pub uplink_bytes: usize,
    /// legacy v1-framing cost of the same live set (wire-savings metric)
    pub uplink_v1_bytes: usize,
    /// transport-defined "edge time": [`InProcess`] reports the paper's
    /// Fig 7 quantity on the virtual clock (edge compute + encode +
    /// uplink; the full breakdown is in `timing`), while [`Tcp`] can only
    /// attribute local wall-clock head time (compute + encode — its
    /// uplink is inside `round_trip`). Compare across transports via
    /// `round_trip`/`inference_time`, not this field.
    pub edge_time: SimTime,
    /// send → response received (uplink + server + downlink)
    pub round_trip: SimTime,
    pub server_time: SimTime,
    pub inference_time: SimTime,
    /// full virtual-clock breakdown, when the transport runs on one
    pub timing: Option<TimingBreakdown>,
}

/// The tail half of the split: carries encoded head output to wherever
/// the server nodes run and brings detections back.
///
/// Implementations observe their own transfers into a
/// [`BandwidthEstimator`]; [`Transport::bandwidth_bps`] is what the
/// adaptive policy reads.
pub trait Transport: Send {
    /// Short name for banners/logs ("in-process", "tcp:…").
    fn describe(&self) -> String;

    /// Execute `clouds` at split `sp` (ownership passes to the transport —
    /// segments are moved, never cloned). `pipe.depth > 1` requests
    /// pipelined execution; results must come back in submission order
    /// and be byte-identical to serial execution (the schedule is never
    /// allowed to change semantics).
    fn run_segment(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        clouds: Vec<PointCloud>,
        pipe: PipelineConfig,
    ) -> Result<Vec<FrameOutput>>;

    /// Live uplink-bandwidth estimate (bytes/second) from observed
    /// transfers; `None` before the first sample.
    fn bandwidth_bps(&self) -> Option<f64>;

    /// Stage/queue report, if this transport keeps one (markdown).
    fn report(&self) -> Option<String> {
        None
    }

    /// Flush and release transport resources (idempotent).
    fn close(&mut self) -> Result<()> {
        Ok(())
    }
}

/// In-process transport: head, (virtual) link and tail all run in this
/// process on the calibrated virtual clock — the paper-figure path. At
/// `pipeline_depth > 1` segments run through the staged
/// [`Pipeline`], which is kept warm across segments of the same split.
pub struct InProcess {
    estimator: BandwidthEstimator,
    cached: Option<CachedPipeline>,
    /// reports of pipelines retired by policy switches/serial segments —
    /// the session's final report covers the whole stream, not just the
    /// last pipeline instance
    retired: Vec<(String, PipelineReport)>,
}

struct CachedPipeline {
    sp: SplitPoint,
    depth: usize,
    tail_workers: usize,
    pipeline: Pipeline,
}

impl Default for InProcess {
    fn default() -> Self {
        Self::new()
    }
}

impl InProcess {
    pub fn new() -> InProcess {
        InProcess {
            estimator: BandwidthEstimator::default(),
            cached: None,
            retired: Vec::new(),
        }
    }

    /// Retire the cached pipeline (if any), keeping its stage report.
    fn retire_pipeline(&mut self) {
        if let Some(c) = self.cached.take() {
            let label = format!(
                "pipeline (split head_len={}, depth {} x{} tails)",
                c.sp.head_len, c.depth, c.tail_workers
            );
            self.retired.push((label, c.pipeline.report()));
            // Pipeline::drop closes and joins the stage workers
        }
    }

    /// Fold one frame's timing into the bandwidth EWMA and map it to the
    /// transport-agnostic output. The sample is `bytes / (uplink_time -
    /// rtt)`: the virtual link prices `rtt + bytes/bw`, so subtracting the
    /// engine's configured RTT makes the estimator converge to the true
    /// modeled bandwidth instead of under-shooting (which `Adaptive` would
    /// then double-penalize by re-adding RTT). Small payloads are skipped
    /// — see [`MIN_BANDWIDTH_SAMPLE_BYTES`].
    fn output_of(&mut self, engine: &Engine, r: FrameResult) -> FrameOutput {
        let t = &r.timing;
        if t.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES {
            let rtt = SimTime::from_secs_f64(engine.link().config().rtt_one_way);
            self.estimator
                .observe(t.uplink_bytes, t.uplink_time.saturating_sub(rtt));
        }
        let uplink_bytes = t.uplink_bytes;
        let uplink_v1_bytes = t.uplink_v1_bytes;
        let edge_time = t.edge_time;
        let inference_time = t.inference_time;
        let server_time = t.server_compute();
        let round_trip = t
            .inference_time
            .saturating_sub(t.edge_compute())
            .saturating_sub(t.encode_time);
        FrameOutput {
            detections: r.detections,
            uplink_bytes,
            uplink_v1_bytes,
            edge_time,
            round_trip,
            server_time,
            inference_time,
            timing: Some(r.timing),
        }
    }
}

impl Transport for InProcess {
    fn describe(&self) -> String {
        "in-process (virtual clock)".to_string()
    }

    fn run_segment(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        clouds: Vec<PointCloud>,
        pipe: PipelineConfig,
    ) -> Result<Vec<FrameOutput>> {
        let results: Vec<FrameResult> = if pipe.depth <= 1 {
            self.retire_pipeline();
            clouds
                .iter()
                .map(|c| engine.run_frame(c, sp))
                .collect::<Result<_>>()?
        } else {
            let stale = match &self.cached {
                Some(c) => {
                    c.sp != sp || c.depth != pipe.depth || c.tail_workers != pipe.tail_workers
                }
                None => true,
            };
            if stale {
                self.retire_pipeline();
                self.cached = Some(CachedPipeline {
                    sp,
                    depth: pipe.depth,
                    tail_workers: pipe.tail_workers,
                    pipeline: Pipeline::spawn(engine.clone(), sp, pipe)?,
                });
            }
            let batch = self
                .cached
                .as_ref()
                .expect("pipeline cached above")
                .pipeline
                .run_batch(clouds);
            match batch {
                Ok(r) => r,
                Err(e) => {
                    // the pipeline closed itself on error; don't reuse it
                    self.retire_pipeline();
                    return Err(e);
                }
            }
        };
        Ok(results
            .into_iter()
            .map(|r| self.output_of(engine, r))
            .collect())
    }

    fn bandwidth_bps(&self) -> Option<f64> {
        self.estimator.bandwidth_bps()
    }

    fn report(&self) -> Option<String> {
        let mut sections: Vec<String> = self
            .retired
            .iter()
            .map(|(label, r)| format!("#### {label}\n\n{}", r.to_markdown()))
            .collect();
        if let Some(c) = &self.cached {
            sections.push(c.pipeline.report().to_markdown());
        }
        (!sections.is_empty()).then(|| sections.join("\n"))
    }

    fn close(&mut self) -> Result<()> {
        self.retire_pipeline();
        Ok(())
    }
}

/// TCP transport: the session is the edge process; the tail runs in a
/// `splitpoint serve-server` process at `addr`. Connects lazily on the
/// first segment; `pipeline_depth > 1` uses the pipelined edge client
/// (overlap head(N+1) with the server round trip of frame N).
pub struct Tcp {
    addr: String,
    client: Option<EdgeClient>,
    estimator: BandwidthEstimator,
}

/// Smallest payload worth treating as a bandwidth sample (both
/// transports). Below this, transfer time is RTT/latency-dominated and
/// `bytes / elapsed` measures latency, not throughput — an edge-only
/// segment's ~9-byte empty packets would otherwise poison the EWMA with
/// sub-KB/s "bandwidth", after which the adaptive policy costs every
/// shipping split as absurdly expensive and can never escape edge-only
/// (positive feedback).
pub const MIN_BANDWIDTH_SAMPLE_BYTES: usize = 16 * 1024;

impl Tcp {
    pub fn new(addr: impl Into<String>) -> Tcp {
        Tcp {
            addr: addr.into(),
            client: None,
            estimator: BandwidthEstimator::default(),
        }
    }
}

impl Transport for Tcp {
    fn describe(&self) -> String {
        format!("tcp:{} (realtime)", self.addr)
    }

    fn run_segment(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        clouds: Vec<PointCloud>,
        pipe: PipelineConfig,
    ) -> Result<Vec<FrameOutput>> {
        if self.client.is_none() {
            self.client = Some(
                EdgeClient::connect(self.addr.as_str(), engine.clone()).with_context(
                    || format!("is `splitpoint serve-server` running at {}?", self.addr),
                )?,
            );
        }
        let client = self.client.as_mut().expect("connected above");
        let results = client.run_stream(&clouds, sp, pipe.depth)?;
        Ok(results
            .into_iter()
            .enumerate()
            .map(|(i, (detections, t))| {
                // transfer ≈ round trip minus the server's self-reported
                // compute minus both configured RTT legs — `price_splits`
                // re-adds rtt_one_way per leg, so leaving RTT inside the
                // sample would double-count it (mirrors the InProcess
                // correction). Two further filters keep the EWMA honest:
                // RTT-dominated payloads are skipped
                // (MIN_BANDWIDTH_SAMPLE_BYTES), and in pipelined mode
                // only the segment's FIRST frame is sampled — the
                // in-flight window drains at each segment boundary, so
                // frame 0's round trip has no queueing, while later
                // frames wait behind up to depth-1 frames of server
                // compute and would deflate the estimate.
                let queue_free = pipe.depth <= 1 || i == 0;
                if queue_free && t.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES {
                    let rtt_both_legs = SimTime::from_secs_f64(
                        2.0 * engine.link().config().rtt_one_way,
                    );
                    self.estimator.observe(
                        t.uplink_bytes,
                        t.round_trip
                            .saturating_sub(t.server_compute)
                            .saturating_sub(rtt_both_legs),
                    );
                }
                FrameOutput {
                    detections,
                    uplink_bytes: t.uplink_bytes,
                    uplink_v1_bytes: t.uplink_v1_bytes,
                    edge_time: t.edge_compute,
                    round_trip: t.round_trip,
                    server_time: t.server_compute,
                    inference_time: t.inference_time,
                    timing: None,
                }
            })
            .collect())
    }

    fn bandwidth_bps(&self) -> Option<f64> {
        self.estimator.bandwidth_bps()
    }

    fn close(&mut self) -> Result<()> {
        match self.client.take() {
            Some(client) => client.shutdown(),
            None => Ok(()),
        }
    }
}

// -------------------------------------------------------------- policies

/// Everything a policy may consult at a re-evaluation boundary.
pub struct PolicyContext<'a> {
    pub engine: &'a Engine,
    /// profile cloud for this segment (its first frame)
    pub cloud: &'a PointCloud,
    /// frames completed so far in this session
    pub frames_done: u64,
    /// live transport bandwidth estimate (bytes/second), if any
    pub bandwidth_bps: Option<f64>,
    /// split the previous segment ran at
    pub current: Option<SplitPoint>,
}

/// Decides the split point for each segment of the stream.
pub trait SplitPolicy: Send {
    /// Short name for banners/logs.
    fn describe(&self) -> String;

    /// Split for the next segment. Called once per segment boundary with
    /// fresh context; implementations may keep state (hysteresis).
    fn choose(&mut self, ctx: &PolicyContext<'_>) -> Result<SplitPoint>;

    /// Frames between re-evaluations. The session clamps this to its
    /// internal segment cap; `usize::MAX` means "never re-evaluate".
    fn interval(&self) -> usize {
        usize::MAX
    }
}

/// Always the same split (the classic `--split` flag).
#[derive(Debug, Clone, Copy)]
pub struct Fixed(pub SplitPoint);

impl SplitPolicy for Fixed {
    fn describe(&self) -> String {
        "fixed".to_string()
    }

    fn choose(&mut self, _ctx: &PolicyContext<'_>) -> Result<SplitPoint> {
        Ok(self.0)
    }
}

/// Runtime-adaptive split selection: every `every` frames, re-price every
/// split under the transport's *live* bandwidth estimate (falling back to
/// the configured link model until the first transfer lands), and switch
/// only when the best split beats the current one by more than
/// `hysteresis` — flapping between near-tied splits would churn the
/// pipeline for no gain.
///
/// Cost control: re-pricing ([`adaptive::price_splits`]) is pure
/// arithmetic and runs at every re-evaluation; the expensive half
/// ([`adaptive::profile_splits`] — one full unscaled pipeline run) is
/// cached and refreshed only every `reprofile_every` evaluations, so at
/// the defaults (8 × 4) the stream pays one extra profile frame per 32
/// real frames (~3%), not one per 8.
#[derive(Debug, Clone)]
pub struct Adaptive {
    objective: Objective,
    every: usize,
    hysteresis: f64,
    reprofile_every: usize,
    cached_costs: Option<Vec<adaptive::SplitCosts>>,
    evals_since_profile: usize,
}

impl Adaptive {
    pub fn new(objective: Objective) -> Adaptive {
        Adaptive {
            objective,
            every: 8,
            hysteresis: 0.10,
            reprofile_every: 4,
            cached_costs: None,
            evals_since_profile: 0,
        }
    }

    /// Re-evaluation interval in frames (default 8).
    pub fn every(mut self, frames: usize) -> Adaptive {
        self.every = frames.max(1);
        self
    }

    /// Minimum fractional improvement required to switch (default 0.10).
    pub fn hysteresis(mut self, h: f64) -> Adaptive {
        self.hysteresis = h.max(0.0);
        self
    }

    /// Evaluations between fresh profile runs (default 4; 1 = re-profile
    /// at every re-evaluation).
    pub fn reprofile_every(mut self, evals: usize) -> Adaptive {
        self.reprofile_every = evals.max(1);
        self
    }
}

impl SplitPolicy for Adaptive {
    fn describe(&self) -> String {
        let obj = match self.objective {
            Objective::InferenceTime => "inference-time",
            Objective::EdgeTime => "edge-time",
        };
        format!("adaptive({obj}, every {} frame(s))", self.every)
    }

    fn choose(&mut self, ctx: &PolicyContext<'_>) -> Result<SplitPoint> {
        let link = match ctx.bandwidth_bps {
            Some(bps) if bps > 0.0 => ctx.engine.link().with_bandwidth(bps),
            _ => ctx.engine.link().clone(),
        };
        // refresh the (expensive) profile only every Nth evaluation; the
        // per-evaluation work is the pure-arithmetic re-pricing below
        if self.cached_costs.is_none() || self.evals_since_profile >= self.reprofile_every {
            self.cached_costs = Some(adaptive::profile_splits(ctx.engine, ctx.cloud)?);
            self.evals_since_profile = 0;
        }
        self.evals_since_profile += 1;
        let costs = self.cached_costs.as_ref().expect("profiled above");
        let estimates = adaptive::price_splits(costs, &link);
        let best = adaptive::best_estimate(&estimates, self.objective);
        // hysteresis against the split the session actually ran last
        // segment (`ctx.current` — the policy keeps no shadow copy)
        let chosen = match ctx.current {
            Some(cur) if cur != best.split => {
                let cur_cost = estimates
                    .iter()
                    .find(|e| e.split == cur)
                    .map(|e| self.objective.cost(e).as_secs_f64());
                match cur_cost {
                    // switch only past the hysteresis margin
                    Some(cc)
                        if self.objective.cost(best).as_secs_f64()
                            < cc * (1.0 - self.hysteresis) =>
                    {
                        best.split
                    }
                    Some(_) => cur,
                    None => best.split,
                }
            }
            _ => best.split,
        };
        Ok(chosen)
    }

    fn interval(&self) -> usize {
        self.every
    }
}

// --------------------------------------------------------------- session

/// One delivered frame: session sequencing, provenance, the split it ran
/// at, and the transport's output.
#[derive(Debug, Clone)]
pub struct SessionFrame {
    /// dense session-wide sequence number (delivery order)
    pub seq: u64,
    /// source-assigned sequence (replay position, scan index, …)
    pub source_seq: u64,
    pub sensor_id: u32,
    /// points in the input cloud
    pub points: usize,
    pub split: SplitPoint,
    pub split_label: String,
    pub output: FrameOutput,
}

/// End-of-stream accounting.
#[derive(Debug, Clone, Default)]
pub struct SessionReport {
    pub frames: usize,
    pub wall: Duration,
    /// split changes the policy made mid-stream
    pub switches: usize,
    /// frames executed per split label
    pub split_usage: BTreeMap<String, usize>,
    /// transport's final bandwidth estimate
    pub bandwidth_bps: Option<f64>,
    /// total uplink bytes actually shipped (wire v2)
    pub uplink_bytes: usize,
    /// what the same stream would have cost under the v1 framing
    pub uplink_v1_bytes: usize,
    /// staged-pipeline stage/queue report, when the transport kept one
    pub transport_report: Option<String>,
}

impl SessionReport {
    /// Wire bytes saved by the v2 delta framing, as a fraction of v1.
    pub fn wire_savings(&self) -> Option<f64> {
        (self.uplink_v1_bytes > 0)
            .then(|| 1.0 - self.uplink_bytes as f64 / self.uplink_v1_bytes as f64)
    }

    /// One-paragraph human summary for CLI output.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let wall = self.wall.as_secs_f64();
        let _ = write!(
            s,
            "{} frame(s) in {:.2} s ({:.2} frames/s wall)",
            self.frames,
            wall,
            self.frames as f64 / wall.max(1e-9)
        );
        if !self.split_usage.is_empty() {
            let splits: Vec<String> = self
                .split_usage
                .iter()
                .map(|(k, v)| format!("{k}×{v}"))
                .collect();
            let _ = write!(s, "; splits {} ({} switch(es))", splits.join(", "), self.switches);
        }
        if let Some(bps) = self.bandwidth_bps {
            let _ = write!(s, "; est. bandwidth {:.2} MB/s", bps / 1e6);
        }
        if let Some(savings) = self.wire_savings() {
            let _ = write!(
                s,
                "; uplink {:.2} MB (wire v2; v1 would be {:.2} MB, {:.1}% saved)",
                self.uplink_bytes as f64 / 1e6,
                self.uplink_v1_bytes as f64 / 1e6,
                savings * 100.0
            );
        }
        s
    }
}

/// The facade: source → policy → transport, segment by segment. Build one
/// with [`SplitSession::builder`].
pub struct SplitSession {
    engine: Arc<Engine>,
    source: Box<dyn FrameSource>,
    transport: Box<dyn Transport>,
    policy: Box<dyn SplitPolicy>,
    pipe: PipelineConfig,
    frames_done: u64,
}

impl SplitSession {
    pub fn builder() -> SplitSessionBuilder {
        SplitSessionBuilder::new()
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Banner line describing the assembled session.
    pub fn describe(&self) -> String {
        format!(
            "source: {} | transport: {} | policy: {} | depth {} x{} tail(s), {} kernel thread(s)",
            self.source.describe(),
            self.transport.describe(),
            self.policy.describe(),
            self.pipe.depth,
            self.pipe.tail_workers,
            self.engine.runtime().threads(),
        )
    }

    /// Run the stream to exhaustion, delivering each frame to `on_frame`
    /// in order. The transport is closed on every exit path — a source or
    /// transport error still sends the TCP shutdown / drains the pipeline
    /// before the error propagates.
    pub fn run_with<F: FnMut(SessionFrame)>(&mut self, mut on_frame: F) -> Result<SessionReport> {
        let t0 = Instant::now();
        let mut report = SessionReport::default();
        let run_res = self.run_loop(&mut on_frame, &mut report);
        let close_res = self.transport.close();
        report.transport_report = self.transport.report();
        report.bandwidth_bps = self.transport.bandwidth_bps();
        report.wall = t0.elapsed();
        run_res?;
        close_res?;
        Ok(report)
    }

    /// The segment loop behind [`SplitSession::run_with`].
    fn run_loop(
        &mut self,
        on_frame: &mut dyn FnMut(SessionFrame),
        report: &mut SessionReport,
    ) -> Result<()> {
        let mut current_sp: Option<SplitPoint> = None;
        loop {
            // ---- pull one segment from the source
            let target = self.policy.interval().max(1).min(SEGMENT_MAX);
            let mut metas: Vec<(u32, u64, usize)> = Vec::with_capacity(target);
            let mut clouds: Vec<PointCloud> = Vec::with_capacity(target);
            while clouds.len() < target {
                match self.source.next_frame()? {
                    Some(f) => {
                        metas.push((f.sensor_id, f.seq, f.cloud.len()));
                        clouds.push(f.cloud);
                    }
                    None => break,
                }
            }
            if clouds.is_empty() {
                return Ok(());
            }
            let n = clouds.len();

            // ---- policy decides this segment's split
            let ctx = PolicyContext {
                engine: &*self.engine,
                cloud: &clouds[0],
                frames_done: self.frames_done,
                bandwidth_bps: self.transport.bandwidth_bps(),
                current: current_sp,
            };
            let sp = self.policy.choose(&ctx)?;
            if current_sp.is_some_and(|c| c != sp) {
                report.switches += 1;
            }
            current_sp = Some(sp);

            // ---- transport executes the segment (clouds move, no clone)
            let outs = self
                .transport
                .run_segment(&self.engine, sp, clouds, self.pipe)?;
            if outs.len() != n {
                bail!("transport returned {} result(s) for {n} frame(s)", outs.len());
            }
            let label = self.engine.graph().split_label(sp);
            *report.split_usage.entry(label.clone()).or_default() += n;
            for ((sensor_id, source_seq, points), output) in metas.into_iter().zip(outs) {
                report.uplink_bytes += output.uplink_bytes;
                report.uplink_v1_bytes += output.uplink_v1_bytes;
                report.frames += 1;
                on_frame(SessionFrame {
                    seq: self.frames_done,
                    source_seq,
                    sensor_id,
                    points,
                    split: sp,
                    split_label: label.clone(),
                    output,
                });
                self.frames_done += 1;
            }
        }
    }

    /// [`SplitSession::run_with`], collecting every frame.
    pub fn run(&mut self) -> Result<(Vec<SessionFrame>, SessionReport)> {
        let mut frames = Vec::new();
        let report = self.run_with(|f| frames.push(f))?;
        Ok((frames, report))
    }
}

// --------------------------------------------------------------- builder

/// Assembles a [`SplitSession`] (or just its engine / a server process)
/// from parts. Unset axes get the classic defaults: synthetic scenes,
/// in-process transport, the config's fixed split, serial depth, one
/// kernel thread.
pub struct SplitSessionBuilder {
    artifacts: PathBuf,
    config: Option<SystemConfig>,
    split: Option<String>,
    engine: Option<Arc<Engine>>,
    source: Option<Box<dyn FrameSource>>,
    transport: Option<Box<dyn Transport>>,
    policy: Option<Box<dyn SplitPolicy>>,
    depth: usize,
    tail_workers: usize,
    threads: usize,
    role: EngineRole,
}

impl Default for SplitSessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SplitSessionBuilder {
    pub fn new() -> SplitSessionBuilder {
        SplitSessionBuilder {
            artifacts: PathBuf::from("artifacts"),
            config: None,
            split: None,
            engine: None,
            source: None,
            transport: None,
            policy: None,
            depth: 1,
            tail_workers: 1,
            threads: 1,
            role: EngineRole::Full,
        }
    }

    /// Artifact directory (`make artifacts` output; default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Load the system config from a JSON file.
    pub fn config_file(mut self, path: &std::path::Path) -> Result<Self> {
        self.config = Some(SystemConfig::load(path)?);
        Ok(self)
    }

    /// Override the config's split name ("vfe", "conv2", "edge_only", …).
    /// With the default [`Fixed`] policy this is the split every frame
    /// runs at.
    pub fn split(mut self, name: &str) -> Self {
        self.split = Some(name.to_string());
        self
    }

    /// Inject a prebuilt engine (benches and tests sweeping sessions over
    /// one compiled runtime). Overrides `artifacts`/`config`/`split`/
    /// `threads`/`role` — the engine is taken as-is.
    pub fn engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Frame source (any [`FrameSource`]).
    pub fn source(mut self, source: Box<dyn FrameSource>) -> Self {
        self.source = Some(source);
        self
    }

    /// Synthetic-scene source shortcut.
    pub fn synthetic(self, seed: u64, frames: usize) -> Self {
        self.source(Box::new(SceneSource::new(seed, frames)))
    }

    /// `--source` CLI spec: `synthetic` (uses `seed`/`frames`),
    /// `kitti:<dir>`, or `replay:<file>.bin`. `frames` caps directory
    /// sources and sets the synthetic/replay length.
    pub fn source_spec(
        self,
        spec: Option<&str>,
        seed: u64,
        frames: Option<usize>,
    ) -> Result<Self> {
        Ok(self.source(parse_source(spec, seed, frames)?))
    }

    /// Transport (any [`Transport`]). Default: [`InProcess`].
    pub fn transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// TCP transport shortcut (edge process against `serve-server`).
    pub fn tcp(self, addr: &str) -> Self {
        self.transport(Box::new(Tcp::new(addr)))
    }

    /// Split policy (any [`SplitPolicy`]). Default: [`Fixed`] at the
    /// config's split.
    pub fn policy(mut self, policy: Box<dyn SplitPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Adaptive-policy shortcut.
    pub fn adaptive(self, objective: Objective) -> Self {
        self.policy(Box::new(Adaptive::new(objective)))
    }

    /// Staged-pipeline depth; 1 (default) = serial.
    pub fn pipeline_depth(mut self, depth: usize) -> Self {
        self.depth = depth.max(1);
        self
    }

    /// Parallel tail stages when pipelined (default 1).
    pub fn tail_workers(mut self, n: usize) -> Self {
        self.tail_workers = n.max(1);
        self
    }

    /// Total kernel-thread budget; split across tail workers via
    /// [`PipelineConfig::kernel_threads_for`] so the two levels of
    /// parallelism compose (default 1; outputs are bit-identical at any
    /// count).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Which half of the pipeline this engine serves (default `Full`).
    pub fn role(mut self, role: EngineRole) -> Self {
        self.role = role;
        self
    }

    /// Build just the engine — the thin-shell path for subcommands and
    /// benches that drive [`Engine`] directly (sweep, estimate,
    /// calibrate).
    pub fn build_engine(&self) -> Result<Arc<Engine>> {
        if let Some(engine) = &self.engine {
            return Ok(engine.clone());
        }
        let manifest = Manifest::load(&self.artifacts)?;
        let mut cfg = self.config.clone().unwrap_or_else(SystemConfig::paper);
        if let Some(split) = &self.split {
            cfg.split = split.clone();
        }
        let tails = if self.depth > 1 { self.tail_workers } else { 1 };
        let kernel = PipelineConfig::kernel_threads_for(self.threads, tails);
        let runtime = Arc::new(XlaRuntime::load_pooled(&manifest, kernel)?);
        Ok(Arc::new(Engine::with_runtime_role(
            &manifest, cfg, runtime, self.role,
        )?))
    }

    /// Build the full session.
    pub fn build(mut self) -> Result<SplitSession> {
        let engine = self.build_engine()?;
        let policy: Box<dyn SplitPolicy> = match self.policy.take() {
            Some(p) => p,
            None => Box::new(Fixed(engine.split()?)),
        };
        let source = self
            .source
            .take()
            .unwrap_or_else(|| Box::new(SceneSource::new(1, 5)));
        let transport = self
            .transport
            .take()
            .unwrap_or_else(|| Box::new(InProcess::new()));
        Ok(SplitSession {
            engine,
            source,
            transport,
            policy,
            pipe: PipelineConfig {
                depth: self.depth,
                tail_workers: self.tail_workers,
            },
            frames_done: 0,
        })
    }

    /// Build the server side of the TCP deployment: a tail-role engine
    /// (no edge-side state until a raw-offload request needs it) behind a
    /// listening [`Server`].
    pub fn build_server(self, listen: &str) -> Result<Server> {
        let engine = self.role(EngineRole::ServerTail).build_engine()?;
        Server::spawn(listen, engine)
    }
}

/// Parse a `--source` spec. `None`/`"synthetic"` yields `frames`
/// (default 5) scenes from `seed`; `kitti:<dir>` streams a scan
/// directory (capped at `frames` when given); `replay:<file>.bin` replays
/// one recorded scan `frames` (default 1) times.
pub fn parse_source(
    spec: Option<&str>,
    seed: u64,
    frames: Option<usize>,
) -> Result<Box<dyn FrameSource>> {
    let spec = spec.unwrap_or("synthetic");
    match crate::util::cli::split_spec(spec) {
        ("synthetic", None) => Ok(Box::new(SceneSource::new(seed, frames.unwrap_or(5)))),
        ("kitti", Some(dir)) => {
            let src = KittiSource::open(std::path::Path::new(dir))?;
            Ok(match frames {
                Some(n) => Box::new(src.limit(n)),
                None => Box::new(src),
            })
        }
        ("replay", Some(file)) => Ok(Box::new(
            ReplaySource::from_file(std::path::Path::new(file))?
                .repeated(frames.unwrap_or(1)),
        )),
        _ => bail!(
            "unknown --source '{spec}' (want synthetic, kitti:<dir>, or replay:<file>.bin)"
        ),
    }
}
