//! `SplitSession` — the public facade over the whole split-computing
//! stack.
//!
//! The paper's headline result is that the *right* split point is a
//! deployment decision (voxelization-split vs in-network splits, shifting
//! with link bandwidth), yet the original entry points hard-wired one
//! concrete assembly per subcommand. A session decomposes the run loop
//! into three swappable axes:
//!
//! * **[`FrameSource`]** — where frames come from: synthetic scenes
//!   ([`SceneSource`]), a KITTI `.bin` directory ([`KittiSource`]), or a
//!   recorded replay ([`ReplaySource`]).
//! * **[`Transport`]** — where the tail half runs: [`InProcess`] (the
//!   calibrated virtual clock, optionally through the staged pipeline) or
//!   [`Tcp`] (a real edge-server process). Both feed an EWMA
//!   [`BandwidthEstimator`] from observed transfers.
//! * **[`SplitPolicy`]** — which split each segment of the stream uses:
//!   [`Fixed`], or [`Adaptive`] re-costing every split from the live
//!   bandwidth estimate with switch hysteresis.
//!
//! ```no_run
//! use splitpoint::coordinator::session::SplitSession;
//!
//! let (frames, report) = SplitSession::builder()
//!     .artifacts("artifacts")
//!     .synthetic(1, 16)
//!     .pipeline_depth(4)
//!     .build()?
//!     .run()?;
//! println!("{} frames, {}", frames.len(), report.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Equivalence contract (pinned by `rust/tests/session.rs`): a session is
//! an *assembly*, never a semantic change. Per-frame detections are
//! byte-identical to calling [`Engine::run_frame`] at the same split —
//! whatever the source, transport, pipeline depth, or policy schedule.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::config::SystemConfig;
use crate::coordinator::adaptive::{self, Objective};
use crate::coordinator::batcher::MultiSource;
use crate::coordinator::engine::{Engine, EngineRole, FrameResult, TimingBreakdown};
use crate::coordinator::fault::{FaultProfile, FaultTransport, LinkHealth, RetryPolicy};
use crate::coordinator::link::BandwidthEstimator;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig, PipelineReport};
use crate::coordinator::remote::{
    ClientOptions, EdgeClient, EdgeStream, LinkCounters, RemoteTiming, Server, ServerConfig,
    ServerStats,
};
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::model::manifest::Manifest;
use crate::pointcloud::kitti::{KittiSource, RecordedSource};
use crate::pointcloud::scene::SceneSource;
use crate::pointcloud::{Frame, FrameSource, PointCloud, RecordingSource, ReplaySource};
use crate::postprocess::Detection;
use crate::runtime::simd::SimdMode;
use crate::runtime::XlaRuntime;
use crate::tensor::codec::WirePrecision;
use crate::telemetry::{
    self,
    sla::{SlaEvaluator, SlaSpec, SlaVerdict},
};

/// Upper bound on frames between policy re-evaluations, whatever the
/// policy's own `interval()` asks for — bounds how long a stale split
/// decision can persist on an unbounded stream.
///
/// Since the continuous-session rework the stream no longer *drains* at
/// these boundaries: frames keep flowing through the transport's
/// in-flight window, the bounded feeder thread keeps reading ahead, and
/// only an actual split flip flushes the window.
const SEGMENT_MAX: usize = 32;

/// Frames the feeder thread may read ahead of the executing stream — the
/// bound that lets KITTI `.bin` disk I/O overlap head/transfer/tail
/// compute across segment boundaries without ballooning memory on
/// unbounded sources.
const FEED_AHEAD: usize = 4;

/// When a bandwidth-consuming policy ([`SplitPolicy::wants_bandwidth`])
/// runs over a transport that can only sample empty-window frames
/// honestly ([`Transport::needs_queue_free_samples`] — real TCP), the
/// session deliberately drains the in-flight window at every Nth policy
/// boundary so the next frame enters an empty window and yields a
/// queue-free bandwidth sample — on a continuously full TCP window no
/// frame after the first is otherwise sample-safe, and the adaptive
/// policy would price splits from stale link data forever. Fixed-style
/// policies, and any policy on the in-process transport (which samples
/// every frame on the virtual clock), never pay this: their streams stay
/// continuously pipelined.
const RESAMPLE_BOUNDARIES: usize = 4;

/// How far above the configured two-leg RTT the measured RTT must sit
/// before [`Adaptive`] treats the link as degraded and starts preferring
/// smaller-uplink splits (in addition to any breached SLA objective).
const DEGRADED_RTT_FACTOR: f64 = 4.0;

// ------------------------------------------------------------ transports

/// One frame's outcome, transport-agnostic: detections plus the timing
/// facts every transport can report. `timing` carries the full
/// virtual-clock breakdown when the transport has one (in-process);
/// wall-clock transports leave it `None`.
#[derive(Debug, Clone)]
pub struct FrameOutput {
    pub detections: Vec<Detection>,
    pub uplink_bytes: usize,
    /// legacy v1-framing cost of the same live set (wire-savings metric)
    pub uplink_v1_bytes: usize,
    /// exact-f32 (v2) cost of the same live set — equals `uplink_bytes`
    /// on f32 sessions, the quant-savings baseline on f16/int8 sessions
    pub uplink_f32_bytes: usize,
    /// bytes actually shipped under v3 quantized framing (0 on f32 runs)
    pub uplink_v3_bytes: usize,
    /// transport-defined "edge time": [`InProcess`] reports the paper's
    /// Fig 7 quantity on the virtual clock (edge compute + encode +
    /// uplink; the full breakdown is in `timing`), while [`Tcp`] can only
    /// attribute local wall-clock head time (compute + encode — its
    /// uplink is inside `round_trip`). Compare across transports via
    /// `round_trip`/`inference_time`, not this field.
    pub edge_time: SimTime,
    /// send → response received (uplink + server + downlink)
    pub round_trip: SimTime,
    pub server_time: SimTime,
    pub inference_time: SimTime,
    /// full virtual-clock breakdown, when the transport runs on one
    pub timing: Option<TimingBreakdown>,
}

/// The tail half of the split: carries encoded head output to wherever
/// the server nodes run and brings detections back.
///
/// Incremental streaming API (the continuous-session rework): the caller
/// feeds frames one at a time with [`Transport::submit`] and drains
/// completed frames — in submission order, byte-identical to serial
/// execution — with [`Transport::recv`]. The in-flight window is the
/// caller's responsibility: the session never lets
/// [`Transport::in_flight`] exceed the pipeline depth before submitting,
/// and only drains the window fully when the split policy actually flips,
/// at a periodic telemetry boundary for bandwidth-consuming policies
/// ([`SplitPolicy::wants_bandwidth`]), or at end of stream. This is what
/// keeps a fixed-policy TCP stream's pipe busy across segment boundaries.
///
/// Implementations observe their own transfers into a
/// [`BandwidthEstimator`]; [`Transport::bandwidth_bps`] is what the
/// adaptive policy reads.
pub trait Transport: Send {
    /// Short name for banners/logs ("in-process", "tcp:…").
    fn describe(&self) -> String;

    /// Submit one frame at split `sp` into the in-flight window
    /// (ownership of the cloud passes to the transport — frames are
    /// moved, never cloned). `pipe.depth > 1` requests pipelined
    /// execution. Callers must not change `sp` or `pipe` while frames
    /// are in flight — the session flushes first.
    fn submit(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        cloud: PointCloud,
        pipe: PipelineConfig,
    ) -> Result<()>;

    /// Deliver the next completed frame in submission order, blocking
    /// until it is ready. Calling with nothing in flight is an error.
    fn recv(&mut self, engine: &Arc<Engine>) -> Result<FrameOutput>;

    /// Frames submitted but not yet delivered through [`Transport::recv`].
    fn in_flight(&self) -> usize;

    /// Convenience batch executor over the streaming API: submit every
    /// cloud with a `pipe.depth`-bounded window, then drain. Provided for
    /// tests and one-shot callers; the session drives submit/recv
    /// directly so the window survives across its segment boundaries.
    fn run_segment(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        clouds: Vec<PointCloud>,
        pipe: PipelineConfig,
    ) -> Result<Vec<FrameOutput>> {
        let window = pipe.depth.max(1);
        let mut out = Vec::with_capacity(clouds.len());
        for cloud in clouds {
            while self.in_flight() >= window {
                out.push(self.recv(engine)?);
            }
            self.submit(engine, sp, cloud, pipe)?;
        }
        while self.in_flight() > 0 {
            out.push(self.recv(engine)?);
        }
        Ok(out)
    }

    /// Live uplink-bandwidth estimate (bytes/second) from observed
    /// transfers; `None` before the first sample.
    fn bandwidth_bps(&self) -> Option<f64>;

    /// Stage/queue report, if this transport keeps one (markdown).
    fn report(&self) -> Option<String> {
        None
    }

    /// Whether this transport can only produce honest bandwidth samples
    /// from frames that entered an *empty* window. True for real-wire
    /// transports ([`Tcp`]): a queued frame's round trip includes waiting
    /// behind other frames' server compute, which would deflate the
    /// estimate. False (default) for transports that sample every frame
    /// cleanly ([`InProcess`] prices the uplink on the virtual clock,
    /// queueing-free by construction) — the session then never pays the
    /// periodic telemetry drain.
    fn needs_queue_free_samples(&self) -> bool {
        false
    }

    /// Link-resilience telemetry: retries, reconnects, backoff/stall time
    /// and smoothed RTT observed so far. The default (a transport with no
    /// real link) is permanently clean; [`Tcp`] reports its client's
    /// counters and [`FaultTransport`] adds its injected stall time.
    fn link_health(&self) -> LinkHealth {
        LinkHealth::default()
    }

    /// Flush and release transport resources (idempotent). In-flight
    /// frames still undelivered on an error path are abandoned.
    fn close(&mut self) -> Result<()> {
        Ok(())
    }
}

/// In-process transport: head, (virtual) link and tail all run in this
/// process on the calibrated virtual clock — the paper-figure path. At
/// `pipeline_depth > 1` segments run through the staged
/// [`Pipeline`], which is kept warm across segments of the same split.
pub struct InProcess {
    estimator: BandwidthEstimator,
    cached: Option<CachedPipeline>,
    /// reports of pipelines retired by policy switches/serial segments —
    /// the session's final report covers the whole stream, not just the
    /// last pipeline instance
    retired: Vec<(String, PipelineReport)>,
    /// serial-mode (`depth <= 1`) results completed at submit time,
    /// awaiting recv
    ready: VecDeque<FrameResult>,
}

struct CachedPipeline {
    sp: SplitPoint,
    depth: usize,
    tail_workers: usize,
    pipeline: Pipeline,
}

impl Default for InProcess {
    fn default() -> Self {
        Self::new()
    }
}

impl InProcess {
    pub fn new() -> InProcess {
        InProcess {
            estimator: BandwidthEstimator::default(),
            cached: None,
            retired: Vec::new(),
            ready: VecDeque::new(),
        }
    }

    /// Retire the cached pipeline (if any), keeping its stage report.
    fn retire_pipeline(&mut self) {
        if let Some(c) = self.cached.take() {
            let label = format!(
                "pipeline (split head_len={}, depth {} x{} tails)",
                c.sp.head_len, c.depth, c.tail_workers
            );
            self.retired.push((label, c.pipeline.report()));
            // Pipeline::drop closes and joins the stage workers
        }
    }

    /// Fold one frame's timing into the bandwidth EWMA and map it to the
    /// transport-agnostic output. The sample is `bytes / (uplink_time -
    /// rtt)`: the virtual link prices `rtt + bytes/bw`, so subtracting the
    /// engine's configured RTT makes the estimator converge to the true
    /// modeled bandwidth instead of under-shooting (which `Adaptive` would
    /// then double-penalize by re-adding RTT). Small payloads are skipped
    /// — see [`MIN_BANDWIDTH_SAMPLE_BYTES`].
    fn output_of(&mut self, engine: &Engine, r: FrameResult) -> FrameOutput {
        let t = &r.timing;
        if t.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES {
            let rtt = SimTime::from_secs_f64(engine.link().config().rtt_one_way);
            self.estimator
                .observe(t.uplink_bytes, t.uplink_time.saturating_sub(rtt));
        }
        let uplink_bytes = t.uplink_bytes;
        let uplink_v1_bytes = t.uplink_v1_bytes;
        let uplink_f32_bytes = t.uplink_f32_bytes;
        let uplink_v3_bytes = t.uplink_v3_bytes;
        let edge_time = t.edge_time;
        let inference_time = t.inference_time;
        let server_time = t.server_compute();
        let round_trip = t
            .inference_time
            .saturating_sub(t.edge_compute())
            .saturating_sub(t.encode_time);
        FrameOutput {
            detections: r.detections,
            uplink_bytes,
            uplink_v1_bytes,
            uplink_f32_bytes,
            uplink_v3_bytes,
            edge_time,
            round_trip,
            server_time,
            inference_time,
            timing: Some(r.timing),
        }
    }
}

impl Transport for InProcess {
    fn describe(&self) -> String {
        "in-process (virtual clock)".to_string()
    }

    fn submit(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        cloud: PointCloud,
        pipe: PipelineConfig,
    ) -> Result<()> {
        if pipe.depth <= 1 {
            // serial path: execute immediately, deliver lazily — the
            // session's window loop recv's before the next submit
            self.retire_pipeline();
            self.ready.push_back(engine.run_frame(&cloud, sp)?);
            return Ok(());
        }
        let stale = match &self.cached {
            Some(c) => {
                c.sp != sp || c.depth != pipe.depth || c.tail_workers != pipe.tail_workers
            }
            None => true,
        };
        if stale {
            if self.in_flight() > 0 {
                bail!(
                    "split/depth changed with {} frame(s) in flight — flush first",
                    self.in_flight()
                );
            }
            self.retire_pipeline();
            self.cached = Some(CachedPipeline {
                sp,
                depth: pipe.depth,
                tail_workers: pipe.tail_workers,
                pipeline: Pipeline::spawn(engine.clone(), sp, pipe)?,
            });
        }
        let submit = self.cached.as_ref().expect("pipeline cached above").pipeline.submit(cloud);
        if let Err(e) = submit {
            self.retire_pipeline();
            return Err(e);
        }
        Ok(())
    }

    fn recv(&mut self, engine: &Arc<Engine>) -> Result<FrameOutput> {
        if let Some(r) = self.ready.pop_front() {
            return Ok(self.output_of(engine, r));
        }
        let next = match &self.cached {
            Some(c) if c.pipeline.in_flight() > 0 => c.pipeline.next_result(),
            _ => bail!("in-process recv with no frame in flight"),
        };
        match next {
            Some(Ok(r)) => Ok(self.output_of(engine, r)),
            Some(Err(e)) => {
                // the pipeline closed itself on error; don't reuse it
                self.retire_pipeline();
                Err(e)
            }
            None => {
                self.retire_pipeline();
                Err(anyhow!("pipeline closed with frames in flight"))
            }
        }
    }

    fn in_flight(&self) -> usize {
        self.ready.len() + self.cached.as_ref().map_or(0, |c| c.pipeline.in_flight())
    }

    fn bandwidth_bps(&self) -> Option<f64> {
        self.estimator.bandwidth_bps()
    }

    fn report(&self) -> Option<String> {
        let mut sections: Vec<String> = self
            .retired
            .iter()
            .map(|(label, r)| format!("#### {label}\n\n{}", r.to_markdown()))
            .collect();
        if let Some(c) = &self.cached {
            sections.push(c.pipeline.report().to_markdown());
        }
        (!sections.is_empty()).then(|| sections.join("\n"))
    }

    fn close(&mut self) -> Result<()> {
        self.retire_pipeline();
        Ok(())
    }
}

/// TCP transport: the session is the edge process; the tail runs in a
/// `splitpoint serve-server` process at `addr`. Connects lazily on the
/// first frame; `pipeline_depth > 1` opens a persistent [`EdgeStream`]
/// whose in-flight window (overlap head(N+1) with the server round trip
/// of frame N) survives across the session's segment boundaries — the
/// pipe only drains when the split policy actually flips.
pub struct Tcp {
    addr: String,
    conn: TcpConn,
    opts: ClientOptions,
    /// the connected client's retry/reconnect counters (shared with the
    /// stream handle it may be converted into)
    counters: Option<Arc<LinkCounters>>,
    /// smoothed link round trip (reply latency minus server compute) from
    /// queue-free frames — the policy plane's RTT signal
    rtt: Option<SimTime>,
    estimator: BandwidthEstimator,
    /// serial-mode results completed at submit time, awaiting recv
    ready: VecDeque<(Vec<Detection>, RemoteTiming)>,
    /// streaming mode: whether each in-flight frame was submitted into an
    /// empty window (its round trip is queueing-free and safe to sample)
    queue_free: VecDeque<bool>,
}

enum TcpConn {
    Idle,
    /// serial (`depth <= 1`): one blocking round trip per frame
    Serial(EdgeClient),
    /// pipelined: persistent incremental stream handle
    Streaming(EdgeStream),
}

/// Smallest payload worth treating as a bandwidth sample (both
/// transports). Below this, transfer time is RTT/latency-dominated and
/// `bytes / elapsed` measures latency, not throughput — an edge-only
/// segment's ~9-byte empty packets would otherwise poison the EWMA with
/// sub-KB/s "bandwidth", after which the adaptive policy costs every
/// shipping split as absurdly expensive and can never escape edge-only
/// (positive feedback).
pub const MIN_BANDWIDTH_SAMPLE_BYTES: usize = 16 * 1024;

impl Tcp {
    pub fn new(addr: impl Into<String>) -> Tcp {
        Tcp::with_options(addr, ClientOptions::default())
    }

    /// TCP transport with explicit resilience knobs (Busy backoff policy,
    /// resumable sessions).
    pub fn with_options(addr: impl Into<String>, opts: ClientOptions) -> Tcp {
        Tcp {
            addr: addr.into(),
            conn: TcpConn::Idle,
            opts,
            counters: None,
            rtt: None,
            estimator: BandwidthEstimator::default(),
            ready: VecDeque::new(),
            queue_free: VecDeque::new(),
        }
    }

    /// Connect lazily, picking serial or streaming mode from the pipeline
    /// depth of the first submit. The mode is fixed for the connection's
    /// lifetime — the session never changes `pipe` mid-stream.
    fn connect(&mut self, engine: &Arc<Engine>, depth: usize) -> Result<()> {
        if matches!(self.conn, TcpConn::Idle) {
            let client =
                EdgeClient::connect_with(self.addr.as_str(), engine.clone(), self.opts.clone())
                    .with_context(|| {
                        format!("is `splitpoint serve-server` running at {}?", self.addr)
                    })?;
            self.counters = Some(client.counters());
            self.conn = if depth <= 1 {
                TcpConn::Serial(client)
            } else {
                TcpConn::Streaming(client.into_stream(depth)?)
            };
        }
        Ok(())
    }
}

impl Transport for Tcp {
    fn describe(&self) -> String {
        format!("tcp:{} (realtime)", self.addr)
    }

    fn submit(
        &mut self,
        engine: &Arc<Engine>,
        sp: SplitPoint,
        cloud: PointCloud,
        pipe: PipelineConfig,
    ) -> Result<()> {
        self.connect(engine, pipe.depth)?;
        match &mut self.conn {
            TcpConn::Idle => unreachable!("connected above"),
            TcpConn::Serial(client) => {
                if pipe.depth > 1 {
                    bail!("pipelined submit on a serial TCP connection");
                }
                // serial: one full round trip now, delivered at recv; the
                // window never queues, so every frame is sample-safe
                self.ready.push_back(client.run_frame(&cloud, sp)?);
                self.queue_free.push_back(true);
                Ok(())
            }
            TcpConn::Streaming(stream) => {
                if pipe.depth <= 1 {
                    bail!("serial submit on a streaming TCP connection");
                }
                // a frame entering an EMPTY window (first frame after
                // connect or after a policy-flip flush) sees no queueing —
                // later frames wait behind up to depth-1 frames of server
                // compute, which would deflate the bandwidth estimate
                self.queue_free.push_back(stream.in_flight() == 0);
                stream.submit(cloud, sp)
            }
        }
    }

    fn recv(&mut self, engine: &Arc<Engine>) -> Result<FrameOutput> {
        let (detections, t) = match &mut self.conn {
            TcpConn::Streaming(stream) => stream.recv()?,
            _ => self.ready.pop_front().context("tcp recv with no frame in flight")?,
        };
        let queue_free = self.queue_free.pop_front().unwrap_or(false);
        // transfer ≈ round trip minus the server's self-reported compute
        // minus both configured RTT legs — `price_splits` re-adds
        // rtt_one_way per leg, so leaving RTT inside the sample would
        // double-count it (mirrors the InProcess correction). Two further
        // filters keep the EWMA honest: RTT-dominated payloads are skipped
        // (MIN_BANDWIDTH_SAMPLE_BYTES), and queue-waiting frames are never
        // sampled (`queue_free`).
        if queue_free {
            // smoothed RTT signal for the policy plane: reply latency
            // minus the server's self-reported compute (link legs +
            // transfer), EWMA'd over queue-free frames only
            let sample = t.round_trip.saturating_sub(t.server_compute);
            self.rtt = Some(match self.rtt {
                Some(prev) => SimTime {
                    nanos: (prev.nanos * 7 + sample.nanos) / 8,
                },
                None => sample,
            });
        }
        if queue_free && t.uplink_bytes >= MIN_BANDWIDTH_SAMPLE_BYTES {
            let rtt_both_legs = SimTime::from_secs_f64(2.0 * engine.link().config().rtt_one_way);
            self.estimator.observe(
                t.uplink_bytes,
                t.round_trip
                    .saturating_sub(t.server_compute)
                    .saturating_sub(rtt_both_legs),
            );
        }
        Ok(FrameOutput {
            detections,
            uplink_bytes: t.uplink_bytes,
            uplink_v1_bytes: t.uplink_v1_bytes,
            uplink_f32_bytes: t.uplink_f32_bytes,
            uplink_v3_bytes: t.uplink_v3_bytes,
            edge_time: t.edge_compute,
            round_trip: t.round_trip,
            server_time: t.server_compute,
            inference_time: t.inference_time,
            timing: None,
        })
    }

    fn in_flight(&self) -> usize {
        match &self.conn {
            TcpConn::Streaming(stream) => stream.in_flight(),
            _ => self.ready.len(),
        }
    }

    fn needs_queue_free_samples(&self) -> bool {
        true
    }

    fn bandwidth_bps(&self) -> Option<f64> {
        self.estimator.bandwidth_bps()
    }

    fn link_health(&self) -> LinkHealth {
        let mut h = self.counters.as_ref().map(|c| c.health()).unwrap_or_default();
        h.rtt = self.rtt;
        h
    }

    fn close(&mut self) -> Result<()> {
        match std::mem::replace(&mut self.conn, TcpConn::Idle) {
            TcpConn::Idle => Ok(()),
            TcpConn::Serial(client) => client.shutdown(),
            TcpConn::Streaming(stream) => stream.shutdown(),
        }
    }
}

// -------------------------------------------------------------- policies

/// Everything a policy may consult at a re-evaluation boundary.
pub struct PolicyContext<'a> {
    pub engine: &'a Engine,
    /// profile cloud for this segment (its first frame)
    pub cloud: &'a PointCloud,
    /// frames completed so far in this session
    pub frames_done: u64,
    /// live transport bandwidth estimate (bytes/second), if any
    pub bandwidth_bps: Option<f64>,
    /// split the previous segment ran at
    pub current: Option<SplitPoint>,
    /// frames still inside the transport's window at this boundary — on a
    /// continuous stream this stays above zero across every boundary that
    /// doesn't flip the split (pinned by `rust/tests/session.rs`)
    pub in_flight: usize,
    /// link-resilience telemetry from [`Transport::link_health`]: retries,
    /// reconnects, backoff/stall time, smoothed RTT
    pub health: LinkHealth,
    /// declared SLA objectives' verdict at this boundary
    /// ([`SlaEvaluator`]); empty when the session declared none — policies
    /// see *objective pressure*, not just raw link samples
    pub sla: SlaVerdict,
}

/// Decides the split point for each segment of the stream.
pub trait SplitPolicy: Send {
    /// Short name for banners/logs.
    fn describe(&self) -> String;

    /// Split for the next segment. Called once per segment boundary with
    /// fresh context; implementations may keep state (hysteresis).
    fn choose(&mut self, ctx: &PolicyContext<'_>) -> Result<SplitPoint>;

    /// Frames between re-evaluations. The session clamps this to its
    /// internal segment cap; `usize::MAX` means "never re-evaluate".
    fn interval(&self) -> usize {
        usize::MAX
    }

    /// Whether this policy consumes the live bandwidth estimate. When
    /// true, the session trades a little pipelining for telemetry: every
    /// [`RESAMPLE_BOUNDARIES`]th boundary it drains the window so the
    /// next frame's round trip is queue-free and sampleable. Policies
    /// that ignore `bandwidth_bps` keep the default `false` and their
    /// streams never drain mid-flight.
    fn wants_bandwidth(&self) -> bool {
        false
    }

    /// Human-readable reason for the most recent [`SplitPolicy::choose`]
    /// decision, recorded into the [`SegmentRecord`] that decision opens.
    /// Stateless policies keep the default (their static description);
    /// [`Adaptive`] reports *why* it switched, held, or was frozen by its
    /// cooldown.
    fn explain(&self) -> String {
        self.describe()
    }
}

/// Always the same split (the classic `--split` flag).
#[derive(Debug, Clone, Copy)]
pub struct Fixed(pub SplitPoint);

impl SplitPolicy for Fixed {
    fn describe(&self) -> String {
        "fixed".to_string()
    }

    fn choose(&mut self, _ctx: &PolicyContext<'_>) -> Result<SplitPoint> {
        Ok(self.0)
    }
}

/// Runtime-adaptive split selection: every `every` frames, re-price every
/// split under the transport's *live* bandwidth estimate (falling back to
/// the configured link model until the first transfer lands), and switch
/// only when the best split beats the current one by more than
/// `hysteresis` — flapping between near-tied splits would churn the
/// pipeline for no gain.
///
/// Cost control: re-pricing ([`adaptive::price_splits`]) is pure
/// arithmetic and runs at every re-evaluation; the expensive half
/// ([`adaptive::profile_splits`] — one full unscaled pipeline run) is
/// cached and refreshed only every `reprofile_every` evaluations, so at
/// the defaults (8 × 4) the stream pays one extra profile frame per 32
/// real frames (~3%), not one per 8.
#[derive(Debug, Clone)]
pub struct Adaptive {
    objective: Objective,
    every: usize,
    hysteresis: f64,
    reprofile_every: usize,
    cooldown: usize,
    cached_costs: Option<Vec<adaptive::SplitCosts>>,
    evals_since_profile: usize,
    /// evaluations since the last switch (saturating; MAX = never switched)
    evals_since_switch: usize,
    /// why the last `choose` call decided what it did (see
    /// [`SplitPolicy::explain`]); empty before the first evaluation
    last_explain: String,
}

impl Adaptive {
    pub fn new(objective: Objective) -> Adaptive {
        Adaptive {
            objective,
            every: 8,
            hysteresis: 0.10,
            reprofile_every: 4,
            cooldown: 0,
            cached_costs: None,
            evals_since_profile: 0,
            evals_since_switch: usize::MAX,
            last_explain: String::new(),
        }
    }

    /// Re-evaluation interval in frames (default 8).
    pub fn every(mut self, frames: usize) -> Adaptive {
        self.every = frames.max(1);
        self
    }

    /// Minimum fractional improvement required to switch (default 0.10).
    pub fn hysteresis(mut self, h: f64) -> Adaptive {
        self.hysteresis = h.max(0.0);
        self
    }

    /// Evaluations between fresh profile runs (default 4; 1 = re-profile
    /// at every re-evaluation).
    pub fn reprofile_every(mut self, evals: usize) -> Adaptive {
        self.reprofile_every = evals.max(1);
        self
    }

    /// Refuse another flip for `evals` evaluations after a switch
    /// (default 0 = disabled). Every switch flushes the transport's
    /// in-flight window and (in-process) respawns the staged pipeline, so
    /// a cooldown bounds how often a noisy bandwidth estimate can pay
    /// that cost even when each flip individually clears the hysteresis
    /// margin.
    pub fn cooldown(mut self, evals: usize) -> Adaptive {
        self.cooldown = evals;
        self
    }
}

impl SplitPolicy for Adaptive {
    fn describe(&self) -> String {
        let obj = match self.objective {
            Objective::InferenceTime => "inference-time",
            Objective::EdgeTime => "edge-time",
        };
        format!("adaptive({obj}, every {} frame(s))", self.every)
    }

    fn choose(&mut self, ctx: &PolicyContext<'_>) -> Result<SplitPoint> {
        let link = match ctx.bandwidth_bps {
            Some(bps) if bps > 0.0 => ctx.engine.link().with_bandwidth(bps),
            _ => ctx.engine.link().clone(),
        };
        // refresh the (expensive) profile only every Nth evaluation; the
        // per-evaluation work is the pure-arithmetic re-pricing below
        if self.cached_costs.is_none() || self.evals_since_profile >= self.reprofile_every {
            self.cached_costs = Some(adaptive::profile_splits(ctx.engine, ctx.cloud)?);
            self.evals_since_profile = 0;
        }
        self.evals_since_profile += 1;
        let costs = self.cached_costs.as_ref().expect("profiled above");
        let estimates = adaptive::price_splits(costs, &link);
        let best = adaptive::best_estimate(&estimates, self.objective);
        // degraded-link preference: while an SLA objective is breached or
        // the measured RTT sits well above the configured link's, prefer
        // the smallest-uplink split among those within the hysteresis band
        // of the optimum — shipping fewer bytes is the edge's only lever
        // against a sick wire, and inside the band the cost difference is
        // below the threshold the policy considers meaningful anyway
        let baseline_rtt = (2.0 * ctx.engine.link().config().rtt_one_way).max(1e-3);
        let rtt_inflated = ctx
            .health
            .rtt
            .is_some_and(|rtt| rtt.as_secs_f64() > DEGRADED_RTT_FACTOR * baseline_rtt);
        let degraded = ctx.sla.any_breached() || rtt_inflated;
        let best = if degraded {
            let band = SimTime::from_secs_f64(
                self.objective.cost(best).as_secs_f64() * (1.0 + self.hysteresis),
            );
            estimates
                .iter()
                .filter(|e| self.objective.cost(e) <= band)
                .min_by_key(|e| (e.uplink_bytes, self.objective.cost(e)))
                .unwrap_or(best)
        } else {
            best
        };
        let best_ms = self.objective.cost(best).as_secs_f64() * 1e3;
        let bw = match ctx.bandwidth_bps {
            Some(bps) if bps > 0.0 => format!("{:.2} MB/s measured", bps / 1e6),
            _ => "configured link model".to_string(),
        };
        // hysteresis against the split the session actually ran last
        // segment (`ctx.current` — the policy keeps no shadow copy)
        let desired = match ctx.current {
            Some(cur) if cur != best.split => {
                let cur_cost = estimates
                    .iter()
                    .find(|e| e.split == cur)
                    .map(|e| self.objective.cost(e).as_secs_f64());
                match cur_cost {
                    // switch only past the hysteresis margin
                    Some(cc)
                        if self.objective.cost(best).as_secs_f64()
                            < cc * (1.0 - self.hysteresis) =>
                    {
                        self.last_explain = format!(
                            "switched: best prices {best_ms:.2} ms vs current \
                             {:.2} ms, beating the {:.0}% hysteresis ({bw})",
                            cc * 1e3,
                            self.hysteresis * 100.0
                        );
                        best.split
                    }
                    Some(cc) => {
                        self.last_explain = format!(
                            "held: best prices {best_ms:.2} ms vs current {:.2} ms, \
                             within the {:.0}% hysteresis ({bw})",
                            cc * 1e3,
                            self.hysteresis * 100.0
                        );
                        cur
                    }
                    None => {
                        self.last_explain =
                            "switched: current split missing from estimates".to_string();
                        best.split
                    }
                }
            }
            Some(_) => {
                self.last_explain =
                    format!("held: best split already current at {best_ms:.2} ms ({bw})");
                best.split
            }
            None => {
                self.last_explain =
                    format!("initial pick: cheapest split prices {best_ms:.2} ms ({bw})");
                best.split
            }
        };
        // cooldown: a recent switch freezes the policy at the current
        // split for `cooldown` further evaluations
        let chosen = match ctx.current {
            Some(cur) if desired != cur && self.evals_since_switch < self.cooldown => {
                self.last_explain = format!(
                    "held by cooldown: switch wanted but only {} of {} evaluations \
                     have passed since the last flip",
                    self.evals_since_switch, self.cooldown
                );
                cur
            }
            _ => desired,
        };
        if ctx.current.is_some_and(|cur| chosen != cur) {
            self.evals_since_switch = 0;
        } else {
            self.evals_since_switch = self.evals_since_switch.saturating_add(1);
        }
        if degraded {
            let cause = if ctx.sla.any_breached() {
                "SLA breached"
            } else {
                "RTT inflated"
            };
            self.last_explain.push_str(&format!(
                " [degraded ({cause}): preferring smallest uplink within the hysteresis band]"
            ));
        }
        if !ctx.health.is_clean() {
            // surface the fault telemetry the decision was made under —
            // degradation shows up in the segment records, not just stats
            self.last_explain.push_str(&format!(
                " [link degraded: {} retry(ies), {} reconnect(s)]",
                ctx.health.retries, ctx.health.reconnects
            ));
        }
        Ok(chosen)
    }

    fn interval(&self) -> usize {
        self.every
    }

    fn wants_bandwidth(&self) -> bool {
        true
    }

    fn explain(&self) -> String {
        if self.last_explain.is_empty() {
            self.describe()
        } else {
            self.last_explain.clone()
        }
    }
}

// --------------------------------------------------------------- session

/// One delivered frame: session sequencing, provenance, the split it ran
/// at, and the transport's output.
#[derive(Debug, Clone)]
pub struct SessionFrame {
    /// dense session-wide sequence number (delivery order)
    pub seq: u64,
    /// source-assigned sequence (replay position, scan index, …)
    pub source_seq: u64,
    pub sensor_id: u32,
    /// points in the input cloud
    pub points: usize,
    pub split: SplitPoint,
    pub split_label: String,
    pub output: FrameOutput,
}

/// One contiguous run of frames at a single split: opened whenever the
/// policy's decision actually changes the split (the stream's first
/// boundary included), closed by the next flip or end of stream. The
/// policy boundaries *between* flips — where the decision held — extend
/// the open record's frame count rather than opening a new one.
#[derive(Debug, Clone)]
pub struct SegmentRecord {
    /// 0-based position in stream order
    pub index: usize,
    pub split: SplitPoint,
    pub split_label: String,
    /// frames submitted while this segment was the open one
    pub frames: usize,
    /// the policy's [`SplitPolicy::explain`] at the boundary that opened
    /// this segment — for [`Adaptive`], why it flipped
    pub reason: String,
}

/// End-of-stream accounting.
#[derive(Debug, Clone, Default)]
pub struct SessionReport {
    pub frames: usize,
    pub wall: Duration,
    /// split changes the policy made mid-stream
    pub switches: usize,
    /// frames executed per split label
    pub split_usage: BTreeMap<String, usize>,
    /// frames delivered per sensor id (multi-sensor fan-in tagging; a
    /// single-sensor stream has one entry for sensor 0)
    pub sensor_usage: BTreeMap<u32, usize>,
    /// transport's final bandwidth estimate
    pub bandwidth_bps: Option<f64>,
    /// total uplink bytes actually shipped (wire v2, or v3 when the
    /// session runs a lossy `--wire` precision)
    pub uplink_bytes: usize,
    /// what the same stream would have cost under the v1 framing
    pub uplink_v1_bytes: usize,
    /// what the same stream costs at exact f32 / v2 framing — equals
    /// `uplink_bytes` on f32 sessions; the quant-savings baseline on
    /// f16/int8 sessions
    pub uplink_f32_bytes: usize,
    /// total bytes shipped under v3 quantized framing (0 on f32 sessions)
    pub uplink_v3_bytes: usize,
    /// staged-pipeline stage/queue report, when the transport kept one
    pub transport_report: Option<String>,
    /// per-segment policy decisions in stream order (`run --report`)
    pub segments: Vec<SegmentRecord>,
    /// link-resilience telemetry at end of stream (all-zero on a clean
    /// link or a linkless transport)
    pub link_health: LinkHealth,
    /// declared SLA objectives' final verdict; `None` when the session
    /// declared none ([`SplitSessionBuilder::sla_specs`])
    pub sla: Option<SlaVerdict>,
}

impl SessionReport {
    /// Markdown table of per-segment policy decisions, or `None` for an
    /// empty stream. Printed by `run --report`.
    pub fn segments_table(&self) -> Option<String> {
        use std::fmt::Write as _;
        if self.segments.is_empty() {
            return None;
        }
        let mut s = String::from("| seg | split | frames | policy reason |\n|---|---|---|---|\n");
        for seg in &self.segments {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} |",
                seg.index, seg.split_label, seg.frames, seg.reason
            );
        }
        Some(s)
    }

    /// Render the process-wide telemetry registry in Prometheus text
    /// exposition format — the offline analogue of `serve-server
    /// --metrics-addr`'s `/metrics` endpoint. The session's frame/byte
    /// counters, per-stage latency histograms, link health, and SLA state
    /// all report into [`telemetry::global`], so this is the whole run's
    /// telemetry in one scrape-shaped string.
    pub fn prometheus(&self) -> String {
        telemetry::global().render()
    }

    /// Wire bytes saved by the v2 delta framing, as a fraction of v1.
    pub fn wire_savings(&self) -> Option<f64> {
        (self.uplink_v1_bytes > 0)
            .then(|| 1.0 - self.uplink_bytes as f64 / self.uplink_v1_bytes as f64)
    }

    /// Wire bytes saved by v3 quantization, as a fraction of the same
    /// stream at exact f32 (v2 framing). `None` on f32 sessions — there
    /// is no quantized traffic to compare.
    pub fn quant_savings(&self) -> Option<f64> {
        (self.uplink_v3_bytes > 0 && self.uplink_f32_bytes > 0)
            .then(|| 1.0 - self.uplink_bytes as f64 / self.uplink_f32_bytes as f64)
    }

    /// One-paragraph human summary for CLI output.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let wall = self.wall.as_secs_f64();
        let _ = write!(
            s,
            "{} frame(s) in {:.2} s ({:.2} frames/s wall)",
            self.frames,
            wall,
            self.frames as f64 / wall.max(1e-9)
        );
        if !self.split_usage.is_empty() {
            let splits: Vec<String> = self
                .split_usage
                .iter()
                .map(|(k, v)| format!("{k}×{v}"))
                .collect();
            let _ = write!(s, "; splits {} ({} switch(es))", splits.join(", "), self.switches);
        }
        if self.sensor_usage.len() > 1 {
            let sensors: Vec<String> = self
                .sensor_usage
                .iter()
                .map(|(k, v)| format!("s{k}×{v}"))
                .collect();
            let _ = write!(s, "; sensors {}", sensors.join(", "));
        }
        if let Some(bps) = self.bandwidth_bps {
            let _ = write!(s, "; est. bandwidth {:.2} MB/s", bps / 1e6);
        }
        if let Some(quant) = self.quant_savings() {
            let _ = write!(
                s,
                "; uplink {:.2} MB (wire v3 quantized; f32 would be {:.2} MB, \
                 {:.1}% saved; v1 would be {:.2} MB)",
                self.uplink_bytes as f64 / 1e6,
                self.uplink_f32_bytes as f64 / 1e6,
                quant * 100.0,
                self.uplink_v1_bytes as f64 / 1e6,
            );
        } else if let Some(savings) = self.wire_savings() {
            let _ = write!(
                s,
                "; uplink {:.2} MB (wire v2; v1 would be {:.2} MB, {:.1}% saved)",
                self.uplink_bytes as f64 / 1e6,
                self.uplink_v1_bytes as f64 / 1e6,
                savings * 100.0
            );
        }
        if !self.link_health.is_clean() {
            let _ = write!(
                s,
                "; link: {} retry(ies), {} reconnect(s), {:.1} ms stalled",
                self.link_health.retries,
                self.link_health.reconnects,
                self.link_health.stall_time.as_millis_f64()
            );
        }
        s
    }
}

/// The facade: source → policy → transport, as one continuous stream — a
/// bounded feeder thread reads ahead of compute and the transport's
/// in-flight window only drains on a split flip. Build one with
/// [`SplitSession::builder`].
pub struct SplitSession {
    engine: Arc<Engine>,
    source: Box<dyn FrameSource>,
    transport: Box<dyn Transport>,
    policy: Box<dyn SplitPolicy>,
    pipe: PipelineConfig,
    frames_done: u64,
    telemetry: SessionTelemetry,
}

/// The session's pre-interned [`telemetry::global`] handles plus the
/// optional SLA evaluator — registered once at build time, so the
/// per-frame cost is relaxed atomic adds (plus plain field adds for the
/// SLA window) on the delivery path.
struct SessionTelemetry {
    frames: Arc<telemetry::Counter>,
    uplink_bytes: Arc<telemetry::Counter>,
    uplink_v1_bytes: Arc<telemetry::Counter>,
    uplink_v3_bytes: Arc<telemetry::Counter>,
    sla: Option<SlaEvaluator>,
}

impl SessionTelemetry {
    fn new(sla_specs: Vec<SlaSpec>) -> SessionTelemetry {
        let reg = telemetry::global();
        SessionTelemetry {
            frames: reg.counter(
                "sp_session_frames_total",
                "Frames delivered by the client session.",
                &[],
            ),
            uplink_bytes: reg.counter(
                "sp_session_uplink_bytes_total",
                "Uplink bytes actually shipped (wire v2).",
                &[],
            ),
            uplink_v1_bytes: reg.counter(
                "sp_session_uplink_v1_bytes_total",
                "What the same stream would have cost under the v1 framing.",
                &[],
            ),
            uplink_v3_bytes: reg.counter(
                "sp_session_uplink_v3_bytes_total",
                "Uplink bytes shipped under the v3 quantized framing \
                 (zero on f32 sessions).",
                &[],
            ),
            sla: (!sla_specs.is_empty()).then(|| SlaEvaluator::new(sla_specs, reg)),
        }
    }
}

impl SplitSession {
    pub fn builder() -> SplitSessionBuilder {
        SplitSessionBuilder::new()
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Banner line describing the assembled session.
    pub fn describe(&self) -> String {
        format!(
            "source: {} | transport: {} | policy: {} | depth {} x{} tail(s), \
             {} kernel thread(s), simd {}",
            self.source.describe(),
            self.transport.describe(),
            self.policy.describe(),
            self.pipe.depth,
            self.pipe.tail_workers,
            self.engine.runtime().threads(),
            self.engine.runtime().simd_dispatch(),
        )
    }

    /// Run the stream to exhaustion, delivering each frame to `on_frame`
    /// in order. The transport is closed on every exit path — a source or
    /// transport error still sends the TCP shutdown / drains the pipeline
    /// before the error propagates.
    pub fn run_with<F: FnMut(SessionFrame)>(&mut self, mut on_frame: F) -> Result<SessionReport> {
        let t0 = Instant::now();
        let mut report = SessionReport::default();
        let run_res = self.run_loop(&mut on_frame, &mut report);
        report.link_health = self.transport.link_health();
        // final SLA evaluation over whatever window remains, then publish
        // the link + runtime totals into the process-wide registry
        if let Some(sla) = self.telemetry.sla.as_mut() {
            report.sla = Some(sla.evaluate(&report.link_health));
        }
        publish_global_telemetry(self.engine.as_ref(), &report.link_health);
        let close_res = self.transport.close();
        report.transport_report = self.transport.report();
        report.bandwidth_bps = self.transport.bandwidth_bps();
        report.wall = t0.elapsed();
        run_res?;
        close_res?;
        Ok(report)
    }

    /// The continuous streaming loop behind [`SplitSession::run_with`].
    ///
    /// A bounded feeder thread pulls frames from the [`FrameSource`]
    /// ([`FEED_AHEAD`] read-ahead), so source I/O overlaps
    /// head/transfer/tail compute across segment boundaries. The main
    /// loop re-evaluates the policy every `interval` frames and keeps the
    /// transport's in-flight window at `pipeline_depth`; the window is
    /// only drained when the policy actually flips the split (or the
    /// stream ends) — never at a mere segment boundary.
    fn run_loop(
        &mut self,
        on_frame: &mut dyn FnMut(SessionFrame),
        report: &mut SessionReport,
    ) -> Result<()> {
        let interval = self.policy.interval().max(1).min(SEGMENT_MAX);
        // the telemetry drain costs a window flush — pay it only when the
        // policy consumes bandwidth AND this transport cannot sample a
        // full window honestly (TCP; the virtual clock samples every frame)
        let resample = self.policy.wants_bandwidth() && self.transport.needs_queue_free_samples();
        let window = self.pipe.depth.max(1);
        let pipe = self.pipe;
        let engine = self.engine.clone();
        let source = &mut self.source;
        let transport = &mut self.transport;
        let policy = &mut self.policy;
        let frames_done = &mut self.frames_done;
        let telem = &mut self.telemetry;

        std::thread::scope(|s| -> Result<()> {
            // the channel lives inside the scope body: when the main loop
            // exits early (an error), `feed_rx` drops before the scope
            // joins the feeder, so a feeder blocked on a full channel
            // fails its send and exits instead of deadlocking the join
            let (feed_tx, feed_rx) = std::sync::mpsc::sync_channel::<Result<Frame>>(FEED_AHEAD);
            s.spawn(move || {
                loop {
                    match source.next_frame() {
                        Ok(Some(f)) => {
                            if feed_tx.send(Ok(f)).is_err() {
                                break; // consumer bailed
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let _ = feed_tx.send(Err(e));
                            break;
                        }
                    }
                }
                // feed_tx drops here: the main loop sees end-of-stream
            });

            let mut pending: VecDeque<PendingMeta> = VecDeque::new();
            let mut current_sp: Option<SplitPoint> = None;
            let mut current_label = String::new();
            let mut into_segment = 0usize;
            let mut boundaries = 0usize;
            loop {
                let frame = match feed_rx.recv() {
                    Ok(Ok(f)) => f,
                    Ok(Err(e)) => return Err(e.context("frame source failed mid-stream")),
                    Err(_) => break, // source exhausted
                };

                // ---- segment boundary: the policy decides the next split
                if into_segment == 0 {
                    boundaries += 1;
                    // periodic telemetry drain for bandwidth-consuming
                    // policies: the frame submitted next enters an empty
                    // window, so its round trip is a clean sample
                    if resample && boundaries % RESAMPLE_BOUNDARIES == 0 {
                        while transport.in_flight() > 0 {
                            deliver_one(
                                &engine,
                                &mut **transport,
                                &mut pending,
                                frames_done,
                                telem,
                                report,
                                on_frame,
                            )?;
                        }
                    }
                    let health = transport.link_health();
                    // fold the frames since the last boundary into the SLA
                    // verdict the policy sees alongside raw link health
                    let sla = match telem.sla.as_mut() {
                        Some(s) => s.evaluate(&health),
                        None => SlaVerdict::default(),
                    };
                    let ctx = PolicyContext {
                        engine: &*engine,
                        cloud: &frame.cloud,
                        frames_done: *frames_done,
                        bandwidth_bps: transport.bandwidth_bps(),
                        current: current_sp,
                        in_flight: transport.in_flight(),
                        health,
                        sla,
                    };
                    let sp = policy.choose(&ctx)?;
                    if current_sp.is_some_and(|c| c != sp) {
                        // flush: every in-flight frame still runs (and is
                        // delivered) at the split it was submitted under
                        while transport.in_flight() > 0 {
                            deliver_one(
                                &engine,
                                &mut **transport,
                                &mut pending,
                                frames_done,
                                telem,
                                report,
                                on_frame,
                            )?;
                        }
                        report.switches += 1;
                    }
                    if current_sp != Some(sp) {
                        current_label = engine.graph().split_label(sp);
                        report.segments.push(SegmentRecord {
                            index: report.segments.len(),
                            split: sp,
                            split_label: current_label.clone(),
                            frames: 0,
                            reason: policy.explain(),
                        });
                    }
                    current_sp = Some(sp);
                }
                let sp = current_sp.expect("split chosen at segment start");

                // ---- keep the window at `depth`, then submit
                while transport.in_flight() >= window {
                    deliver_one(
                        &engine,
                        &mut **transport,
                        &mut pending,
                        frames_done,
                        telem,
                        report,
                        on_frame,
                    )?;
                }
                pending.push_back(PendingMeta {
                    sensor_id: frame.sensor_id,
                    source_seq: frame.seq,
                    points: frame.cloud.len(),
                    split: sp,
                    label: current_label.clone(),
                });
                transport.submit(&engine, sp, frame.cloud, pipe)?;
                *report.split_usage.entry(current_label.clone()).or_default() += 1;
                if let Some(seg) = report.segments.last_mut() {
                    seg.frames += 1;
                }
                into_segment = (into_segment + 1) % interval;
            }

            // ---- end of stream: drain the window
            while transport.in_flight() > 0 {
                deliver_one(
                    &engine,
                    &mut **transport,
                    &mut pending,
                    frames_done,
                    telem,
                    report,
                    on_frame,
                )?;
            }
            Ok(())
        })
    }

    /// [`SplitSession::run_with`], collecting every frame.
    pub fn run(&mut self) -> Result<(Vec<SessionFrame>, SessionReport)> {
        let mut frames = Vec::new();
        let report = self.run_with(|f| frames.push(f))?;
        Ok((frames, report))
    }
}

/// Provenance of one submitted-but-undelivered frame: everything the
/// session needs to wrap the transport's eventual [`FrameOutput`] into a
/// [`SessionFrame`]. Transports deliver in submission order, so a FIFO
/// deque of these stays aligned with `Transport::recv`.
struct PendingMeta {
    sensor_id: u32,
    source_seq: u64,
    points: usize,
    split: SplitPoint,
    label: String,
}

/// Deliver the transport's next completed frame to `on_frame`, folding it
/// into the running report, the registry counters, and the SLA window.
fn deliver_one(
    engine: &Arc<Engine>,
    transport: &mut dyn Transport,
    pending: &mut VecDeque<PendingMeta>,
    frames_done: &mut u64,
    telem: &mut SessionTelemetry,
    report: &mut SessionReport,
    on_frame: &mut dyn FnMut(SessionFrame),
) -> Result<()> {
    let output = transport.recv(engine)?;
    let meta = pending
        .pop_front()
        .context("transport delivered a frame with no pending meta")?;
    report.uplink_bytes += output.uplink_bytes;
    report.uplink_v1_bytes += output.uplink_v1_bytes;
    report.uplink_f32_bytes += output.uplink_f32_bytes;
    report.uplink_v3_bytes += output.uplink_v3_bytes;
    report.frames += 1;
    telem.frames.inc();
    telem.uplink_bytes.add(output.uplink_bytes as u64);
    telem.uplink_v1_bytes.add(output.uplink_v1_bytes as u64);
    telem.uplink_v3_bytes.add(output.uplink_v3_bytes as u64);
    if let Some(sla) = telem.sla.as_mut() {
        sla.observe_frame(
            output.inference_time.as_secs_f64(),
            output.uplink_bytes as u64,
            output.edge_time.as_secs_f64(),
        );
    }
    *report.sensor_usage.entry(meta.sensor_id).or_default() += 1;
    on_frame(SessionFrame {
        seq: *frames_done,
        source_seq: meta.source_seq,
        sensor_id: meta.sensor_id,
        points: meta.points,
        split: meta.split,
        split_label: meta.label,
        output,
    });
    *frames_done += 1;
    Ok(())
}

/// Publish end-of-run link and runtime telemetry into
/// [`telemetry::global`]. Counters merge monotonically
/// ([`telemetry::Counter::merge_total`]) so repeated sessions in one
/// process never double-count an externally-accumulated total; gauges are
/// last-value by nature.
fn publish_global_telemetry(engine: &Engine, health: &LinkHealth) {
    let reg = telemetry::global();
    reg.counter(
        "sp_link_retries_total",
        "Busy rejections retried after backoff.",
        &[],
    )
    .merge_total(health.retries);
    reg.counter(
        "sp_link_reconnects_total",
        "Transparent reconnect + session-resume cycles.",
        &[],
    )
    .merge_total(health.reconnects);
    reg.gauge(
        "sp_link_backoff_seconds",
        "Total time spent sleeping in retry/reconnect backoff.",
        &[],
    )
    .set(health.backoff_time.as_secs_f64());
    reg.gauge(
        "sp_link_stall_seconds",
        "Injected stall time, when a fault profile is in the path.",
        &[],
    )
    .set(health.stall_time.as_secs_f64());
    if let Some(rtt) = health.rtt {
        reg.gauge(
            "sp_link_rtt_seconds",
            "Smoothed measured round-trip time over queue-free frames.",
            &[],
        )
        .set(rtt.as_secs_f64());
    }
    let (seen, skipped) = engine.runtime().tap_stats();
    reg.counter(
        "sp_runtime_taps_seen_total",
        "Gather taps inspected by the sparse kernels.",
        &[],
    )
    .merge_total(seen);
    reg.counter(
        "sp_runtime_taps_skipped_total",
        "Gather taps skipped via per-tap occupancy masks.",
        &[],
    )
    .merge_total(skipped);
    reg.gauge("sp_runtime_threads", "Kernel pool threads.", &[])
        .set(engine.runtime().threads() as f64);
    reg.gauge(
        "sp_runtime_dispatch_info",
        "Active SIMD dispatch tier (value is always 1).",
        &[("dispatch", engine.runtime().simd_dispatch())],
    )
    .set(1.0);
}

// --------------------------------------------------------------- builder

/// Assembles a [`SplitSession`] (or just its engine / a server process)
/// from parts. Unset axes get the classic defaults: synthetic scenes,
/// in-process transport, the config's fixed split, serial depth, one
/// kernel thread.
pub struct SplitSessionBuilder {
    artifacts: PathBuf,
    config: Option<SystemConfig>,
    split: Option<String>,
    engine: Option<Arc<Engine>>,
    source: Option<Box<dyn FrameSource>>,
    transport: Option<Box<dyn Transport>>,
    policy: Option<Box<dyn SplitPolicy>>,
    depth: usize,
    tail_workers: usize,
    threads: usize,
    simd: SimdMode,
    wire: Option<WirePrecision>,
    role: EngineRole,
    sensors: usize,
    record: Option<PathBuf>,
    tcp_addr: Option<String>,
    retry_max: Option<u32>,
    resume: bool,
    fault: Option<(FaultProfile, u64)>,
    sla: Vec<SlaSpec>,
}

impl Default for SplitSessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SplitSessionBuilder {
    pub fn new() -> SplitSessionBuilder {
        SplitSessionBuilder {
            artifacts: PathBuf::from("artifacts"),
            config: None,
            split: None,
            engine: None,
            source: None,
            transport: None,
            policy: None,
            depth: 1,
            tail_workers: 1,
            threads: 1,
            simd: SimdMode::Auto,
            wire: None,
            role: EngineRole::Full,
            sensors: 1,
            record: None,
            tcp_addr: None,
            retry_max: None,
            resume: false,
            fault: None,
            sla: Vec::new(),
        }
    }

    /// Artifact directory (`make artifacts` output; default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Load the system config from a JSON file.
    pub fn config_file(mut self, path: &std::path::Path) -> Result<Self> {
        self.config = Some(SystemConfig::load(path)?);
        Ok(self)
    }

    /// Override the config's split name ("vfe", "conv2", "edge_only", …).
    /// With the default [`Fixed`] policy this is the split every frame
    /// runs at.
    pub fn split(mut self, name: &str) -> Self {
        self.split = Some(name.to_string());
        self
    }

    /// Inject a prebuilt engine (benches and tests sweeping sessions over
    /// one compiled runtime). Overrides `artifacts`/`config`/`split`/
    /// `threads`/`role` — the engine is taken as-is.
    pub fn engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Frame source (any [`FrameSource`]).
    pub fn source(mut self, source: Box<dyn FrameSource>) -> Self {
        self.source = Some(source);
        self
    }

    /// Synthetic-scene source shortcut.
    pub fn synthetic(self, seed: u64, frames: usize) -> Self {
        self.source(Box::new(SceneSource::new(seed, frames)))
    }

    /// `--source` CLI spec: `synthetic` (uses `seed`/`frames`),
    /// `kitti:<dir>`, `replay:<file>.bin`, or `replay:<corpus-dir>` (a
    /// [`RecorderSink`](crate::pointcloud::kitti::RecorderSink) corpus).
    /// `frames` caps directory sources and sets the synthetic/replay
    /// length. Honors a prior [`SplitSessionBuilder::sensors`] call by
    /// replicating the spec per sensor behind a round-robin
    /// [`MultiSource`] — set the sensor count *before* the source spec.
    pub fn source_spec(
        self,
        spec: Option<&str>,
        seed: u64,
        frames: Option<usize>,
    ) -> Result<Self> {
        let sensors = self.sensors;
        Ok(self.source(parse_source_multi(spec, seed, frames, sensors)?))
    }

    /// Multi-sensor fan-in: replicate the next `source_spec` across `n`
    /// sensors (synthetic sources get seeds `seed..seed+n`; directory and
    /// replay sources stream the same data per sensor), round-robin
    /// interleaved through the [`Batcher`](crate::coordinator::batcher::Batcher)
    /// with per-sensor frame tagging. Default 1.
    pub fn sensors(mut self, n: usize) -> Self {
        self.sensors = n.max(1);
        self
    }

    /// Record every frame the source yields into `dir` as a `.bin` +
    /// manifest replay corpus (see
    /// [`RecorderSink`](crate::pointcloud::kitti::RecorderSink)) — the
    /// inverse of `replay:<dir>`.
    pub fn record_to(mut self, dir: impl Into<PathBuf>) -> Self {
        self.record = Some(dir.into());
        self
    }

    /// `--sink` CLI spec: `record:<dir>` (see
    /// [`SplitSessionBuilder::record_to`]). `None` is a no-op.
    pub fn sink_spec(mut self, spec: Option<&str>) -> Result<Self> {
        if let Some(spec) = spec {
            match crate::util::cli::split_spec(spec) {
                ("record", Some(dir)) if !dir.is_empty() => {
                    self.record = Some(PathBuf::from(dir));
                }
                _ => bail!("unknown --sink '{spec}' (want record:<dir>)"),
            }
        }
        Ok(self)
    }

    /// Transport (any [`Transport`]). Default: [`InProcess`].
    pub fn transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// TCP transport shortcut (edge process against `serve-server`).
    /// Resolved at [`SplitSessionBuilder::build`] so later
    /// [`SplitSessionBuilder::retry_max`] / [`SplitSessionBuilder::resume`]
    /// calls still apply.
    pub fn tcp(mut self, addr: &str) -> Self {
        self.tcp_addr = Some(addr.to_string());
        self
    }

    /// Cap on Busy/reconnect retries per request for the TCP transport
    /// (default: [`RetryPolicy::default`]'s budget). `0` restores the
    /// legacy fail-fast behaviour.
    pub fn retry_max(mut self, n: u32) -> Self {
        self.retry_max = Some(n);
        self
    }

    /// Opt the TCP transport into the resumable-session handshake:
    /// reconnect after a link drop and resume with no lost or duplicated
    /// frames. Default off — the clean-path byte stream is unchanged.
    pub fn resume(mut self, on: bool) -> Self {
        self.resume = on;
        self
    }

    /// Wrap the transport in a deterministic [`FaultTransport`] replaying
    /// `profile` from `seed` (test/CI knob; default off).
    pub fn fault(mut self, profile: FaultProfile, seed: u64) -> Self {
        self.fault = Some((profile, seed));
        self
    }

    /// Declare SLA objectives (the `--sla` flag; parse a CSV spec with
    /// [`crate::telemetry::sla::parse_specs`]). They are evaluated at
    /// every policy boundary, surfaced to the policy through
    /// `PolicyContext::sla`, exported as `sp_sla_*` metrics, and reported
    /// in [`SessionReport::sla`]. Default: none.
    pub fn sla_specs(mut self, specs: Vec<SlaSpec>) -> Self {
        self.sla = specs;
        self
    }

    /// Split policy (any [`SplitPolicy`]). Default: [`Fixed`] at the
    /// config's split.
    pub fn policy(mut self, policy: Box<dyn SplitPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Adaptive-policy shortcut.
    pub fn adaptive(self, objective: Objective) -> Self {
        self.policy(Box::new(Adaptive::new(objective)))
    }

    /// Staged-pipeline depth; 1 (default) = serial.
    pub fn pipeline_depth(mut self, depth: usize) -> Self {
        self.depth = depth.max(1);
        self
    }

    /// Parallel tail stages when pipelined (default 1).
    pub fn tail_workers(mut self, n: usize) -> Self {
        self.tail_workers = n.max(1);
        self
    }

    /// Total kernel-thread budget; split across tail workers via
    /// [`PipelineConfig::kernel_threads_for`] so the two levels of
    /// parallelism compose (default 1; outputs are bit-identical at any
    /// count).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Which half of the pipeline this engine serves (default `Full`).
    pub fn role(mut self, role: EngineRole) -> Self {
        self.role = role;
        self
    }

    /// Kernel SIMD dispatch (`--simd auto|scalar|forced`; default
    /// [`SimdMode::Auto`]). Outputs are bit-identical at any setting —
    /// this only selects the instruction set the axpy micro-kernel runs
    /// on (see `runtime::simd`). Ignored when a prebuilt
    /// [`SplitSessionBuilder::engine`] is injected.
    pub fn simd(mut self, mode: SimdMode) -> Self {
        self.simd = mode;
        self
    }

    /// Wire precision for the uplink payloads (`--wire f32|f16|int8`).
    /// F32 (the default) ships byte-identical v2 frames; F16/Int8 ship
    /// v3 quantized frames. Overrides the config file's `wire` field,
    /// like [`SplitSessionBuilder::split`] overrides its split.
    pub fn wire_precision(mut self, precision: WirePrecision) -> Self {
        self.wire = Some(precision);
        self
    }

    /// Build just the engine — the thin-shell path for subcommands and
    /// benches that drive [`Engine`] directly (sweep, estimate,
    /// calibrate).
    pub fn build_engine(&self) -> Result<Arc<Engine>> {
        if let Some(engine) = &self.engine {
            return Ok(engine.clone());
        }
        let manifest = Manifest::load(&self.artifacts)?;
        let mut cfg = self.config.clone().unwrap_or_else(SystemConfig::paper);
        if let Some(split) = &self.split {
            cfg.split = split.clone();
        }
        if let Some(wire) = self.wire {
            cfg.wire = wire;
        }
        let tails = if self.depth > 1 { self.tail_workers } else { 1 };
        let kernel = PipelineConfig::kernel_threads_for(self.threads, tails);
        let runtime = Arc::new(XlaRuntime::load_with(&manifest, kernel, self.simd)?);
        Ok(Arc::new(Engine::with_runtime_role(
            &manifest, cfg, runtime, self.role,
        )?))
    }

    /// Build the full session.
    pub fn build(mut self) -> Result<SplitSession> {
        let engine = self.build_engine()?;
        let policy: Box<dyn SplitPolicy> = match self.policy.take() {
            Some(p) => p,
            None => Box::new(Fixed(engine.split()?)),
        };
        let mut source = self
            .source
            .take()
            .unwrap_or_else(|| Box::new(SceneSource::new(1, 5)));
        if let Some(dir) = self.record.take() {
            source = Box::new(RecordingSource::new(source, &dir)?);
        }
        let mut transport: Box<dyn Transport> = match self.transport.take() {
            Some(t) => t,
            None => match self.tcp_addr.take() {
                Some(addr) => {
                    let opts = ClientOptions {
                        retry: match self.retry_max {
                            Some(n) => RetryPolicy {
                                max_retries: n,
                                ..RetryPolicy::default()
                            },
                            None => RetryPolicy::default(),
                        },
                        resume: self.resume,
                    };
                    Box::new(Tcp::with_options(addr, opts))
                }
                None => Box::new(InProcess::new()),
            },
        };
        if let Some((profile, seed)) = self.fault.take() {
            transport = Box::new(FaultTransport::new(transport, profile, seed));
        }
        let telemetry = SessionTelemetry::new(std::mem::take(&mut self.sla));
        Ok(SplitSession {
            engine,
            source,
            transport,
            policy,
            pipe: PipelineConfig {
                depth: self.depth,
                tail_workers: self.tail_workers,
            },
            frames_done: 0,
            telemetry,
        })
    }

    /// Build the server side of the TCP deployment.
    #[deprecated(note = "use ServerSession::builder().listen(addr).build()")]
    pub fn build_server(self, listen: &str) -> Result<Server> {
        Ok(ServerSessionBuilder::from_inner(self)
            .listen(listen)
            .build()?
            .into_server())
    }
}

// -------------------------------------------------------- server session

/// The server-process counterpart of [`SplitSession`]: a tail-role engine
/// behind a listening concurrent [`Server`], assembled by a builder
/// symmetric with the client side. The facade owns the admission and
/// teardown knobs ([`ServerConfig`]) the raw `Server::spawn_with` takes,
/// so `serve-server` and the tests stay thin shells.
///
/// ```no_run
/// use splitpoint::coordinator::session::ServerSession;
///
/// let server = ServerSession::builder()
///     .listen("0.0.0.0:7878")
///     .artifacts("artifacts")
///     .threads(4)
///     .max_sessions(8)
///     .build()?;
/// println!("serving on {}", server.addr());
/// # server.shutdown()?;
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ServerSession {
    server: Server,
}

impl ServerSession {
    pub fn builder() -> ServerSessionBuilder {
        ServerSessionBuilder::from_inner(SplitSessionBuilder::new())
    }

    /// The bound address (resolved port when `listen` used port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }

    /// Point-in-time server metrics.
    pub fn stats(&self) -> ServerStats {
        self.server.stats()
    }

    /// The metrics endpoint's bound address, when one was configured
    /// ([`ServerSessionBuilder::metrics_addr`]).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.server.metrics_addr()
    }

    /// This server's per-instance metric registry (the one the `/metrics`
    /// endpoint renders).
    pub fn registry(&self) -> Arc<telemetry::Registry> {
        self.server.registry()
    }

    /// Graceful drain (see [`Server::shutdown`]).
    pub fn shutdown(self) -> Result<()> {
        self.server.shutdown()
    }

    /// Unwrap the underlying [`Server`] handle (the deprecated
    /// `build_server` compatibility path).
    pub fn into_server(self) -> Server {
        self.server
    }
}

/// Builds a [`ServerSession`]. Engine axes (`artifacts`, `config`,
/// `threads`, `simd`, a prebuilt `engine`) mirror [`SplitSessionBuilder`];
/// the rest are the server's admission/batching/teardown knobs.
pub struct ServerSessionBuilder {
    inner: SplitSessionBuilder,
    listen: String,
    cfg: ServerConfig,
}

impl Default for ServerSessionBuilder {
    fn default() -> Self {
        ServerSession::builder()
    }
}

impl ServerSessionBuilder {
    fn from_inner(inner: SplitSessionBuilder) -> ServerSessionBuilder {
        ServerSessionBuilder {
            inner,
            listen: "127.0.0.1:7878".to_string(),
            cfg: ServerConfig::default(),
        }
    }

    /// Listen address (default `127.0.0.1:7878`; port 0 picks a free one,
    /// readable back through [`ServerSession::addr`]).
    pub fn listen(mut self, addr: &str) -> Self {
        self.listen = addr.to_string();
        self
    }

    /// Artifact directory (see [`SplitSessionBuilder::artifacts`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.inner = self.inner.artifacts(dir);
        self
    }

    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.inner = self.inner.config(cfg);
        self
    }

    /// Load the system config from a JSON file.
    pub fn config_file(mut self, path: &std::path::Path) -> Result<Self> {
        self.inner = self.inner.config_file(path)?;
        Ok(self)
    }

    /// Kernel-thread budget, split across tail lanes via
    /// [`PipelineConfig::kernel_threads_for`] when `tail_slots > 1`.
    pub fn threads(mut self, n: usize) -> Self {
        self.inner = self.inner.threads(n);
        self
    }

    /// Kernel SIMD dispatch (see [`SplitSessionBuilder::simd`]).
    pub fn simd(mut self, mode: SimdMode) -> Self {
        self.inner = self.inner.simd(mode);
        self
    }

    /// Wire precision for frames this server *originates* (raw-offload
    /// tails re-encode nothing, so this mostly matters for symmetric
    /// tooling; decode always accepts v1/v2/v3 regardless).
    pub fn wire_precision(mut self, precision: WirePrecision) -> Self {
        self.inner = self.inner.wire_precision(precision);
        self
    }

    /// Inject a prebuilt engine (tests sharing one compiled runtime).
    pub fn engine(mut self, engine: Arc<Engine>) -> Self {
        self.inner = self.inner.engine(engine);
        self
    }

    /// Concurrent session cap (see [`ServerConfig::max_sessions`]).
    pub fn max_sessions(mut self, n: usize) -> Self {
        self.cfg.max_sessions = n.max(1);
        self
    }

    /// Global pending-job cap (see [`ServerConfig::pending_cap`]).
    pub fn pending_cap(mut self, n: usize) -> Self {
        self.cfg.pending_cap = n.max(1);
        self
    }

    /// Per-session in-flight bound (see [`ServerConfig::session_window`]).
    pub fn session_window(mut self, n: usize) -> Self {
        self.cfg.session_window = n.max(1);
        self
    }

    /// Graceful-drain deadline (see [`ServerConfig::drain_timeout`]).
    pub fn drain_timeout(mut self, d: Duration) -> Self {
        self.cfg.drain_timeout = d;
        self
    }

    /// Parallel tail lanes per dispatch (see [`ServerConfig::tail_slots`]).
    pub fn tail_slots(mut self, n: usize) -> Self {
        self.cfg.tail_slots = n.max(1);
        self
    }

    /// Cross-session coalescing policy (see [`ServerConfig::batch`]).
    pub fn batch(mut self, max_frames: usize, max_wait: Duration) -> Self {
        self.cfg.batch = crate::coordinator::batcher::BatchPolicy {
            max_frames: max_frames.max(1),
            max_wait,
        };
        self
    }

    /// Periodic stderr metrics summary (see
    /// [`ServerConfig::stats_interval`]); zero disables it.
    pub fn stats_interval(mut self, d: Duration) -> Self {
        self.cfg.stats_interval = (!d.is_zero()).then_some(d);
        self
    }

    /// Serve this server's metric registry as a Prometheus `/metrics`
    /// endpoint at `addr` (see [`ServerConfig::metrics_addr`]; port 0
    /// picks a free one, readable back through
    /// [`ServerSession::metrics_addr`]).
    pub fn metrics_addr(mut self, addr: &str) -> Self {
        self.cfg.metrics_addr = Some(addr.to_string());
        self
    }

    /// Per-session resume-ledger size bound (see
    /// [`ServerConfig::resume_ledger_cap`]).
    pub fn resume_ledger_cap(mut self, n: usize) -> Self {
        self.cfg.resume_ledger_cap = n.max(1);
        self
    }

    /// Build the tail-role engine and start listening.
    pub fn build(self) -> Result<ServerSession> {
        let mut inner = self.inner.role(EngineRole::ServerTail);
        if self.cfg.tail_slots > 1 {
            // split the kernel-thread budget across the dispatch lanes the
            // same way the pipelined client splits it across tail workers
            inner = inner.pipeline_depth(2).tail_workers(self.cfg.tail_slots);
        }
        let engine = inner.build_engine()?;
        let server = Server::spawn_with(&self.listen, engine, self.cfg)?;
        Ok(ServerSession { server })
    }
}

/// Parse a `--source` spec. `None`/`"synthetic"` yields `frames`
/// (default 5) scenes from `seed`; `kitti:<dir>` streams a scan
/// directory (capped at `frames` when given); `replay:<file>.bin` replays
/// one recorded scan `frames` (default 1) times; `replay:<dir>` streams a
/// recorded corpus (a `RecorderSink` manifest directory, capped at
/// `frames` when given) with its original sensor tags and sequence
/// numbers.
pub fn parse_source(
    spec: Option<&str>,
    seed: u64,
    frames: Option<usize>,
) -> Result<Box<dyn FrameSource>> {
    let spec = spec.unwrap_or("synthetic");
    match crate::util::cli::split_spec(spec) {
        ("synthetic", None) => Ok(Box::new(SceneSource::new(seed, frames.unwrap_or(5)))),
        ("kitti", Some(dir)) => {
            let src = KittiSource::open(std::path::Path::new(dir))?;
            Ok(match frames {
                Some(n) => Box::new(src.limit(n)),
                None => Box::new(src),
            })
        }
        ("replay", Some(path)) if std::path::Path::new(path).is_dir() => {
            let src = RecordedSource::open(std::path::Path::new(path))?;
            Ok(match frames {
                Some(n) => Box::new(src.limit(n)),
                None => Box::new(src),
            })
        }
        ("replay", Some(file)) => Ok(Box::new(
            ReplaySource::from_file(std::path::Path::new(file))?
                .repeated(frames.unwrap_or(1)),
        )),
        _ => bail!(
            "unknown --source '{spec}' (want synthetic, kitti:<dir>, replay:<file>.bin, \
             or replay:<corpus-dir>)"
        ),
    }
}

/// [`parse_source`] replicated across `sensors` round-robin fan-in
/// sources (see [`SplitSessionBuilder::sensors`]); `sensors <= 1` is the
/// plain single-source parse.
pub fn parse_source_multi(
    spec: Option<&str>,
    seed: u64,
    frames: Option<usize>,
    sensors: usize,
) -> Result<Box<dyn FrameSource>> {
    if sensors <= 1 {
        return parse_source(spec, seed, frames);
    }
    let mut sources = Vec::with_capacity(sensors);
    for i in 0..sensors {
        sources.push(parse_source(spec, seed + i as u64, frames)?);
    }
    Ok(Box::new(MultiSource::round_robin(sources)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A stream that ships no bytes (e.g. a segment of empty clouds at
    /// edge-only, where no occupied site ever reaches the wire) must
    /// report "no savings measurable", not divide by zero.
    #[test]
    fn wire_savings_is_none_when_nothing_shipped() {
        let empty = SessionReport::default();
        assert_eq!(empty.uplink_v1_bytes, 0);
        assert_eq!(empty.wire_savings(), None);

        let shipped = SessionReport {
            uplink_bytes: 50,
            uplink_v1_bytes: 100,
            ..SessionReport::default()
        };
        let savings = shipped.wire_savings().expect("v1 bytes observed");
        assert!((savings - 0.5).abs() < 1e-12);
        // an all-empty stream's summary must not print a savings clause
        assert!(!empty.summary().contains("saved"));
    }

    /// `quant_savings` only reports when v3 traffic actually shipped, and
    /// measures against the f32 baseline (not v1).
    #[test]
    fn quant_savings_is_none_on_f32_sessions() {
        let f32_run = SessionReport {
            uplink_bytes: 50,
            uplink_v1_bytes: 100,
            uplink_f32_bytes: 50,
            ..SessionReport::default()
        };
        assert_eq!(f32_run.quant_savings(), None);
        assert!(f32_run.summary().contains("wire v2"));

        let quantized = SessionReport {
            uplink_bytes: 30,
            uplink_v1_bytes: 100,
            uplink_f32_bytes: 60,
            uplink_v3_bytes: 30,
            ..SessionReport::default()
        };
        let q = quantized.quant_savings().expect("v3 bytes observed");
        assert!((q - 0.5).abs() < 1e-12);
        assert!(quantized.summary().contains("wire v3 quantized"));
    }

    #[test]
    fn sink_spec_accepts_record_dirs_only() {
        assert!(SplitSession::builder().sink_spec(None).is_ok());
        let b = SplitSession::builder()
            .sink_spec(Some("record:/tmp/corpus"))
            .unwrap();
        assert_eq!(b.record.as_deref(), Some(std::path::Path::new("/tmp/corpus")));
        assert!(SplitSession::builder().sink_spec(Some("record:")).is_err());
        assert!(SplitSession::builder().sink_spec(Some("tape:/x")).is_err());
    }

    #[test]
    fn adaptive_cooldown_defaults_off() {
        let a = Adaptive::new(Objective::InferenceTime);
        assert_eq!(a.cooldown, 0);
        assert_eq!(a.evals_since_switch, usize::MAX);
    }

    #[test]
    fn segments_table_lists_policy_decisions_in_order() {
        let mut report = SessionReport::default();
        assert!(report.segments_table().is_none(), "empty stream has no table");
        report.segments.push(SegmentRecord {
            index: 0,
            split: SplitPoint { head_len: 2 },
            split_label: "conv2".to_string(),
            frames: 8,
            reason: "initial pick: cheapest split prices 0.40 ms (configured link model)"
                .to_string(),
        });
        report.segments.push(SegmentRecord {
            index: 1,
            split: SplitPoint { head_len: 0 },
            split_label: "raw".to_string(),
            frames: 24,
            reason: "switched: best prices 0.20 ms vs current 0.40 ms, beating the \
                     10% hysteresis (9.50 MB/s measured)"
                .to_string(),
        });
        let table = report.segments_table().expect("two segments recorded");
        let lines: Vec<&str> = table.lines().collect();
        assert!(lines[0].contains("policy reason"));
        assert!(lines[2].starts_with("| 0 | conv2 | 8 |"));
        assert!(lines[3].starts_with("| 1 | raw | 24 |"));
        assert!(lines[3].contains("switched"));
    }

    /// Policies without bespoke explanations fall back to their static
    /// description; `Adaptive` does too until its first evaluation.
    #[test]
    fn explain_defaults_to_describe() {
        let fixed = Fixed(SplitPoint { head_len: 3 });
        assert_eq!(fixed.explain(), fixed.describe());
        let a = Adaptive::new(Objective::InferenceTime);
        assert!(a.last_explain.is_empty());
        assert_eq!(a.explain(), a.describe());
    }

    #[test]
    fn builder_defaults_to_auto_simd() {
        let b = SplitSession::builder();
        assert_eq!(b.simd, SimdMode::Auto);
        let b = b.simd(SimdMode::Scalar);
        assert_eq!(b.simd, SimdMode::Scalar);
    }
}
