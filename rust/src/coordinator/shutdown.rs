//! Unified teardown contract for every connection-holding handle.
//!
//! [`Server`](crate::coordinator::remote::Server),
//! [`EdgeClient`](crate::coordinator::remote::EdgeClient), and
//! [`EdgeStream`](crate::coordinator::remote::EdgeStream) each used to
//! hand-roll their own drain-vs-abandon logic in `shutdown`/`Drop`. They
//! now share one two-mode contract: **drain** finishes in-flight work
//! before closing (the `shutdown()` happy path), **abort** unblocks and
//! abandons it (the `Drop` path, which must never block forever or
//! panic). Every by-value `shutdown()` convenience and every `Drop` impl
//! is a thin wrapper over [`Shutdown::shutdown_mode`].

use anyhow::Result;

/// How to tear a handle down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShutdownMode {
    /// Graceful: stop accepting new work, flush everything in flight,
    /// then close. The server bounds this with its configured
    /// `drain_timeout` and falls back to [`ShutdownMode::Abort`] when the
    /// deadline passes.
    Drain,
    /// Immediate: shut sockets both ways to unblock any stuck reader or
    /// writer, drop in-flight work, join threads. Infallible in spirit —
    /// implementations log rather than propagate where possible.
    Abort,
}

/// The common teardown surface. Implementations must be idempotent: a
/// second call (any mode) is a no-op, so `shutdown()` followed by `Drop`
/// never double-joins a thread or double-closes a socket.
pub trait Shutdown {
    /// Tear down with the given mode. `Drain` may fail (a peer died with
    /// frames in flight, the drain deadline passed); `Abort` should not.
    fn shutdown_mode(&mut self, mode: ShutdownMode) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Handle {
        drains: usize,
        aborts: usize,
        done: bool,
    }

    impl Shutdown for Handle {
        fn shutdown_mode(&mut self, mode: ShutdownMode) -> Result<()> {
            if self.done {
                return Ok(());
            }
            self.done = true;
            match mode {
                ShutdownMode::Drain => self.drains += 1,
                ShutdownMode::Abort => self.aborts += 1,
            }
            Ok(())
        }
    }

    #[test]
    fn idempotent_teardown_pattern() {
        let mut h = Handle {
            drains: 0,
            aborts: 0,
            done: false,
        };
        h.shutdown_mode(ShutdownMode::Drain).unwrap();
        // the Drop path after an explicit shutdown is a no-op
        h.shutdown_mode(ShutdownMode::Abort).unwrap();
        assert_eq!((h.drains, h.aborts), (1, 0));
    }
}
