//! Adaptive split-point selection (extension; paper §III-B chooses split
//! points offline by inspection — this automates it).
//!
//! One unscaled profile run yields per-node host times and every
//! intermediate tensor; each candidate split is then costed analytically:
//!
//!   inference(s) = Σ_head t_i·edge_slowdown + wire(s)/bw + rtt
//!                + Σ_tail t_i·server_slowdown + response(s)/bw + rtt
//!
//! which is exact for the additive virtual-clock model (validated against
//! `Engine::run_frame` in the property tests). The selector re-runs when
//! link bandwidth changes, giving the crossover behaviour Fig 6 implies.

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::link::LinkModel;
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::tensor::codec::Packet;

/// Predicted cost of one candidate split.
#[derive(Debug, Clone)]
pub struct SplitEstimate {
    pub split: SplitPoint,
    pub label: String,
    pub uplink_bytes: usize,
    pub downlink_bytes: usize,
    pub edge_time: SimTime,
    pub inference_time: SimTime,
}

/// Link-*independent* per-split costs from one profile frame: compute
/// times and wire sizes, with the link terms left unpriced. Profiling is
/// the expensive half (a full unscaled pipeline run); pricing against a
/// [`LinkModel`] is pure arithmetic — so a caller tracking a live
/// bandwidth estimate can cache this and re-price every re-evaluation,
/// re-profiling only occasionally (see `session::Adaptive`).
#[derive(Debug, Clone)]
pub struct SplitCosts {
    pub split: SplitPoint,
    pub label: String,
    pub uplink_bytes: usize,
    pub downlink_bytes: usize,
    /// false only for edge-only execution (no transfer leg at all; a
    /// split that ships an empty live set still pays the link RTT)
    pub pays_uplink: bool,
    pub pays_downlink: bool,
    pub edge_compute: SimTime,
    pub server_compute: SimTime,
}

/// Cost out every split point from a single profile frame, using the
/// engine's static link model.
pub fn estimate_splits(engine: &Engine, cloud: &PointCloud) -> Result<Vec<SplitEstimate>> {
    estimate_splits_with_link(engine, cloud, engine.link())
}

/// [`estimate_splits`] under an explicit link model — the adaptive session
/// policy passes the engine's RTT with a *live* bandwidth estimate from
/// the transport, so the analytic crossover tracks the wire instead of
/// the configured constant.
pub fn estimate_splits_with_link(
    engine: &Engine,
    cloud: &PointCloud,
    link: &LinkModel,
) -> Result<Vec<SplitEstimate>> {
    Ok(price_splits(&profile_splits(engine, cloud)?, link))
}

/// The expensive half of estimation: one unscaled profile run yielding
/// every split's compute times and wire sizes (link terms unpriced).
pub fn profile_splits(engine: &Engine, cloud: &PointCloud) -> Result<Vec<SplitCosts>> {
    let (mut store, host_times) = engine.profile_frame(cloud)?;
    let cfg = engine.config();
    let graph = engine.graph();
    let policy = cfg.codec;

    // packets share the profiled tensors by refcount; encoded_size runs
    // off each tensor's cached occupied-site index, so costing every
    // split rescans nothing
    let shared_packet = |ids: &[crate::model::graph::TensorId]| {
        Packet::from_shared(
            ids.iter()
                .map(|&id| {
                    (
                        graph.tensor_name(id).to_string(),
                        store.get(id).cloned().expect("profiled tensor present"),
                    )
                })
                .collect(),
        )
    };

    let mut costs = Vec::new();
    for sp in graph.all_splits() {
        let live = graph.live_ids(sp);
        let uplink_bytes = if live.is_empty() {
            0
        } else {
            shared_packet(live).encoded_size(policy)
        };
        let resp = graph.response_ids(sp);
        let downlink_bytes = if resp.is_empty() {
            0
        } else {
            shared_packet(resp).encoded_size(policy)
        };

        let edge_compute: SimTime = host_times[..sp.head_len]
            .iter()
            .map(|(n, d)| SimTime::from_duration(*d).scaled(cfg.edge.factor_for(n)))
            .sum();
        let server_compute: SimTime = host_times[sp.head_len..]
            .iter()
            .map(|(n, d)| SimTime::from_duration(*d).scaled(cfg.server.factor_for(n)))
            .sum();

        costs.push(SplitCosts {
            split: sp,
            label: graph.split_label(sp),
            uplink_bytes,
            downlink_bytes,
            pays_uplink: sp.head_len != graph.len(),
            pays_downlink: !resp.is_empty(),
            edge_compute,
            server_compute,
        });
    }
    // the adaptive session policy calls this on the streaming hot path:
    // hand the profile run's scatter grids back to the voxelizer pool so
    // a re-evaluation never costs the next frame a fresh dense-grid
    // allocation (every per-split packet above has been dropped by now,
    // so the grids are uniquely held)
    engine.reclaim_scratch(&mut store);
    Ok(costs)
}

/// The cheap half: price profiled costs under a link model. Pure
/// arithmetic — callable per re-evaluation with a fresh bandwidth
/// estimate at no profiling cost.
pub fn price_splits(costs: &[SplitCosts], link: &LinkModel) -> Vec<SplitEstimate> {
    costs
        .iter()
        .map(|c| {
            let uplink = if c.pays_uplink {
                link.transfer_time(c.uplink_bytes)
            } else {
                SimTime::ZERO
            };
            let downlink = if c.pays_downlink {
                link.transfer_time(c.downlink_bytes)
            } else {
                SimTime::ZERO
            };
            let edge_time = c.edge_compute + uplink;
            SplitEstimate {
                split: c.split,
                label: c.label.clone(),
                uplink_bytes: c.uplink_bytes,
                downlink_bytes: c.downlink_bytes,
                edge_time,
                inference_time: edge_time + c.server_compute + downlink,
            }
        })
        .collect()
}

/// What the selector optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// total inference latency (paper Fig 6)
    InferenceTime,
    /// edge-device busy time (paper Fig 7 / power proxy)
    EdgeTime,
}

impl Objective {
    /// The cost an estimate pays under this objective.
    pub fn cost(self, est: &SplitEstimate) -> SimTime {
        match self {
            Objective::InferenceTime => est.inference_time,
            Objective::EdgeTime => est.edge_time,
        }
    }
}

/// Cheapest estimate under an objective (panics on an empty slice — the
/// graph always has at least one split point).
pub fn best_estimate(estimates: &[SplitEstimate], objective: Objective) -> &SplitEstimate {
    estimates
        .iter()
        .min_by(|a, b| objective.cost(a).cmp(&objective.cost(b)))
        .expect("graph has at least one split point")
}

/// Pick the best split for an objective.
pub fn choose_split(
    engine: &Engine,
    cloud: &PointCloud,
    objective: Objective,
) -> Result<SplitEstimate> {
    Ok(best_estimate(&estimate_splits(engine, cloud)?, objective).clone())
}
