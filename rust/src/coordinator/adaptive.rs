//! Adaptive split-point selection (extension; paper §III-B chooses split
//! points offline by inspection — this automates it).
//!
//! One unscaled profile run yields per-node host times and every
//! intermediate tensor; each candidate split is then costed analytically:
//!
//!   inference(s) = Σ_head t_i·edge_slowdown + wire(s)/bw + rtt
//!                + Σ_tail t_i·server_slowdown + response(s)/bw + rtt
//!
//! which is exact for the additive virtual-clock model (validated against
//! `Engine::run_frame` in the property tests). The selector re-runs when
//! link bandwidth changes, giving the crossover behaviour Fig 6 implies.

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::tensor::codec::Packet;

/// Predicted cost of one candidate split.
#[derive(Debug, Clone)]
pub struct SplitEstimate {
    pub split: SplitPoint,
    pub label: String,
    pub uplink_bytes: usize,
    pub downlink_bytes: usize,
    pub edge_time: SimTime,
    pub inference_time: SimTime,
}

/// Cost out every split point from a single profile frame.
pub fn estimate_splits(engine: &Engine, cloud: &PointCloud) -> Result<Vec<SplitEstimate>> {
    let (store, host_times) = engine.profile_frame(cloud)?;
    let cfg = engine.config();
    let graph = engine.graph();
    let policy = cfg.codec;

    // packets share the profiled tensors by refcount; encoded_size runs
    // off each tensor's cached occupied-site index, so costing every
    // split rescans nothing
    let shared_packet = |ids: &[crate::model::graph::TensorId]| {
        Packet::from_shared(
            ids.iter()
                .map(|&id| {
                    (
                        graph.tensor_name(id).to_string(),
                        store.get(id).cloned().expect("profiled tensor present"),
                    )
                })
                .collect(),
        )
    };

    let mut estimates = Vec::new();
    for sp in graph.all_splits() {
        let live = graph.live_ids(sp);
        let uplink_bytes = if live.is_empty() {
            0
        } else {
            shared_packet(live).encoded_size(policy)
        };
        let resp = graph.response_ids(sp);
        let downlink_bytes = if resp.is_empty() {
            0
        } else {
            shared_packet(resp).encoded_size(policy)
        };

        let edge_compute: SimTime = host_times[..sp.head_len]
            .iter()
            .map(|(n, d)| SimTime::from_duration(*d).scaled(cfg.edge.factor_for(n)))
            .sum();
        let server_compute: SimTime = host_times[sp.head_len..]
            .iter()
            .map(|(n, d)| SimTime::from_duration(*d).scaled(cfg.server.factor_for(n)))
            .sum();

        let uplink = if sp.head_len == graph.len() {
            SimTime::ZERO
        } else {
            engine.link().transfer_time(uplink_bytes)
        };
        let downlink = if resp.is_empty() {
            SimTime::ZERO
        } else {
            engine.link().transfer_time(downlink_bytes)
        };

        let edge_time = edge_compute + uplink;
        estimates.push(SplitEstimate {
            split: sp,
            label: graph.split_label(sp),
            uplink_bytes,
            downlink_bytes,
            edge_time,
            inference_time: edge_time + server_compute + downlink,
        });
    }
    Ok(estimates)
}

/// What the selector optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// total inference latency (paper Fig 6)
    InferenceTime,
    /// edge-device busy time (paper Fig 7 / power proxy)
    EdgeTime,
}

/// Pick the best split for an objective.
pub fn choose_split(
    engine: &Engine,
    cloud: &PointCloud,
    objective: Objective,
) -> Result<SplitEstimate> {
    let estimates = estimate_splits(engine, cloud)?;
    Ok(estimates
        .into_iter()
        .min_by(|a, b| {
            let ka = match objective {
                Objective::InferenceTime => a.inference_time,
                Objective::EdgeTime => a.edge_time,
            };
            let kb = match objective {
                Objective::InferenceTime => b.inference_time,
                Objective::EdgeTime => b.edge_time,
            };
            ka.cmp(&kb)
        })
        .expect("graph has at least one split point"))
}
