//! Wire transport: length-prefixed message frames over any byte stream.
//!
//! Substrate module (no tokio offline): blocking I/O + threads. The frame
//! format is shared by the TCP edge/server pair and the in-memory loopback
//! used in tests.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

const FRAME_MAGIC: u32 = 0x5350_4652; // "SPFR"
/// Hard cap on a single frame (guards against corrupt length prefixes).
const MAX_FRAME: usize = 1 << 30;

/// Message types of the split-computing protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// edge → server: run the tail from `head_len` on this live set.
    Infer {
        request_id: u64,
        head_len: u8,
        packet: Vec<u8>,
    },
    /// server → edge: predictions plus server-side timing for metrics.
    InferResult {
        request_id: u64,
        server_nanos: u64,
        packet: Vec<u8>,
    },
    /// server → edge on failure.
    Error { request_id: u64, message: String },
    /// either direction: close the session.
    Shutdown,
    /// server → edge: admission refused — the server's global pending cap
    /// is reached. `pending` is the queue depth at refusal time, the
    /// retry hint (the request was *not* queued; resubmit after backoff).
    Busy { request_id: u64, pending: u64 },
    /// edge → server: request a metrics snapshot. Use a dedicated
    /// connection — the reply is not ordered with in-flight inference
    /// replies on a pipelined session.
    Stats,
    /// server → edge: metrics snapshot as `key=value` lines plus one
    /// `session …` row per live session.
    StatsResult { text: String },
    /// edge → server, first message of a resumable session. `token == 0`
    /// opens a new resumable session (`acked_up_to` ignored); a nonzero
    /// token resumes a parked session, and `acked_up_to` is the highest
    /// request id the client has fully delivered — the server prunes its
    /// ledger up to it. Plain (non-resumable) sessions never send this,
    /// keeping the clean-path byte stream unchanged.
    Hello { token: u64, acked_up_to: u64 },
    /// server → edge: resumable-session handshake accepted; `token` is
    /// the session token to present on reconnect.
    HelloAck { token: u64 },
}

impl Message {
    fn type_byte(&self) -> u8 {
        match self {
            Message::Infer { .. } => 1,
            Message::InferResult { .. } => 2,
            Message::Error { .. } => 3,
            Message::Shutdown => 4,
            Message::Busy { .. } => 5,
            Message::Stats => 6,
            Message::StatsResult { .. } => 7,
            Message::Hello { .. } => 8,
            Message::HelloAck { .. } => 9,
        }
    }
}

/// Write one frame.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let mut payload = Vec::new();
    match msg {
        Message::Infer {
            request_id,
            head_len,
            packet,
        } => {
            payload.extend_from_slice(&request_id.to_le_bytes());
            payload.push(*head_len);
            payload.extend_from_slice(packet);
        }
        Message::InferResult {
            request_id,
            server_nanos,
            packet,
        } => {
            payload.extend_from_slice(&request_id.to_le_bytes());
            payload.extend_from_slice(&server_nanos.to_le_bytes());
            payload.extend_from_slice(packet);
        }
        Message::Error {
            request_id,
            message,
        } => {
            payload.extend_from_slice(&request_id.to_le_bytes());
            payload.extend_from_slice(message.as_bytes());
        }
        Message::Shutdown => {}
        Message::Busy {
            request_id,
            pending,
        } => {
            payload.extend_from_slice(&request_id.to_le_bytes());
            payload.extend_from_slice(&pending.to_le_bytes());
        }
        Message::Stats => {}
        Message::StatsResult { text } => {
            payload.extend_from_slice(text.as_bytes());
        }
        Message::Hello {
            token,
            acked_up_to,
        } => {
            payload.extend_from_slice(&token.to_le_bytes());
            payload.extend_from_slice(&acked_up_to.to_le_bytes());
        }
        Message::HelloAck { token } => {
            payload.extend_from_slice(&token.to_le_bytes());
        }
    }
    w.write_all(&FRAME_MAGIC.to_le_bytes())?;
    w.write_all(&[msg.type_byte()])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame (blocking).
pub fn read_message<R: Read>(r: &mut R) -> Result<Message> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head).context("reading frame header")?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:#x}");
    }
    let ty = head[4];
    let len = u32::from_le_bytes(head[5..9].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;

    let u64_at = |off: usize| -> Result<u64> {
        payload
            .get(off..off + 8)
            .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
            .context("truncated frame")
    };
    Ok(match ty {
        1 => Message::Infer {
            request_id: u64_at(0)?,
            head_len: *payload.get(8).context("truncated Infer")?,
            packet: payload[9..].to_vec(),
        },
        2 => Message::InferResult {
            request_id: u64_at(0)?,
            server_nanos: u64_at(8)?,
            packet: payload[16..].to_vec(),
        },
        3 => Message::Error {
            request_id: u64_at(0)?,
            message: String::from_utf8_lossy(&payload[8..]).to_string(),
        },
        4 => Message::Shutdown,
        5 => Message::Busy {
            request_id: u64_at(0)?,
            pending: u64_at(8)?,
        },
        6 => Message::Stats,
        7 => Message::StatsResult {
            text: String::from_utf8_lossy(&payload).to_string(),
        },
        8 => Message::Hello {
            token: u64_at(0)?,
            acked_up_to: u64_at(8)?,
        },
        9 => Message::HelloAck { token: u64_at(0)? },
        t => bail!("unknown message type {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(msg: Message) -> Message {
        let mut buf = Vec::new();
        write_message(&mut buf, &msg).unwrap();
        read_message(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn all_messages_roundtrip() {
        for msg in [
            Message::Infer {
                request_id: 7,
                head_len: 3,
                packet: vec![1, 2, 3],
            },
            Message::InferResult {
                request_id: 7,
                server_nanos: 123_456,
                packet: vec![9; 100],
            },
            Message::Error {
                request_id: 9,
                message: "boom".into(),
            },
            Message::Shutdown,
            Message::Busy {
                request_id: 11,
                pending: 64,
            },
            Message::Stats,
            Message::StatsResult {
                text: "frames=3\nsessions_active=1\n".into(),
            },
            Message::Hello {
                token: 0,
                acked_up_to: 0,
            },
            Message::Hello {
                token: 0xdead_beef,
                acked_up_to: 41,
            },
            Message::HelloAck { token: 0xdead_beef },
        ] {
            assert_eq!(roundtrip(msg.clone()), msg);
        }
    }

    #[test]
    fn stream_of_messages() {
        let mut buf = Vec::new();
        for i in 0..5u64 {
            write_message(
                &mut buf,
                &Message::Infer {
                    request_id: i,
                    head_len: 2,
                    packet: vec![i as u8],
                },
            )
            .unwrap();
        }
        let mut cur = Cursor::new(buf);
        for i in 0..5u64 {
            match read_message(&mut cur).unwrap() {
                Message::Infer { request_id, .. } => assert_eq!(request_id, i),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Shutdown).unwrap();
        buf[0] ^= 0x55;
        assert!(read_message(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut buf = Vec::new();
        write_message(
            &mut buf,
            &Message::Infer {
                request_id: 1,
                head_len: 1,
                packet: vec![0; 64],
            },
        )
        .unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_message(&mut Cursor::new(buf)).is_err());
    }
}
