//! Pipelined multi-frame execution: overlap preprocess(N+1) with
//! transfer/tail(N).
//!
//! [`Engine::run_frame`] is the serial composition of three stage
//! functions (head → transfer → tail; see `coordinator::engine`). This
//! module runs the *same* three functions on dedicated worker threads
//! connected by bounded queues, so while frame N's tail executes on the
//! (virtual) server, frame N+1's voxelization and head compute already run
//! on the edge — the head/tail overlap SC-MII and PointSplit exploit to
//! keep both sides of a split busy.
//!
//! Invariants, pinned by `rust/tests/pipeline.rs`:
//!
//! * **Byte-identity** — pipelined per-frame output (detections, wire byte
//!   counts) is identical to serial `run_frame`, because both paths execute
//!   the identical stage functions on the identical inputs.
//! * **Submission order** — results come back in submission order at any
//!   depth and tail-worker count (a reorder buffer holds early finishers).
//! * **Bounded in-flight work** — every inter-stage queue holds at most
//!   `depth` frames; [`Pipeline::submit`] blocks when the pipeline is full
//!   (backpressure), and `close` never deadlocks: queued frames drain,
//!   blocked producers wake with an error. Note the bound covers frames
//!   *inside* the stages: completed results park in the (unbounded)
//!   reorder buffer until the consumer takes them, so a consumer that
//!   stops draining while frames keep being submitted accumulates
//!   finished `FrameResult`s — drain concurrently, as [`run_stream`]
//!   does. (Keeping the output side unbounded is what makes shutdown
//!   unconditionally deadlock-free: workers can always finish and exit.)

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::engine::{Engine, FrameResult};
use crate::metrics::{OccupancyHist, Recorder};
use crate::model::graph::SplitPoint;
use crate::pointcloud::{FrameSource, PointCloud};
use crate::telemetry;

// --------------------------------------------------------- bounded queue

/// A blocking MPMC queue with a hard capacity — the backpressure primitive
/// between pipeline stages.
///
/// `push` blocks while full and fails once the queue is closed; `pop`
/// blocks while empty and returns `None` once the queue is closed *and*
/// drained. `close` wakes every waiter, so no thread can sleep through a
/// shutdown.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

#[derive(Debug)]
struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> BoundedQueue<T> {
        assert!(cap >= 1, "queue capacity must be at least 1");
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    /// Enqueue, blocking while the queue is at capacity. Returns the item
    /// back if the queue is (or becomes, while blocked) closed.
    pub fn push(&self, item: T) -> std::result::Result<(), T> {
        let mut q = self.state.lock().unwrap();
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < self.cap {
                q.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            q = self.not_full.wait(q).unwrap();
        }
    }

    /// Dequeue, blocking while empty. Returns the item plus the queue
    /// depth *after* the pop (the occupancy sample the pipeline records);
    /// `None` once closed and drained.
    pub fn pop(&self) -> Option<(T, usize)> {
        let mut q = self.state.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                let depth = q.items.len();
                self.not_full.notify_one();
                return Some((item, depth));
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
    }

    /// No more pushes; queued items still drain. Wakes all waiters.
    pub fn close(&self) {
        let mut q = self.state.lock().unwrap();
        q.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// -------------------------------------------------------- reorder buffer

/// Restores submission order: out-of-order workers complete items as
/// they finish; the consumer always receives seq 0, 1, 2, …
///
/// Generic over the completed item: the pipeline reorders
/// `Result<FrameResult>`s for its pull-driven consumer ([`Reorder::next`]),
/// and the concurrent split server reorders per-session reply messages
/// push-driven ([`Reorder::drain_ready`]) so each TCP client sees FIFO
/// replies no matter which tail worker finished first.
#[derive(Debug)]
pub(crate) struct Reorder<T> {
    state: Mutex<ReorderState<T>>,
    ready: Condvar,
}

#[derive(Debug)]
struct ReorderState<T> {
    results: BTreeMap<u64, T>,
    next: u64,
    /// set once every stage worker has exited — every submitted frame has
    /// its entry by then
    producers_done: bool,
}

impl<T> Reorder<T> {
    pub(crate) fn new() -> Reorder<T> {
        Reorder {
            state: Mutex::new(ReorderState {
                results: BTreeMap::new(),
                next: 0,
                producers_done: false,
            }),
            ready: Condvar::new(),
        }
    }

    pub(crate) fn complete(&self, seq: u64, result: T) {
        let mut s = self.state.lock().unwrap();
        s.results.insert(seq, result);
        self.ready.notify_all();
    }

    fn finish(&self) {
        let mut s = self.state.lock().unwrap();
        s.producers_done = true;
        self.ready.notify_all();
    }

    /// Blocks until the next-in-order frame completes; `None` once the
    /// pipeline is closed and fully drained.
    fn next(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            let seq = s.next;
            if let Some(r) = s.results.remove(&seq) {
                s.next += 1;
                return Some(r);
            }
            if s.producers_done {
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    /// Non-blocking complement of [`Reorder::next`]: pop the contiguous
    /// run of in-order items that are ready *now* (possibly empty). The
    /// server's reply path calls this after every [`Reorder::complete`] —
    /// whichever worker lands the next-in-order reply flushes it and any
    /// successors it unblocked.
    pub(crate) fn drain_ready(&self) -> Vec<(u64, T)> {
        let mut s = self.state.lock().unwrap();
        let mut out = Vec::new();
        loop {
            let seq = s.next;
            match s.results.remove(&seq) {
                Some(r) => {
                    out.push((seq, r));
                    s.next += 1;
                }
                None => return out,
            }
        }
    }
}

// ------------------------------------------------------------- pipeline

/// Pipeline shape. `depth` bounds every inter-stage queue (total in-flight
/// frames ≈ 3·depth + workers); `tail_workers` parallelizes the dominant
/// tail stage — per-frame tails are independent, and the reorder buffer
/// keeps delivery in submission order.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub depth: usize,
    pub tail_workers: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            depth: 2,
            tail_workers: 1,
        }
    }
}

impl PipelineConfig {
    pub fn with_depth(depth: usize) -> PipelineConfig {
        PipelineConfig {
            depth,
            ..PipelineConfig::default()
        }
    }

    /// Split one total worker budget (the CLI's `--threads`) between this
    /// pipeline's concurrent tail stages and the per-execute kernel pool,
    /// so stage-level and kernel-level parallelism compose instead of
    /// oversubscribing: `tail_workers` tails each drive kernels on
    /// `total / tail_workers` pool threads (min 1). The division is purely
    /// a scheduling decision — outputs are bit-identical either way.
    pub fn kernel_threads_for(total_threads: usize, tail_workers: usize) -> usize {
        (total_threads.max(1) / tail_workers.max(1)).max(1)
    }
}

/// Per-stage service latency and queue occupancy, sampled live by the
/// stage workers.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    /// service time per stage: `stage/head`, `stage/transfer`, `stage/tail`
    pub stage_latency: Recorder,
    /// depth observed at each dequeue: `queue/input`, `queue/transfer`,
    /// `queue/tail`
    pub queue_occupancy: BTreeMap<String, OccupancyHist>,
    /// frames fully completed (delivered to the reorder buffer)
    pub frames: usize,
}

impl PipelineReport {
    /// Markdown rendering: stage latency table + occupancy table.
    pub fn to_markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut out = self.stage_latency.to_markdown("pipeline stage latency");
        let _ = writeln!(out, "\n### queue occupancy at dequeue\n");
        let _ = writeln!(out, "| queue | samples | mean depth | max | ≥1 waiting |");
        let _ = writeln!(out, "|---|---|---|---|---|");
        for (name, h) in &self.queue_occupancy {
            let _ = writeln!(
                out,
                "| {name} | {} | {:.2} | {} | {:.0}% |",
                h.count(),
                h.mean(),
                h.max(),
                h.fraction_at_least(1) * 100.0
            );
        }
        out
    }
}

#[derive(Debug)]
struct PipelineShared {
    latency: Mutex<Recorder>,
    occupancy: Mutex<BTreeMap<String, OccupancyHist>>,
    frames: AtomicUsize,
    /// [`telemetry::global`] handles, pre-interned at spawn and keyed by
    /// the same labels the local recorders use — the per-frame additions
    /// below are relaxed atomic ops on already-held `Arc`s
    stage_seconds: BTreeMap<&'static str, Arc<telemetry::Histogram>>,
    queue_depth: BTreeMap<&'static str, Arc<telemetry::Histogram>>,
    frames_total: Arc<telemetry::Counter>,
}

impl PipelineShared {
    fn new() -> PipelineShared {
        let reg = telemetry::global();
        let mut stage_seconds = BTreeMap::new();
        for (label, stage) in [
            ("stage/head", "head"),
            ("stage/transfer", "transfer"),
            ("stage/tail", "tail"),
        ] {
            stage_seconds.insert(
                label,
                reg.histogram(
                    "sp_stage_latency_seconds",
                    "Service time per pipeline stage (seconds).",
                    &[("stage", stage)],
                    &telemetry::latency_buckets(),
                ),
            );
        }
        let mut queue_depth = BTreeMap::new();
        for (label, queue) in [
            ("queue/input", "input"),
            ("queue/transfer", "transfer"),
            ("queue/tail", "tail"),
        ] {
            queue_depth.insert(
                label,
                reg.histogram(
                    "sp_queue_depth",
                    "Queue depth observed at each dequeue.",
                    &[("queue", queue)],
                    &telemetry::depth_buckets(),
                ),
            );
        }
        PipelineShared {
            latency: Mutex::new(Recorder::default()),
            occupancy: Mutex::new(BTreeMap::new()),
            frames: AtomicUsize::new(0),
            stage_seconds,
            queue_depth,
            frames_total: reg.counter(
                "sp_pipeline_frames_total",
                "Frames fully completed by the pipelined executor.",
                &[],
            ),
        }
    }

    fn record_latency(&self, label: &str, since: Instant) {
        let secs = since.elapsed().as_secs_f64();
        if let Some(h) = self.stage_seconds.get(label) {
            h.observe(secs);
        }
        self.latency.lock().unwrap().record(label, secs * 1e3);
    }

    fn record_occupancy(&self, queue: &str, depth: usize) {
        if let Some(h) = self.queue_depth.get(queue) {
            h.observe(depth as f64);
        }
        self.occupancy
            .lock()
            .unwrap()
            .entry(queue.to_string())
            .or_default()
            .record(depth);
    }
}

/// The staged multi-frame scheduler. Spawn once per stream; submit frames
/// (blocking on backpressure), close, and drain results in submission
/// order. All methods take `&self`, so a feeder thread and a collector
/// thread can share one `Pipeline` by reference.
pub struct Pipeline {
    input: Arc<BoundedQueue<(u64, PointCloud)>>,
    reorder: Arc<Reorder<Result<FrameResult>>>,
    shared: Arc<PipelineShared>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// next sequence number; held across the submit push so sequence
    /// numbers are dense and ordered even with concurrent submitters (a
    /// failed push consumes no seq, so the reorder stream has no gaps)
    next_seq: Mutex<u64>,
}

impl Pipeline {
    /// Spawn the stage workers: one head, one transfer, `tail_workers`
    /// tails. Frames flow head → transfer → tail through bounded queues of
    /// `depth` entries each; a stage error routes that frame's `Err`
    /// straight to the output without stalling later frames.
    pub fn spawn(engine: Arc<Engine>, sp: SplitPoint, cfg: PipelineConfig) -> Result<Pipeline> {
        if sp.head_len > engine.graph().len() {
            bail!("split {:?} beyond pipeline length", sp);
        }
        let depth = cfg.depth.max(1);
        let tail_workers = cfg.tail_workers.max(1);

        let input: Arc<BoundedQueue<(u64, PointCloud)>> = Arc::new(BoundedQueue::new(depth));
        let q_transfer = Arc::new(BoundedQueue::new(depth));
        let q_tail = Arc::new(BoundedQueue::new(depth));
        let reorder = Arc::new(Reorder::new());
        let shared = Arc::new(PipelineShared::new());
        let mut threads = Vec::with_capacity(2 + tail_workers);

        // ---- stage 1: head (voxelize + head nodes + wire encode)
        {
            let (input, out) = (input.clone(), q_transfer.clone());
            let (engine, reorder, shared) = (engine.clone(), reorder.clone(), shared.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("sp-pipe-head".into())
                    .spawn(move || {
                        while let Some(((seq, cloud), depth_seen)) = input.pop() {
                            shared.record_occupancy("queue/input", depth_seen);
                            let t0 = Instant::now();
                            match engine.head_stage(&cloud, sp) {
                                Ok(head) => {
                                    shared.record_latency("stage/head", t0);
                                    // defensive: only this worker closes
                                    // `out`, so the push cannot fail today;
                                    // an error completion still beats a
                                    // panic, which would hang the drain
                                    if out.push((seq, head)).is_err() {
                                        reorder.complete(
                                            seq,
                                            Err(anyhow!("pipeline closed mid-frame")),
                                        );
                                    }
                                }
                                Err(e) => {
                                    shared.record_latency("stage/head", t0);
                                    reorder.complete(seq, Err(e));
                                }
                            }
                        }
                        out.close();
                    })?,
            );
        }

        // ---- stage 2: transfer (virtual uplink + wire decode)
        {
            let (input, out) = (q_transfer.clone(), q_tail.clone());
            let (engine, reorder, shared) = (engine.clone(), reorder.clone(), shared.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("sp-pipe-transfer".into())
                    .spawn(move || {
                        while let Some(((seq, head), depth_seen)) = input.pop() {
                            shared.record_occupancy("queue/transfer", depth_seen);
                            let t0 = Instant::now();
                            match engine.transfer_stage(head) {
                                Ok(frame) => {
                                    shared.record_latency("stage/transfer", t0);
                                    // defensive; see the head worker
                                    if out.push((seq, frame)).is_err() {
                                        reorder.complete(
                                            seq,
                                            Err(anyhow!("pipeline closed mid-frame")),
                                        );
                                    }
                                }
                                Err(e) => {
                                    shared.record_latency("stage/transfer", t0);
                                    reorder.complete(seq, Err(e));
                                }
                            }
                        }
                        out.close();
                    })?,
            );
        }

        // ---- stage 3: tail × W (server nodes + finalize), reordered
        let live_tails = Arc::new(AtomicUsize::new(tail_workers));
        for w in 0..tail_workers {
            let input = q_tail.clone();
            let (engine, reorder, shared) = (engine.clone(), reorder.clone(), shared.clone());
            let live_tails = live_tails.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("sp-pipe-tail-{w}"))
                    .spawn(move || {
                        while let Some(((seq, frame), depth_seen)) = input.pop() {
                            shared.record_occupancy("queue/tail", depth_seen);
                            let t0 = Instant::now();
                            let result = engine.tail_stage(frame);
                            shared.record_latency("stage/tail", t0);
                            shared.frames.fetch_add(1, Ordering::Relaxed);
                            shared.frames_total.inc();
                            reorder.complete(seq, result);
                        }
                        // the head and transfer workers have already
                        // exited (their output queues closed before the
                        // tail queue drained), so the last tail worker
                        // seals the stream
                        if live_tails.fetch_sub(1, Ordering::AcqRel) == 1 {
                            reorder.finish();
                        }
                    })?,
            );
        }

        Ok(Pipeline {
            input,
            reorder,
            shared,
            threads: Mutex::new(threads),
            next_seq: Mutex::new(0),
        })
    }

    /// Submit a frame, blocking while the input queue is at capacity
    /// (backpressure). Returns the frame's sequence number; results come
    /// back in submission order via [`Pipeline::next_result`]. Errors if
    /// the pipeline is closed.
    pub fn submit(&self, cloud: PointCloud) -> Result<u64> {
        let mut next = self.next_seq.lock().unwrap();
        let seq = *next;
        match self.input.push((seq, cloud)) {
            Ok(()) => {
                *next += 1;
                Ok(seq)
            }
            Err(_) => Err(anyhow!("pipeline is closed")),
        }
    }

    /// No more frames; queued frames still drain. Idempotent.
    pub fn close(&self) {
        self.input.close();
    }

    /// Next frame result in submission order. Blocks until the frame
    /// completes; `None` once the pipeline is closed and drained. (With no
    /// outstanding frame and the pipeline still open, this blocks until
    /// another thread submits or closes — interleave with `submit`, or run
    /// the feeder on its own thread as [`run_stream`] does.)
    pub fn next_result(&self) -> Option<Result<FrameResult>> {
        self.reorder.next()
    }

    /// Frames submitted so far.
    pub fn submitted(&self) -> u64 {
        *self.next_seq.lock().unwrap()
    }

    /// Frames submitted but not yet delivered through
    /// [`Pipeline::next_result`] — still inside a stage, queued, or parked
    /// in the reorder buffer. This is the occupancy a continuous session
    /// keeps above zero across segment boundaries.
    pub fn in_flight(&self) -> usize {
        let submitted = self.submitted();
        let delivered = self.reorder.state.lock().unwrap().next;
        submitted.saturating_sub(delivered) as usize
    }

    /// Snapshot of per-stage latency and queue occupancy.
    pub fn report(&self) -> PipelineReport {
        PipelineReport {
            stage_latency: self.shared.latency.lock().unwrap().clone(),
            queue_occupancy: self.shared.occupancy.lock().unwrap().clone(),
            frames: self.shared.frames.load(Ordering::Relaxed),
        }
    }

    /// Run one batch of clouds through the (still-open) pipeline and
    /// return their results in submission order. A feeder thread submits
    /// while this thread drains, so batches larger than the queue depth
    /// cannot deadlock, and the pipeline stays warm between batches — the
    /// session's segment executor calls this once per policy interval
    /// without respawning stage workers.
    ///
    /// On a frame error the pipeline is closed (later batches would see a
    /// closed pipeline) and the first error is returned.
    pub fn run_batch(&self, clouds: Vec<PointCloud>) -> Result<Vec<FrameResult>> {
        let n = clouds.len();
        let mut out = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        std::thread::scope(|s| {
            s.spawn(move || {
                // clouds are moved into the pipeline, not cloned — the
                // caller has already given up ownership of the segment
                for cloud in clouds {
                    if self.submit(cloud).is_err() {
                        break;
                    }
                }
            });
            for _ in 0..n {
                match self.next_result() {
                    Some(Ok(r)) => out.push(r),
                    Some(Err(e)) => {
                        first_err = Some(e);
                        // unblocks the feeder if it is parked on a full
                        // input queue
                        self.close();
                        break;
                    }
                    None => {
                        first_err = Some(anyhow!("pipeline closed before batch completed"));
                        break;
                    }
                }
            }
        });
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // close + join is always safe: completed results park in the
        // (unbounded) reorder buffer, so no stage worker can block forever
        self.input.close();
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
    }
}

/// Run a whole frame stream through a pipeline: a feeder thread submits
/// every cloud (cloning out of the slice) while the caller's thread drains
/// results in submission order. Returns the per-frame results plus the
/// stage report.
pub fn run_stream(
    engine: Arc<Engine>,
    sp: SplitPoint,
    clouds: &[PointCloud],
    cfg: PipelineConfig,
) -> Result<(Vec<FrameResult>, PipelineReport)> {
    let pipeline = Pipeline::spawn(engine, sp, cfg)?;
    let mut out = Vec::with_capacity(clouds.len());
    std::thread::scope(|s| -> Result<()> {
        let p = &pipeline;
        s.spawn(move || {
            for cloud in clouds {
                if p.submit(cloud.clone()).is_err() {
                    break;
                }
            }
            p.close();
        });
        for _ in 0..clouds.len() {
            match p.next_result() {
                Some(r) => out.push(r?),
                None => bail!("pipeline ended before delivering every frame"),
            }
        }
        Ok(())
    })?;
    let report = pipeline.report();
    Ok((out, report))
}

/// Stream a [`FrameSource`] straight through a pipeline: the feeder thread
/// pulls frames (the bounded input queue backpressures the source, so a
/// KITTI directory is read no faster than the engine drains it) while the
/// caller's thread collects results in submission order.
pub fn run_source(
    engine: Arc<Engine>,
    sp: SplitPoint,
    source: &mut (dyn FrameSource + '_),
    cfg: PipelineConfig,
) -> Result<(Vec<FrameResult>, PipelineReport)> {
    let pipeline = Pipeline::spawn(engine, sp, cfg)?;
    let mut out = Vec::with_capacity(source.len_hint().unwrap_or(16));
    let mut frame_err: Option<anyhow::Error> = None;
    let source_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    std::thread::scope(|s| {
        let p = &pipeline;
        let src_err = &source_err;
        s.spawn(move || {
            loop {
                match source.next_frame() {
                    Ok(Some(frame)) => {
                        if p.submit(frame.cloud).is_err() {
                            break; // consumer bailed and closed the input
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        *src_err.lock().unwrap() = Some(e);
                        break;
                    }
                }
            }
            p.close();
        });
        while let Some(r) = p.next_result() {
            match r {
                Ok(fr) => out.push(fr),
                Err(e) => {
                    if frame_err.is_none() {
                        frame_err = Some(e);
                    }
                    // stop the feeder; queued frames still drain below
                    p.close();
                }
            }
        }
    });
    if let Some(e) = source_err.into_inner().unwrap() {
        return Err(e.context("frame source failed mid-stream"));
    }
    if let Some(e) = frame_err {
        return Err(e);
    }
    let report = pipeline.report();
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn queue_passes_items_in_order_with_occupancy() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        for i in 0..3 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 3);
        assert!(!q.is_empty());
        let (a, d0) = q.pop().unwrap();
        assert_eq!((a, d0), (0, 2));
        let (b, d1) = q.pop().unwrap();
        assert_eq!((b, d1), (1, 1));
        q.close();
        assert_eq!(q.pop(), Some((2, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_rejects_push_after_close() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.close();
        assert_eq!(q.push(7), Err(7));
    }

    #[test]
    fn queue_blocked_producer_wakes_on_close() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0u32).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(1));
        // give the producer time to block on the full queue
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(producer.join().unwrap(), Err(1));
        // the queued item still drains
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_blocked_consumer_wakes_on_close() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn queue_backpressure_bounds_depth() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(0u32).unwrap();
        q.push(1).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(20));
        // capacity held at 2 while the producer blocks
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().map(|(v, _)| v), Some(0));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop().map(|(v, _)| v), Some(1));
        assert_eq!(q.pop().map(|(v, _)| v), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn reorder_restores_submission_order() {
        let r = Reorder::new();
        let fake = |_seq: u64| -> Result<FrameResult> { Err(anyhow!("sentinel")) };
        r.complete(2, fake(2));
        r.complete(0, fake(0));
        r.complete(1, fake(1));
        r.finish();
        for _ in 0..3 {
            assert!(r.next().unwrap().is_err());
        }
        assert!(r.next().is_none());
    }

    /// The push-driven flush path the server's per-session reply routing
    /// uses: only the contiguous in-order run drains, gaps park.
    #[test]
    fn reorder_drain_ready_pops_contiguous_runs_only() {
        let r: Reorder<&'static str> = Reorder::new();
        r.complete(1, "b");
        assert!(r.drain_ready().is_empty(), "seq 0 missing: nothing ready");
        r.complete(0, "a");
        assert_eq!(r.drain_ready(), vec![(0, "a"), (1, "b")]);
        r.complete(3, "d");
        assert!(r.drain_ready().is_empty(), "seq 2 missing again");
        r.complete(2, "c");
        assert_eq!(r.drain_ready(), vec![(2, "c"), (3, "d")]);
    }

    #[test]
    fn kernel_threads_compose_with_tail_workers() {
        // budget / tails, floored, never below one kernel thread
        assert_eq!(PipelineConfig::kernel_threads_for(8, 2), 4);
        assert_eq!(PipelineConfig::kernel_threads_for(8, 3), 2);
        assert_eq!(PipelineConfig::kernel_threads_for(1, 4), 1);
        assert_eq!(PipelineConfig::kernel_threads_for(0, 0), 1);
        assert_eq!(PipelineConfig::kernel_threads_for(6, 1), 6);
    }

    #[test]
    fn report_markdown_lists_queues() {
        let mut report = PipelineReport::default();
        report
            .queue_occupancy
            .entry("queue/input".into())
            .or_default()
            .record(1);
        let md = report.to_markdown();
        assert!(md.contains("queue/input"));
        assert!(md.contains("queue occupancy"));
    }
}
