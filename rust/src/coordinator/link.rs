//! Network link model between edge device and edge server.
//!
//! The paper's transfer time is bandwidth-dominated (Fig 9 ≈ Fig 8 ÷
//! 61 MB/s); the model is `t = rtt + bytes / bandwidth`, evaluated on the
//! virtual clock. The real-TCP transport ignores this and measures actual
//! wire time instead (realtime mode).

use crate::config::LinkConfig;
use crate::metrics::SimTime;

/// Deterministic link-time calculator.
#[derive(Debug, Clone)]
pub struct LinkModel {
    cfg: LinkConfig,
}

impl LinkModel {
    pub fn new(cfg: LinkConfig) -> LinkModel {
        assert!(cfg.bandwidth_bps > 0.0, "bandwidth must be positive");
        LinkModel { cfg }
    }

    /// One-way transfer time for a payload.
    pub fn transfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(self.cfg.rtt_one_way + bytes as f64 / self.cfg.bandwidth_bps)
    }

    pub fn bandwidth_bps(&self) -> f64 {
        self.cfg.bandwidth_bps
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_point() {
        // EXPERIMENTS.md §Calibration: the default link is anchored so our
        // measured conv2 live set (~0.78 MB) crosses in the paper's 313 ms
        let link = LinkModel::new(LinkConfig::default());
        let t = link.transfer_time(780_000).as_millis_f64();
        assert!((t - 313.0).abs() < 15.0, "conv2 transfer modeled at {t:.1} ms");
    }

    #[test]
    fn monotone_in_bytes() {
        let link = LinkModel::new(LinkConfig::default());
        let mut prev = SimTime::ZERO;
        for mb in [0, 1, 2, 8, 32] {
            let t = link.transfer_time(mb * 1_000_000);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn rtt_floor() {
        let link = LinkModel::new(LinkConfig {
            bandwidth_bps: 1e9,
            rtt_one_way: 0.005,
        });
        assert!(link.transfer_time(0).as_millis_f64() >= 5.0);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_panics() {
        LinkModel::new(LinkConfig {
            bandwidth_bps: 0.0,
            rtt_one_way: 0.0,
        });
    }
}
