//! Network link model between edge device and edge server.
//!
//! The paper's transfer time is bandwidth-dominated (Fig 9 ≈ Fig 8 ÷
//! 61 MB/s); the model is `t = rtt + bytes / bandwidth`, evaluated on the
//! virtual clock. The real-TCP transport ignores this and measures actual
//! wire time instead (realtime mode).

use crate::config::LinkConfig;
use crate::metrics::SimTime;

/// Deterministic link-time calculator.
#[derive(Debug, Clone)]
pub struct LinkModel {
    cfg: LinkConfig,
}

impl LinkModel {
    pub fn new(cfg: LinkConfig) -> LinkModel {
        assert!(cfg.bandwidth_bps > 0.0, "bandwidth must be positive");
        LinkModel { cfg }
    }

    /// One-way transfer time for a payload.
    pub fn transfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(self.cfg.rtt_one_way + bytes as f64 / self.cfg.bandwidth_bps)
    }

    pub fn bandwidth_bps(&self) -> f64 {
        self.cfg.bandwidth_bps
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }

    /// A link model with this model's RTT but a different bandwidth — how
    /// the adaptive split policy folds a live transport estimate into the
    /// analytic cost model.
    pub fn with_bandwidth(&self, bandwidth_bps: f64) -> LinkModel {
        LinkModel::new(LinkConfig {
            bandwidth_bps,
            rtt_one_way: self.cfg.rtt_one_way,
        })
    }
}

/// Rolling uplink-bandwidth estimate from observed transfers (EWMA over
/// per-frame bytes/seconds). Transports feed it one sample per shipped
/// frame; the adaptive split policy reads it instead of the static
/// [`LinkModel`] so the chosen split tracks what the wire actually
/// delivers ("Split Computing for Complex Object Detectors" shows the best
/// split shifts with link bandwidth).
#[derive(Debug, Clone)]
pub struct BandwidthEstimator {
    alpha: f64,
    bps: Option<f64>,
    samples: u64,
}

impl Default for BandwidthEstimator {
    fn default() -> Self {
        BandwidthEstimator::new(0.3)
    }
}

impl BandwidthEstimator {
    /// `alpha` is the EWMA weight of the newest sample (0 < alpha <= 1).
    pub fn new(alpha: f64) -> BandwidthEstimator {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA weight out of range");
        BandwidthEstimator {
            alpha,
            bps: None,
            samples: 0,
        }
    }

    /// Record one observed transfer. Degenerate samples (no bytes, or an
    /// elapsed time too small to divide by) are ignored rather than
    /// poisoning the average.
    pub fn observe(&mut self, bytes: usize, elapsed: SimTime) {
        let secs = elapsed.as_secs_f64();
        if bytes == 0 || secs < 1e-9 {
            return;
        }
        let sample = bytes as f64 / secs;
        self.bps = Some(match self.bps {
            None => sample,
            Some(prev) => prev + self.alpha * (sample - prev),
        });
        self.samples += 1;
    }

    /// Current estimate in bytes/second; `None` until the first sample.
    pub fn bandwidth_bps(&self) -> Option<f64> {
        self.bps
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_point() {
        // EXPERIMENTS.md §Calibration: the default link is anchored so our
        // measured conv2 live set (~0.78 MB) crosses in the paper's 313 ms
        let link = LinkModel::new(LinkConfig::default());
        let t = link.transfer_time(780_000).as_millis_f64();
        assert!((t - 313.0).abs() < 15.0, "conv2 transfer modeled at {t:.1} ms");
    }

    #[test]
    fn monotone_in_bytes() {
        let link = LinkModel::new(LinkConfig::default());
        let mut prev = SimTime::ZERO;
        for mb in [0, 1, 2, 8, 32] {
            let t = link.transfer_time(mb * 1_000_000);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn rtt_floor() {
        let link = LinkModel::new(LinkConfig {
            bandwidth_bps: 1e9,
            rtt_one_way: 0.005,
        });
        assert!(link.transfer_time(0).as_millis_f64() >= 5.0);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_panics() {
        LinkModel::new(LinkConfig {
            bandwidth_bps: 0.0,
            rtt_one_way: 0.0,
        });
    }

    #[test]
    fn with_bandwidth_keeps_rtt() {
        let base = LinkModel::new(LinkConfig {
            bandwidth_bps: 1e6,
            rtt_one_way: 0.010,
        });
        let fast = base.with_bandwidth(1e9);
        assert_eq!(fast.config().rtt_one_way, 0.010);
        assert!(fast.transfer_time(1_000_000) < base.transfer_time(1_000_000));
    }

    #[test]
    fn estimator_converges_to_steady_rate() {
        let mut est = BandwidthEstimator::new(0.5);
        assert_eq!(est.bandwidth_bps(), None);
        // 2 MB/s steady stream
        for _ in 0..20 {
            est.observe(1_000_000, SimTime::from_secs_f64(0.5));
        }
        let bps = est.bandwidth_bps().unwrap();
        assert!((bps - 2e6).abs() < 1.0, "converged to {bps}");
        assert_eq!(est.samples(), 20);
    }

    #[test]
    fn estimator_tracks_a_bandwidth_drop() {
        let mut est = BandwidthEstimator::new(0.5);
        for _ in 0..5 {
            est.observe(4_000_000, SimTime::from_secs_f64(1.0));
        }
        for _ in 0..10 {
            est.observe(1_000_000, SimTime::from_secs_f64(1.0));
        }
        let bps = est.bandwidth_bps().unwrap();
        assert!(bps < 1.1e6, "EWMA follows the drop, got {bps}");
    }

    #[test]
    fn estimator_ignores_degenerate_samples() {
        let mut est = BandwidthEstimator::default();
        est.observe(0, SimTime::from_secs_f64(1.0));
        est.observe(100, SimTime::ZERO);
        assert_eq!(est.bandwidth_bps(), None);
        assert_eq!(est.samples(), 0);
    }

    #[test]
    fn estimator_first_sample_seeds_verbatim() {
        // the first sample must seed the EWMA exactly (not be blended
        // toward an implicit zero prior by alpha), whatever alpha is
        for alpha in [0.05, 0.3, 1.0] {
            let mut est = BandwidthEstimator::new(alpha);
            est.observe(3_000_000, SimTime::from_secs_f64(1.5));
            let bps = est.bandwidth_bps().expect("seeded");
            assert!(
                (bps - 2e6).abs() < 1e-6,
                "alpha {alpha}: first sample taken verbatim, got {bps}"
            );
            assert_eq!(est.samples(), 1);
        }
    }

    #[test]
    fn estimator_zero_duration_guard_is_exact_at_the_boundary() {
        // sub-nanosecond transfers are rejected (dividing by them would
        // produce absurd petabyte/s samples); anything at or above the
        // 1 ns floor is a real sample
        let mut est = BandwidthEstimator::default();
        est.observe(1_000_000, SimTime::from_secs_f64(1e-10));
        assert_eq!(est.bandwidth_bps(), None, "sub-ns elapsed rejected");
        assert_eq!(est.samples(), 0);
        est.observe(1_000_000, SimTime::from_secs_f64(1e-9));
        assert!(est.bandwidth_bps().is_some(), "1 ns floor accepted");
        assert_eq!(est.samples(), 1);
    }
}
