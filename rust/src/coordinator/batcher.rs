//! Deadline-flush batcher (paper §VI future work: "processing
//! integrated data from multiple LiDARs").
//!
//! Items from N producers land in a shared queue; a batch flushes when it
//! reaches `max_frames` items or the oldest item has waited `max_wait`.
//! Per-producer FIFO order is preserved. The batcher is generic over the
//! item type: sensor threads push [`Frame`]s into a `Batcher<Frame>` for
//! multi-LiDAR fan-in, and the concurrent split server pushes per-session
//! tail jobs into the same structure so frames from different TCP
//! connections coalesce into one tail dispatch
//! (see [`crate::coordinator::remote::Server`]).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::pointcloud::{Frame, FrameSource};

/// Flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_frames: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_frames: 4,
            max_wait: Duration::from_millis(5),
        }
    }
}

struct Queue<T> {
    frames: VecDeque<(T, Instant)>,
    closed: bool,
}

/// Thread-safe deadline-flush batcher (defaults to [`Frame`] items).
pub struct Batcher<T = Frame> {
    policy: BatchPolicy,
    q: Mutex<Queue<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        assert!(policy.max_frames > 0);
        Batcher {
            policy,
            q: Mutex::new(Queue {
                frames: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item (called by producer threads). Returns `false` when
    /// the batcher is closed and the item was dropped.
    pub fn push(&self, item: T) -> bool {
        let mut q = self.q.lock().unwrap();
        if q.closed {
            return false;
        }
        q.frames.push_back((item, Instant::now()));
        self.cv.notify_all();
        true
    }

    /// No more frames will arrive; wakes waiting consumers.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.q.lock().unwrap().frames.len()
    }

    /// Dequeue the next batch. Blocks until the policy triggers a flush or
    /// the batcher is closed; `None` means closed-and-drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut out = Vec::new();
        if self.next_batch_into(&mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Allocation-free [`Self::next_batch`]: drain the next batch into
    /// `out` (cleared first; its capacity is reused across batches, so a
    /// steady-state consumer loop allocates nothing). Returns `false` when
    /// the batcher is closed and drained.
    pub fn next_batch_into(&self, out: &mut Vec<T>) -> bool {
        out.clear();
        let mut q = self.q.lock().unwrap();
        loop {
            if q.frames.len() >= self.policy.max_frames {
                self.drain_into(&mut q, out);
                return true;
            }
            if let Some((_, t0)) = q.frames.front() {
                let age = t0.elapsed();
                if age >= self.policy.max_wait {
                    self.drain_into(&mut q, out);
                    return true;
                }
                let remaining = self.policy.max_wait - age;
                let (guard, _) = self.cv.wait_timeout(q, remaining).unwrap();
                q = guard;
            } else if q.closed {
                return false;
            } else {
                q = self.cv.wait(q).unwrap();
            }
        }
    }

    fn drain_into(&self, q: &mut Queue<T>, out: &mut Vec<T>) {
        let n = q.frames.len().min(self.policy.max_frames);
        out.extend(q.frames.drain(..n).map(|(f, _)| f));
    }
}

impl Batcher<Frame> {
    /// Pump a [`FrameSource`] into this batcher until the source is
    /// exhausted or the batcher closes (a sensor thread per source;
    /// multiple sources interleave into the shared queue). Returns the
    /// number of frames actually accepted. Does not close the batcher —
    /// the caller closes once every sensor finishes.
    ///
    /// Note the batcher's queue is **unbounded** (sensors must never
    /// block): this drains the source as fast as it produces. Real-time
    /// sources (live sensors) pace themselves; for a disk-backed source
    /// like `KittiSource`, feed from a thread that paces reads — or
    /// stream it through the bounded pipeline
    /// ([`crate::coordinator::pipeline::run_source`]) instead, which
    /// backpressures the reader.
    pub fn feed_from_source(
        &self,
        source: &mut (dyn FrameSource + '_),
    ) -> anyhow::Result<usize> {
        let mut pushed = 0;
        while let Some(frame) = source.next_frame()? {
            if !self.push(frame) {
                break; // closed mid-stream: stop reading
            }
            pushed += 1;
        }
        Ok(pushed)
    }

    /// Bridge to the staged scheduler: drain batches into `pipeline` until
    /// this batcher closes (or the pipeline does), preserving batch order.
    /// Returns the number of frames forwarded. The pipeline's input queue
    /// applies backpressure, so a slow engine throttles the drain instead
    /// of ballooning in-flight frames.
    pub fn drain_into_pipeline(&self, pipeline: &crate::coordinator::Pipeline) -> usize {
        let mut batch = Vec::new();
        let mut forwarded = 0;
        while self.next_batch_into(&mut batch) {
            for frame in batch.drain(..) {
                if pipeline.submit(frame.cloud).is_err() {
                    return forwarded;
                }
                forwarded += 1;
            }
        }
        forwarded
    }
}

/// Deterministic multi-sensor fan-in: N [`FrameSource`]s merged into one
/// stream by driving the [`Batcher`] one round-robin round at a time —
/// one frame per live sensor per round, flushed as a batch — so S
/// synchronized LiDARs interleave as `s0 s1 … sN s0 s1 …` with per-sensor
/// FIFO order intact (SC-MII's continuous multi-sensor infrastructure
/// setting, without the nondeterminism of free-running sensor threads;
/// for wall-clock-paced sensors, spawn threads over
/// [`Batcher::feed_from_source`] instead).
///
/// Frames are re-tagged with `sensor_id = source index`; each source's
/// own `seq` numbering is preserved, and both travel through the session
/// to `SessionFrame`/`SessionReport::sensor_usage`.
pub struct MultiSource {
    sources: Vec<Option<Box<dyn FrameSource>>>,
    batcher: Batcher,
    buffer: VecDeque<Frame>,
    labels: Vec<String>,
    drained: bool,
}

impl MultiSource {
    /// Round-robin fan-in over `sources` (panics on an empty list).
    pub fn round_robin(sources: Vec<Box<dyn FrameSource>>) -> MultiSource {
        assert!(!sources.is_empty(), "fan-in needs at least one source");
        let labels = sources.iter().map(|s| s.describe()).collect();
        let batcher = Batcher::new(BatchPolicy {
            max_frames: sources.len(),
            // zero wait: a fan-in round is pushed in full before the
            // batch is taken, so the flush never blocks on the clock and
            // the interleave is deterministic
            max_wait: Duration::ZERO,
        });
        MultiSource {
            sources: sources.into_iter().map(Some).collect(),
            batcher,
            buffer: VecDeque::new(),
            labels,
            drained: false,
        }
    }
}

impl FrameSource for MultiSource {
    fn next_frame(&mut self) -> anyhow::Result<Option<Frame>> {
        loop {
            if let Some(f) = self.buffer.pop_front() {
                return Ok(Some(f));
            }
            if self.drained {
                return Ok(None);
            }
            // one fan-in round: pull one frame from every live sensor
            // into the shared batcher, then take the flushed batch
            let mut pushed = 0;
            for (i, slot) in self.sources.iter_mut().enumerate() {
                let exhausted = match slot {
                    Some(src) => match src.next_frame()? {
                        Some(mut frame) => {
                            frame.sensor_id = i as u32;
                            self.batcher.push(frame);
                            pushed += 1;
                            false
                        }
                        None => true,
                    },
                    None => false,
                };
                if exhausted {
                    *slot = None;
                }
            }
            if pushed == 0 {
                self.batcher.close();
                while let Some(batch) = self.batcher.next_batch() {
                    self.buffer.extend(batch);
                }
                self.drained = true;
                continue;
            }
            if let Some(batch) = self.batcher.next_batch() {
                self.buffer.extend(batch);
            }
        }
    }

    fn len_hint(&self) -> Option<usize> {
        let mut total = self.buffer.len() + self.batcher.pending();
        for slot in self.sources.iter().flatten() {
            total += slot.len_hint()?;
        }
        Some(total)
    }

    fn describe(&self) -> String {
        format!(
            "fan-in({} sensor(s): {})",
            self.sources.len(),
            self.labels.join(" | ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::PointCloud;
    use std::sync::Arc;

    fn frame(sensor: u32, seq: u64) -> Frame {
        Frame {
            sensor_id: sensor,
            seq,
            cloud: PointCloud::default(),
        }
    }

    #[test]
    fn flushes_at_max_frames() {
        let b = Batcher::new(BatchPolicy {
            max_frames: 3,
            max_wait: Duration::from_secs(10),
        });
        for i in 0..3 {
            b.push(frame(0, i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn flushes_on_deadline() {
        let b = Batcher::new(BatchPolicy {
            max_frames: 100,
            max_wait: Duration::from_millis(20),
        });
        b.push(frame(0, 0));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(BatchPolicy {
            max_frames: 2,
            max_wait: Duration::from_millis(1),
        });
        assert!(b.push(frame(1, 0)));
        b.close();
        assert!(!b.push(frame(1, 1)), "push after close is rejected");
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn per_sensor_fifo_preserved() {
        let b = Batcher::new(BatchPolicy {
            max_frames: 6,
            max_wait: Duration::from_secs(1),
        });
        for seq in 0..3 {
            b.push(frame(0, seq));
            b.push(frame(1, seq));
        }
        let batch = b.next_batch().unwrap();
        for sensor in [0, 1] {
            let seqs: Vec<u64> = batch
                .iter()
                .filter(|f| f.sensor_id == sensor)
                .map(|f| f.seq)
                .collect();
            assert_eq!(seqs, [0, 1, 2], "sensor {sensor}");
        }
    }

    #[test]
    fn next_batch_into_reuses_buffer() {
        let b = Batcher::new(BatchPolicy {
            max_frames: 2,
            max_wait: Duration::from_millis(1),
        });
        let mut buf = Vec::new();
        for round in 0..3u64 {
            b.push(frame(0, round * 2));
            b.push(frame(0, round * 2 + 1));
            assert!(b.next_batch_into(&mut buf));
            assert_eq!(buf.len(), 2);
            assert_eq!(buf[0].seq, round * 2);
        }
        b.close();
        assert!(!b.next_batch_into(&mut buf));
        assert!(buf.is_empty(), "closed drain must clear the buffer");
    }

    #[test]
    fn feed_from_source_pushes_every_frame() {
        use crate::pointcloud::ReplaySource;
        let b = Batcher::new(BatchPolicy {
            max_frames: 2,
            max_wait: Duration::from_millis(1),
        });
        let clouds = vec![PointCloud::default(); 5];
        let mut src = ReplaySource::from_clouds(clouds);
        assert_eq!(b.feed_from_source(&mut src).unwrap(), 5);
        b.close();
        let mut total = 0;
        while let Some(batch) = b.next_batch() {
            total += batch.len();
        }
        assert_eq!(total, 5);
    }

    #[test]
    fn multi_source_round_robins_and_retags_sensors() {
        use crate::pointcloud::ReplaySource;
        let cloud_of = |n: usize| PointCloud::from_flat(&vec![1.0; n * 4]);
        // sensor 0 has 3 frames, sensor 1 has 1, sensor 2 has 2 —
        // exhausted sensors drop out of later rounds
        let mut m = MultiSource::round_robin(vec![
            Box::new(ReplaySource::from_clouds(vec![cloud_of(1), cloud_of(4), cloud_of(6)])),
            Box::new(ReplaySource::from_clouds(vec![cloud_of(2)])),
            Box::new(ReplaySource::from_clouds(vec![cloud_of(3), cloud_of(5)])),
        ]);
        assert_eq!(m.len_hint(), Some(6));
        let mut seen = Vec::new();
        while let Some(f) = m.next_frame().unwrap() {
            seen.push((f.sensor_id, f.seq, f.cloud.len()));
        }
        assert_eq!(
            seen,
            [
                (0, 0, 1),
                (1, 0, 2),
                (2, 0, 3),
                (0, 1, 4),
                (2, 1, 5),
                (0, 2, 6),
            ],
            "round-robin interleave with per-sensor seq preserved"
        );
        assert_eq!(m.len_hint(), Some(0));
        assert!(m.next_frame().unwrap().is_none(), "stays exhausted");
        assert!(m.describe().contains("3 sensor(s)"));
    }

    #[test]
    #[should_panic]
    fn multi_source_rejects_empty_source_list() {
        let _ = MultiSource::round_robin(Vec::new());
    }

    /// The batcher is generic over the item type — the server batches
    /// per-session tail jobs through the same queue the sensors use.
    #[test]
    fn batches_non_frame_items() {
        let b: Batcher<(u64, &'static str)> = Batcher::new(BatchPolicy {
            max_frames: 2,
            max_wait: Duration::from_secs(1),
        });
        assert!(b.push((1, "a")));
        assert!(b.push((2, "b")));
        assert_eq!(b.next_batch().unwrap(), vec![(1, "a"), (2, "b")]);
        b.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers() {
        let b = Arc::new(Batcher::new(BatchPolicy {
            max_frames: 40,
            max_wait: Duration::from_millis(50),
        }));
        let mut handles = Vec::new();
        for s in 0..4u32 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for seq in 0..10 {
                    b.push(frame(s, seq));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut total = 0;
        while let Some(batch) = b.next_batch() {
            total += batch.len();
        }
        assert_eq!(total, 40);
    }
}
