//! The split-computing execution engine: runs one frame through the
//! pipeline under a split point, producing detections plus the full timing
//! breakdown the paper's figures are built from.
//!
//! Compute runs for real (XLA on this host, rust for preprocess/proposal);
//! measured host time is scaled by the device profile onto the virtual
//! clock, and link time comes from the link model (DESIGN.md §3). The
//! same engine backs the in-process simulator, both ends of the TCP
//! transport, and every bench.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::link::LinkModel;
use crate::metrics::SimTime;
use crate::model::graph::{Node, NodeKind, PipelineGraph, SplitPoint, PRIMAL};
use crate::model::manifest::Manifest;
use crate::pointcloud::PointCloud;
use crate::postprocess::{assemble_predictions, Detection, ProposalConfig, ProposalStage};
use crate::runtime::XlaRuntime;
use crate::tensor::codec::Packet;
use crate::tensor::Tensor;
use crate::voxel::Voxelizer;

/// Which side of the split executed a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Edge,
    Server,
}

/// Per-frame timing breakdown (all on the virtual clock).
#[derive(Debug, Clone)]
pub struct TimingBreakdown {
    pub split_label: String,
    /// (node name, device-scaled time, side)
    pub node_times: Vec<(String, SimTime, Side)>,
    /// wire-encode / decode cost, attributed to their side
    pub encode_time: SimTime,
    pub decode_time: SimTime,
    pub uplink_bytes: usize,
    pub downlink_bytes: usize,
    pub uplink_time: SimTime,
    pub downlink_time: SimTime,
    /// paper Fig 6: start of inference → predictions back on the edge
    pub inference_time: SimTime,
    /// paper Fig 7: start of inference → end of edge→server transfer
    pub edge_time: SimTime,
}

impl TimingBreakdown {
    pub fn edge_compute(&self) -> SimTime {
        self.node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Edge)
            .map(|(_, t, _)| *t)
            .sum()
    }

    pub fn server_compute(&self) -> SimTime {
        self.node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Server)
            .map(|(_, t, _)| *t)
            .sum()
    }

    pub fn node_time(&self, name: &str) -> Option<SimTime> {
        self.node_times
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, t, _)| *t)
    }
}

/// Result of one frame.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub detections: Vec<Detection>,
    pub timing: TimingBreakdown,
}

/// The engine: everything needed to run any split of the pipeline.
pub struct Engine {
    runtime: Arc<XlaRuntime>,
    graph: PipelineGraph,
    voxelizer: Voxelizer,
    proposal: ProposalStage,
    link: LinkModel,
    cfg: SystemConfig,
}

impl Engine {
    pub fn new(manifest: &Manifest, cfg: SystemConfig) -> Result<Engine> {
        let runtime = Arc::new(XlaRuntime::load(manifest)?);
        Self::with_runtime(manifest, cfg, runtime)
    }

    /// Share one XLA runtime across engines (benches sweep configs without
    /// recompiling artifacts).
    pub fn with_runtime(
        manifest: &Manifest,
        cfg: SystemConfig,
        runtime: Arc<XlaRuntime>,
    ) -> Result<Engine> {
        let graph = PipelineGraph::from_manifest(manifest)?;
        let voxelizer = Voxelizer::from_config(&manifest.config);
        let proposal = ProposalStage::new(
            &manifest.config,
            ProposalConfig {
                nms_iou: cfg.nms_iou,
                ..ProposalConfig::default()
            },
        );
        let link = LinkModel::new(cfg.link.clone());
        Ok(Engine {
            runtime,
            graph,
            voxelizer,
            proposal,
            link,
            cfg,
        })
    }

    pub fn graph(&self) -> &PipelineGraph {
        &self.graph
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn link(&self) -> &LinkModel {
        &self.link
    }

    pub fn runtime(&self) -> &Arc<XlaRuntime> {
        &self.runtime
    }

    pub fn split(&self) -> Result<SplitPoint> {
        self.graph.split_by_name(&self.cfg.split)
    }

    /// Execute one node against the tensor store. Returns host wall time.
    pub fn run_node(
        &self,
        node: &Node,
        store: &mut HashMap<String, Tensor>,
    ) -> Result<std::time::Duration> {
        let started = Instant::now();
        match node.kind {
            NodeKind::Preprocess => {
                let pts = store
                    .get(PRIMAL)
                    .context("preprocess: no 'points' in store")?;
                let cloud = PointCloud::from_flat(pts.data());
                let grids = self.voxelizer.voxelize(&cloud);
                store.insert("points_sum".into(), grids.sum);
                store.insert("points_cnt".into(), grids.cnt);
            }
            NodeKind::Proposal => {
                let cls = store.get("cls_logits").context("proposal: cls_logits")?;
                let boxp = store.get("box_preds").context("proposal: box_preds")?;
                let dir = store.get("dir_logits").context("proposal: dir_logits")?;
                let props = self.proposal.run(cls, boxp, dir)?;
                let k = props.classes.len();
                let classes = Tensor::from_vec(
                    &[k],
                    props
                        .classes
                        .iter()
                        .map(|&c| if c == usize::MAX { -1.0 } else { c as f32 })
                        .collect(),
                )?;
                store.insert("rois".into(), props.rois);
                store.insert("roi_classes".into(), classes);
            }
            NodeKind::Xla => {
                let inputs: Vec<Tensor> = node
                    .inputs
                    .iter()
                    .map(|n| {
                        store
                            .get(n)
                            .cloned()
                            .with_context(|| format!("node '{}': missing input '{n}'", node.name))
                    })
                    .collect::<Result<_>>()?;
                let outputs = self.runtime.execute(&node.name, &inputs)?;
                for (name, t) in node.outputs.iter().zip(outputs) {
                    store.insert(name.clone(), t);
                }
            }
        }
        Ok(started.elapsed())
    }

    /// Assemble final detections from the store (runs on the edge).
    pub fn finalize(&self, store: &HashMap<String, Tensor>) -> Result<Vec<Detection>> {
        let scores = store.get("roi_scores").context("no roi_scores")?;
        let boxes = store.get("roi_boxes").context("no roi_boxes")?;
        let classes_t = store.get("roi_classes").context("no roi_classes")?;
        let classes: Vec<usize> = classes_t
            .data()
            .iter()
            .map(|&c| if c < 0.0 { usize::MAX } else { c as usize })
            .collect();
        Ok(assemble_predictions(
            scores,
            boxes,
            &classes,
            self.cfg.score_threshold,
        ))
    }

    /// Run one frame at a split point on the virtual clock.
    pub fn run_frame(&self, cloud: &PointCloud, sp: SplitPoint) -> Result<FrameResult> {
        if sp.head_len > self.graph.len() {
            bail!("split {:?} beyond pipeline length", sp);
        }
        let policy = self.cfg.codec;
        let mut store: HashMap<String, Tensor> = HashMap::new();
        store.insert(PRIMAL.into(), cloud.to_tensor());

        let mut node_times = Vec::with_capacity(self.graph.len());

        // ---- edge: head nodes
        for node in self.graph.head_nodes(sp) {
            let host = self.run_node(node, &mut store)?;
            node_times.push((
                node.name.clone(),
                SimTime::from_duration(host).scaled(self.cfg.edge.factor_for(&node.name)),
                Side::Edge,
            ));
        }

        // ---- edge: encode live set, uplink
        let live = self.graph.live_set(sp);
        let (uplink_bytes, encode_time, decode_time) = if live.is_empty() {
            (0, SimTime::ZERO, SimTime::ZERO)
        } else {
            let packet = Packet::new(
                live.iter()
                    .map(|n| -> Result<(String, Tensor)> {
                        Ok((
                            n.clone(),
                            store
                                .get(n)
                                .cloned()
                                .with_context(|| format!("live tensor '{n}' missing"))?,
                        ))
                    })
                    .collect::<Result<_>>()?,
            );
            let t0 = Instant::now();
            let bytes = packet.encode(policy);
            let enc = SimTime::from_duration(t0.elapsed()).scaled(self.cfg.edge.slowdown);
            let t1 = Instant::now();
            let decoded = Packet::decode(&bytes)?;
            let dec = SimTime::from_duration(t1.elapsed()).scaled(self.cfg.server.slowdown);
            // the server sees exactly the decoded tensors (quantization
            // round-trips through the wire, affecting tail numerics as it
            // would in deployment)
            for (name, t) in decoded.tensors {
                store.insert(name, t);
            }
            (bytes.len(), enc, dec)
        };
        let uplink_time = if sp.head_len == self.graph.len() {
            SimTime::ZERO
        } else {
            self.link.transfer_time(uplink_bytes)
        };

        // ---- server: tail nodes
        for node in self.graph.tail_nodes(sp) {
            let host = self.run_node(node, &mut store)?;
            node_times.push((
                node.name.clone(),
                SimTime::from_duration(host).scaled(self.cfg.server.factor_for(&node.name)),
                Side::Server,
            ));
        }

        // ---- server: response back to the edge
        let resp = self.graph.response_set(sp);
        let (downlink_bytes, downlink_time) = if resp.is_empty() {
            (0, SimTime::ZERO)
        } else {
            let packet = Packet::new(
                resp.iter()
                    .map(|n| (n.clone(), store.get(n).cloned().unwrap()))
                    .collect(),
            );
            let bytes = packet.encode(policy).len();
            (bytes, self.link.transfer_time(bytes))
        };

        let detections = self.finalize(&store)?;

        let edge_compute: SimTime = node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Edge)
            .map(|(_, t, _)| *t)
            .sum();
        let server_compute: SimTime = node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Server)
            .map(|(_, t, _)| *t)
            .sum();

        let edge_time = edge_compute + encode_time + uplink_time;
        let inference_time =
            edge_time + decode_time + server_compute + downlink_time;

        Ok(FrameResult {
            detections,
            timing: TimingBreakdown {
                split_label: self.graph.split_label(sp),
                node_times,
                encode_time,
                decode_time,
                uplink_bytes,
                downlink_bytes,
                uplink_time,
                downlink_time,
                inference_time,
                edge_time,
            },
        })
    }

    /// Convenience: run at the configured split.
    pub fn run_frame_default(&self, cloud: &PointCloud) -> Result<FrameResult> {
        self.run_frame(cloud, self.split()?)
    }

    /// Run the full pipeline once, unscaled, returning every intermediate
    /// tensor and per-node host time. Feeds the adaptive split selector and
    /// the Table I bench: one profile predicts every split analytically.
    pub fn profile_frame(
        &self,
        cloud: &PointCloud,
    ) -> Result<(HashMap<String, Tensor>, Vec<(String, std::time::Duration)>)> {
        let mut store: HashMap<String, Tensor> = HashMap::new();
        store.insert(PRIMAL.into(), cloud.to_tensor());
        let mut times = Vec::with_capacity(self.graph.len());
        for node in self.graph.nodes() {
            let host = self.run_node(node, &mut store)?;
            times.push((node.name.clone(), host));
        }
        Ok((store, times))
    }
}
