//! The split-computing execution engine: runs one frame through the
//! pipeline under a split point, producing detections plus the full timing
//! breakdown the paper's figures are built from.
//!
//! Compute runs for real (XLA or the reference executor on this host, rust
//! for preprocess/proposal); measured host time is scaled by the device
//! profile onto the virtual clock, and link time comes from the link model
//! (DESIGN.md §3). The same engine backs the in-process simulator, both
//! ends of the TCP transport, and every bench.
//!
//! Zero-clone frame contract: the per-frame state is an id-indexed
//! [`TensorStore`] of `Arc<Tensor>` slots — node I/O, wire-packet assembly
//! and `finalize` share tensors by refcount. Steady state performs no
//! `String` hashing, no full-tensor deep clones, and (via the voxelizer's
//! scratch pool) no dense-grid allocation.
//!
//! Staged frame contract: [`Engine::run_frame`] is literally the
//! composition of three stage functions — [`Engine::head_stage`]
//! (edge compute + wire encode), [`Engine::transfer_stage`] (link +
//! decode) and [`Engine::tail_stage`] (server compute + response +
//! finalize). The multi-frame pipeline ([`crate::coordinator::pipeline`])
//! runs the same three functions on separate worker threads, so pipelined
//! output is byte-identical to serial execution *by construction*.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::SystemConfig;
use crate::coordinator::link::LinkModel;
use crate::metrics::SimTime;
use crate::model::graph::{NodeKind, PipelineGraph, SplitPoint, TensorId, TensorStore};
use crate::model::manifest::{Manifest, ModelConfig};
use crate::pointcloud::PointCloud;
use crate::postprocess::{assemble_predictions, Detection, ProposalConfig, ProposalStage};
use crate::runtime::{ModuleId, XlaRuntime};
use crate::tensor::codec::{Packet, WIRE_VERSION};
use crate::tensor::Tensor;
use crate::voxel::Voxelizer;

/// Which side of the split executed a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Edge,
    Server,
}

/// Which half (or both) of the split pipeline an engine instance serves.
///
/// A `Full` engine runs whole frames; the TCP deployment builds one engine
/// per process, and the role records which stages that process is allowed
/// to run. The practical difference is edge-only state: a `ServerTail`
/// engine defers building the voxelizer (and its scratch-grid pool) until
/// a raw-offload request actually needs it, so a server that only ever
/// sees in-network splits never allocates edge-side preprocessing state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineRole {
    /// Both halves (in-process sessions, tests, benches).
    #[default]
    Full,
    /// Edge-device process: head stages + finalize.
    EdgeHead,
    /// Edge-server process: transfer decode + tail stages.
    ServerTail,
}

/// Per-frame timing breakdown (all on the virtual clock).
#[derive(Debug, Clone)]
pub struct TimingBreakdown {
    pub split_label: String,
    /// (node name, device-scaled time, side)
    pub node_times: Vec<(String, SimTime, Side)>,
    /// wire-encode / decode cost, attributed to their side
    pub encode_time: SimTime,
    pub decode_time: SimTime,
    pub uplink_bytes: usize,
    /// what the same live set would cost under the legacy v1 wire framing
    /// (flat site index) — the per-frame v1-vs-v2 savings EXPERIMENTS.md
    /// tracks on real sweeps; equals `uplink_bytes` when nothing ships
    pub uplink_v1_bytes: usize,
    /// what the same live set costs at exact f32 (v2 framing) — equals
    /// `uplink_bytes` on f32 runs; on quantized runs it is the baseline
    /// the v3 savings are measured against
    pub uplink_f32_bytes: usize,
    /// bytes actually shipped under v3 quantized framing (0 when the
    /// session wire precision is f32)
    pub uplink_v3_bytes: usize,
    pub downlink_bytes: usize,
    pub uplink_time: SimTime,
    pub downlink_time: SimTime,
    /// paper Fig 6: start of inference → predictions back on the edge
    pub inference_time: SimTime,
    /// paper Fig 7: start of inference → end of edge→server transfer
    pub edge_time: SimTime,
}

impl TimingBreakdown {
    pub fn edge_compute(&self) -> SimTime {
        self.node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Edge)
            .map(|(_, t, _)| *t)
            .sum()
    }

    pub fn server_compute(&self) -> SimTime {
        self.node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Server)
            .map(|(_, t, _)| *t)
            .sum()
    }

    pub fn node_time(&self, name: &str) -> Option<SimTime> {
        self.node_times
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, t, _)| *t)
    }
}

/// Result of one frame.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub detections: Vec<Detection>,
    pub timing: TimingBreakdown,
}

/// Output of [`Engine::head_stage`]: the head ran on the edge and the live
/// set is encoded into a pooled wire buffer. Opaque to callers — hand it to
/// [`Engine::transfer_stage`], or ship [`HeadFrame::take_wire`] over a real
/// socket (the TCP edge client does).
#[derive(Debug)]
pub struct HeadFrame {
    sp: SplitPoint,
    store: TensorStore,
    node_times: Vec<(String, SimTime, Side)>,
    /// encoded live-set packet (`None` when the live set is empty, i.e.
    /// edge-only execution)
    wire: Option<Vec<u8>>,
    /// live-set cost under the legacy v1 framing (0 when nothing ships)
    wire_v1_bytes: usize,
    /// live-set cost at exact f32 / v2 framing (== wire length on f32
    /// runs; 0 when nothing ships)
    wire_f32_bytes: usize,
    /// actual wire length when shipped under v3 quantized framing (0 on
    /// f32 runs or when nothing ships)
    wire_v3_bytes: usize,
    encode_time: SimTime,
}

impl HeadFrame {
    /// Encoded wire bytes, if the split ships anything.
    pub fn wire(&self) -> Option<&[u8]> {
        self.wire.as_deref()
    }

    /// Byte cost of the same live set under the legacy v1 wire framing.
    pub fn wire_v1_bytes(&self) -> usize {
        self.wire_v1_bytes
    }

    /// Byte cost of the same live set at exact f32 (v2 framing).
    pub fn wire_f32_bytes(&self) -> usize {
        self.wire_f32_bytes
    }

    /// Bytes shipped under v3 quantized framing (0 on f32 runs).
    pub fn wire_v3_bytes(&self) -> usize {
        self.wire_v3_bytes
    }

    /// Take the wire buffer out (for transports that consume the bytes)
    /// leaving the rest of the frame intact.
    pub fn take_wire(&mut self) -> Option<Vec<u8>> {
        self.wire.take()
    }

    /// Decompose into the per-frame store (the edge keeps it to finalize
    /// once the server responds) and the edge-side timing rows.
    pub fn into_store(self) -> (TensorStore, Vec<(String, SimTime, Side)>) {
        (self.store, self.node_times)
    }
}

/// Output of [`Engine::transfer_stage`]: the packet crossed the (virtual)
/// link and was decoded back into the store. Feed to [`Engine::tail_stage`].
#[derive(Debug)]
pub struct TransferredFrame {
    sp: SplitPoint,
    store: TensorStore,
    node_times: Vec<(String, SimTime, Side)>,
    encode_time: SimTime,
    decode_time: SimTime,
    uplink_bytes: usize,
    uplink_v1_bytes: usize,
    uplink_f32_bytes: usize,
    uplink_v3_bytes: usize,
    uplink_time: SimTime,
}

/// The engine: everything needed to run any split of the pipeline.
pub struct Engine {
    runtime: Arc<XlaRuntime>,
    graph: PipelineGraph,
    /// built lazily from `model_cfg` — a `ServerTail` engine only pays for
    /// edge-side preprocessing state if a raw-offload request arrives
    voxelizer: OnceLock<Voxelizer>,
    model_cfg: ModelConfig,
    role: EngineRole,
    proposal: ProposalStage,
    link: LinkModel,
    cfg: SystemConfig,
    /// per-node module id (Xla nodes), resolved once at construction
    node_modules: Vec<Option<ModuleId>>,
    /// (points_sum, points_cnt) ids for scratch-pool recycling
    scatter_ids: Option<(TensorId, TensorId)>,
    /// reusable wire buffers (exact-size `encode_into` targets)
    wire_buffers: Mutex<Vec<Vec<u8>>>,
}

/// Cap on pooled wire buffers (one per in-flight frame is plenty).
const MAX_WIRE_BUFFERS: usize = 8;

impl Engine {
    pub fn new(manifest: &Manifest, cfg: SystemConfig) -> Result<Engine> {
        Self::new_threaded(manifest, cfg, 1)
    }

    /// Engine whose module kernels parallelize over `threads` pool workers
    /// (`0` = all available cores; the CLI's `--threads` knob). Outputs are
    /// bit-identical at any thread count; when combined with the staged
    /// pipeline, size this against `tail_workers` via
    /// [`crate::coordinator::pipeline::PipelineConfig::kernel_threads_for`]
    /// so the two levels of parallelism compose instead of oversubscribing.
    pub fn new_threaded(
        manifest: &Manifest,
        cfg: SystemConfig,
        threads: usize,
    ) -> Result<Engine> {
        let runtime = Arc::new(XlaRuntime::load_pooled(manifest, threads)?);
        Self::with_runtime(manifest, cfg, runtime)
    }

    /// A tail-half engine for the server process: defers all edge-side
    /// state (see [`EngineRole::ServerTail`]).
    pub fn server_tail(
        manifest: &Manifest,
        cfg: SystemConfig,
        threads: usize,
    ) -> Result<Engine> {
        let runtime = Arc::new(XlaRuntime::load_pooled(manifest, threads)?);
        Self::with_runtime_role(manifest, cfg, runtime, EngineRole::ServerTail)
    }

    /// Share one XLA runtime across engines (benches sweep configs without
    /// recompiling artifacts).
    pub fn with_runtime(
        manifest: &Manifest,
        cfg: SystemConfig,
        runtime: Arc<XlaRuntime>,
    ) -> Result<Engine> {
        Self::with_runtime_role(manifest, cfg, runtime, EngineRole::Full)
    }

    /// [`Engine::with_runtime`] with an explicit [`EngineRole`]. `Full`
    /// and `EdgeHead` engines build the voxelizer eagerly (it is on their
    /// steady-state path); `ServerTail` defers it until a raw-offload
    /// request runs the preprocess node.
    pub fn with_runtime_role(
        manifest: &Manifest,
        cfg: SystemConfig,
        runtime: Arc<XlaRuntime>,
        role: EngineRole,
    ) -> Result<Engine> {
        let graph = PipelineGraph::from_manifest(manifest)?;
        let voxelizer = OnceLock::new();
        if role != EngineRole::ServerTail {
            let _ = voxelizer.set(Voxelizer::from_config(&manifest.config));
        }
        let proposal = ProposalStage::new(
            &manifest.config,
            ProposalConfig {
                nms_iou: cfg.nms_iou,
                ..ProposalConfig::default()
            },
        );
        let link = LinkModel::new(cfg.link.clone());
        let node_modules = graph
            .nodes()
            .iter()
            .map(|node| match node.kind {
                NodeKind::Xla => runtime.module_id(&node.name).map(Some),
                _ => Ok(None),
            })
            .collect::<Result<Vec<_>>>()?;
        let scatter_ids = graph
            .nodes()
            .iter()
            .find(|n| n.kind == NodeKind::Preprocess)
            .map(|n| (n.output_ids()[0], n.output_ids()[1]));
        Ok(Engine {
            runtime,
            graph,
            voxelizer,
            model_cfg: manifest.config.clone(),
            role,
            proposal,
            link,
            cfg,
            node_modules,
            scatter_ids,
            wire_buffers: Mutex::new(Vec::new()),
        })
    }

    pub fn graph(&self) -> &PipelineGraph {
        &self.graph
    }

    pub fn role(&self) -> EngineRole {
        self.role
    }

    /// Whether the voxelizer (edge-side scratch state) has been built.
    /// Always true for `Full`/`EdgeHead`; for `ServerTail` it flips only
    /// when a raw-offload request forces preprocessing onto the server.
    pub fn voxelizer_ready(&self) -> bool {
        self.voxelizer.get().is_some()
    }

    fn voxelizer(&self) -> &Voxelizer {
        self.voxelizer
            .get_or_init(|| Voxelizer::from_config(&self.model_cfg))
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn link(&self) -> &LinkModel {
        &self.link
    }

    pub fn runtime(&self) -> &Arc<XlaRuntime> {
        &self.runtime
    }

    pub fn split(&self) -> Result<SplitPoint> {
        self.graph.split_by_name(&self.cfg.split)
    }

    /// A store sized for this engine's graph.
    pub fn new_store(&self) -> TensorStore {
        TensorStore::for_graph(&self.graph)
    }

    /// Execute node `node_idx` against the tensor store. Returns host wall
    /// time. Inputs and outputs move through the store as `Arc` handles —
    /// no tensor is deep-cloned on this path.
    pub fn run_node(
        &self,
        node_idx: usize,
        store: &mut TensorStore,
    ) -> Result<std::time::Duration> {
        let node = &self.graph.nodes()[node_idx];
        let started = Instant::now();
        match node.kind {
            NodeKind::Preprocess => {
                let pts = store
                    .get(node.input_ids()[0])
                    .context("preprocess: no 'points' in store")?;
                let cloud = PointCloud::from_flat(pts.data());
                let grids = self.voxelizer().voxelize(&cloud);
                store.insert(node.output_ids()[0], grids.sum);
                store.insert(node.output_ids()[1], grids.cnt);
            }
            NodeKind::Proposal => {
                let ids = node.input_ids();
                let cls = store.get(ids[0]).context("proposal: cls_logits")?;
                let boxp = store.get(ids[1]).context("proposal: box_preds")?;
                let dir = store.get(ids[2]).context("proposal: dir_logits")?;
                let props = self.proposal.run(cls, boxp, dir)?;
                let k = props.classes.len();
                let classes = Tensor::from_vec(
                    &[k],
                    props
                        .classes
                        .iter()
                        .map(|&c| if c == usize::MAX { -1.0 } else { c as f32 })
                        .collect(),
                )?;
                store.insert(node.output_ids()[0], Arc::new(props.rois));
                store.insert(node.output_ids()[1], Arc::new(classes));
            }
            NodeKind::Xla => {
                let module = self.node_modules[node_idx]
                    .context("xla node without a resolved module id")?;
                let mut inputs: Vec<Arc<Tensor>> = Vec::with_capacity(node.input_ids().len());
                for (&id, name) in node.input_ids().iter().zip(&node.inputs) {
                    inputs.push(
                        store
                            .get(id)
                            .with_context(|| {
                                format!("node '{}': missing input '{name}'", node.name)
                            })?
                            .clone(),
                    );
                }
                let outputs = self.runtime.execute_id(module, &inputs)?;
                for (&id, t) in node.output_ids().iter().zip(outputs) {
                    store.insert(id, Arc::new(t));
                }
            }
        }
        Ok(started.elapsed())
    }

    /// Frame teardown: take the scatter grids out of `store` and hand
    /// them back to the voxelizer's scratch pool (no-op when a packet or
    /// caller still shares them). Every frame driver — local, TCP client,
    /// TCP server — calls this once the store is done.
    pub fn reclaim_scratch(&self, store: &mut TensorStore) {
        if let Some((sum_id, cnt_id)) = self.scatter_ids {
            if let (Some(sum), Some(cnt)) = (store.take(sum_id), store.take(cnt_id)) {
                // a tail engine that never voxelized has no pool to feed
                if let Some(vox) = self.voxelizer.get() {
                    vox.recycle_parts(sum, cnt);
                }
            }
        }
    }

    /// Assemble final detections from the store (runs on the edge).
    pub fn finalize(&self, store: &TensorStore) -> Result<Vec<Detection>> {
        let [id_scores, id_boxes, id_classes] = self.graph.final_output_ids();
        let scores = store.get(id_scores).context("no roi_scores")?;
        let boxes = store.get(id_boxes).context("no roi_boxes")?;
        let classes_t = store.get(id_classes).context("no roi_classes")?;
        let classes: Vec<usize> = classes_t
            .data()
            .iter()
            .map(|&c| if c < 0.0 { usize::MAX } else { c as usize })
            .collect();
        Ok(assemble_predictions(
            scores,
            boxes,
            &classes,
            self.cfg.score_threshold,
        ))
    }

    /// Stage 1 — edge side of one frame: run the head nodes and encode the
    /// live set into a pooled wire buffer. The returned [`HeadFrame`] feeds
    /// [`Engine::transfer_stage`]; the TCP edge client sends its wire bytes
    /// over a real socket instead.
    pub fn head_stage(&self, cloud: &PointCloud, sp: SplitPoint) -> Result<HeadFrame> {
        if sp.head_len > self.graph.len() {
            bail!("split {:?} beyond pipeline length", sp);
        }
        if self.role == EngineRole::ServerTail {
            bail!("server-tail engine cannot run head stages (EngineRole::ServerTail)");
        }
        let mut store = self.new_store();
        store.insert(self.graph.primal_id(), Arc::new(cloud.to_tensor()));

        let mut node_times = Vec::with_capacity(self.graph.len());
        for idx in 0..sp.head_len {
            let host = self.run_node(idx, &mut store)?;
            let name = &self.graph.nodes()[idx].name;
            node_times.push((
                name.clone(),
                SimTime::from_duration(host).scaled(self.cfg.edge.factor_for(name)),
                Side::Edge,
            ));
        }

        // ---- edge: encode the live set
        let live = self.graph.live_ids(sp);
        let (wire, wire_v1_bytes, wire_f32_bytes, wire_v3_bytes, encode_time) = if live
            .is_empty()
        {
            (None, 0, 0, 0, SimTime::ZERO)
        } else {
            let mut tensors = Vec::with_capacity(live.len());
            for &id in live {
                let name = self.graph.tensor_name(id);
                tensors.push((
                    name.to_string(),
                    store
                        .get(id)
                        .with_context(|| format!("live tensor '{name}' missing"))?
                        .clone(),
                ));
            }
            let packet = Packet::from_shared(tensors);
            // what the legacy framing would have cost (size arithmetic off
            // the cached site indexes — no second encode)
            let v1 = packet.encoded_size_versioned(self.cfg.codec, 1);
            // encode into a pooled, exactly-presized buffer — the
            // steady-state wire path allocates nothing. f32 precision
            // emits the byte-identical v2 frame; f16/int8 emit v3.
            let mut buf = self
                .wire_buffers
                .lock()
                .unwrap()
                .pop()
                .unwrap_or_default();
            let t0 = Instant::now();
            packet.encode_wire_into(self.cfg.codec, self.cfg.wire, &mut buf);
            let enc = SimTime::from_duration(t0.elapsed()).scaled(self.cfg.edge.slowdown);
            // v2-f32 baseline + actual v3 cost, both without re-encoding
            let (f32b, v3b) = if self.cfg.wire.lossy() {
                (
                    packet.encoded_size_versioned(self.cfg.codec, WIRE_VERSION),
                    buf.len(),
                )
            } else {
                (buf.len(), 0)
            };
            (Some(buf), v1, f32b, v3b, enc)
        };

        Ok(HeadFrame {
            sp,
            store,
            node_times,
            wire,
            wire_v1_bytes,
            wire_f32_bytes,
            wire_v3_bytes,
            encode_time,
        })
    }

    /// Stage 2 — the wire crossing: charge the uplink on the virtual clock
    /// and decode the packet into the store. The server sees exactly the
    /// decoded tensors (quantization round-trips through the wire,
    /// affecting tail numerics as it would in deployment).
    pub fn transfer_stage(&self, head: HeadFrame) -> Result<TransferredFrame> {
        let HeadFrame {
            sp,
            mut store,
            node_times,
            wire,
            wire_v1_bytes,
            wire_f32_bytes,
            wire_v3_bytes,
            encode_time,
        } = head;
        let (uplink_bytes, decode_time) = match wire {
            None => (0, SimTime::ZERO),
            Some(buf) => {
                let t1 = Instant::now();
                let decoded = Packet::decode(&buf)?;
                let dec =
                    SimTime::from_duration(t1.elapsed()).scaled(self.cfg.server.slowdown);
                let wire_len = buf.len();
                {
                    let mut pool = self.wire_buffers.lock().unwrap();
                    if pool.len() < MAX_WIRE_BUFFERS {
                        pool.push(buf);
                    }
                }
                // order is the live-set order, so ids line up without any
                // name lookups
                for (&id, (name, t)) in self.graph.live_ids(sp).iter().zip(decoded.tensors) {
                    debug_assert_eq!(self.graph.tensor_name(id), name.as_str());
                    store.insert(id, t);
                }
                (wire_len, dec)
            }
        };
        let uplink_time = if sp.head_len == self.graph.len() {
            SimTime::ZERO
        } else {
            self.link.transfer_time(uplink_bytes)
        };
        Ok(TransferredFrame {
            sp,
            store,
            node_times,
            encode_time,
            decode_time,
            uplink_bytes,
            uplink_v1_bytes: wire_v1_bytes,
            uplink_f32_bytes: wire_f32_bytes,
            uplink_v3_bytes: wire_v3_bytes,
            uplink_time,
        })
    }

    /// Stage 3 — server side: run the tail nodes, price the response
    /// downlink, assemble detections and hand scratch grids back to the
    /// pool.
    pub fn tail_stage(&self, frame: TransferredFrame) -> Result<FrameResult> {
        if self.role == EngineRole::EdgeHead {
            bail!("edge-head engine cannot run tail stages (EngineRole::EdgeHead)");
        }
        let TransferredFrame {
            sp,
            mut store,
            mut node_times,
            encode_time,
            decode_time,
            uplink_bytes,
            uplink_v1_bytes,
            uplink_f32_bytes,
            uplink_v3_bytes,
            uplink_time,
        } = frame;

        // ---- server: tail nodes
        for idx in sp.head_len..self.graph.len() {
            let host = self.run_node(idx, &mut store)?;
            let name = &self.graph.nodes()[idx].name;
            node_times.push((
                name.clone(),
                SimTime::from_duration(host).scaled(self.cfg.server.factor_for(name)),
                Side::Server,
            ));
        }

        // ---- server: response back to the edge
        let resp = self.graph.response_ids(sp);
        let (downlink_bytes, downlink_time) = if resp.is_empty() {
            (0, SimTime::ZERO)
        } else {
            let packet = Packet::from_shared(
                resp.iter()
                    .map(|&id| {
                        (
                            self.graph.tensor_name(id).to_string(),
                            store.get(id).cloned().expect("response tensor produced"),
                        )
                    })
                    .collect(),
            );
            // only the byte count matters on the virtual clock; the exact
            // size calculator skips building the buffer entirely
            let bytes = packet.encoded_size(self.cfg.codec);
            (bytes, self.link.transfer_time(bytes))
        };

        let detections = self.finalize(&store)?;

        // ---- teardown: hand the scatter grids back to the scratch pool
        self.reclaim_scratch(&mut store);

        let edge_compute: SimTime = node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Edge)
            .map(|(_, t, _)| *t)
            .sum();
        let server_compute: SimTime = node_times
            .iter()
            .filter(|(_, _, s)| *s == Side::Server)
            .map(|(_, t, _)| *t)
            .sum();

        let edge_time = edge_compute + encode_time + uplink_time;
        let inference_time =
            edge_time + decode_time + server_compute + downlink_time;

        Ok(FrameResult {
            detections,
            timing: TimingBreakdown {
                split_label: self.graph.split_label(sp),
                node_times,
                encode_time,
                decode_time,
                uplink_bytes,
                uplink_v1_bytes,
                uplink_f32_bytes,
                uplink_v3_bytes,
                downlink_bytes,
                uplink_time,
                downlink_time,
                inference_time,
                edge_time,
            },
        })
    }

    /// Run one frame at a split point on the virtual clock: the serial
    /// composition of the three stage functions. The pipelined engine runs
    /// the identical stages on worker threads, so its per-frame output is
    /// byte-identical to this path.
    pub fn run_frame(&self, cloud: &PointCloud, sp: SplitPoint) -> Result<FrameResult> {
        let head = self.head_stage(cloud, sp)?;
        let transferred = self.transfer_stage(head)?;
        self.tail_stage(transferred)
    }

    /// Convenience: run at the configured split.
    pub fn run_frame_default(&self, cloud: &PointCloud) -> Result<FrameResult> {
        self.run_frame(cloud, self.split()?)
    }

    /// Run the full pipeline once, unscaled, returning every intermediate
    /// tensor and per-node host time. Feeds the adaptive split selector and
    /// the Table I bench: one profile predicts every split analytically.
    pub fn profile_frame(
        &self,
        cloud: &PointCloud,
    ) -> Result<(TensorStore, Vec<(String, std::time::Duration)>)> {
        let mut store = self.new_store();
        store.insert(self.graph.primal_id(), Arc::new(cloud.to_tensor()));
        let mut times = Vec::with_capacity(self.graph.len());
        for idx in 0..self.graph.len() {
            let host = self.run_node(idx, &mut store)?;
            times.push((self.graph.nodes()[idx].name.clone(), host));
        }
        Ok((store, times))
    }
}
