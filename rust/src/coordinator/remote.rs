//! Real two-process deployment: concurrent TCP split server + edge client.
//!
//! This is the paper's Fig 1/2 topology executed for real: the head runs in
//! the edge process, the live set crosses an actual socket, the tail runs
//! in the server process, and predictions come back. Realtime mode —
//! timings are wall-clock on this host (no device scaling), so the numbers
//! demonstrate the mechanism; the calibrated virtual-clock engine produces
//! the paper-comparable figures.
//!
//! The server side is a multi-client session server sharing one tail:
//!
//! * every connection gets a session handler thread that reads requests,
//!   applies admission control, and enqueues tail jobs;
//! * one shared [`Batcher`] coalesces jobs across sessions, so frames from
//!   different clients land in one tail dispatch (each frame's tail is
//!   independent — batching changes scheduling, never arithmetic, so every
//!   client's detections stay byte-identical to a solo run);
//! * a dispatcher thread pulls batches and scatters them over the engine's
//!   kernel [`WorkerPool`](crate::runtime::pool::WorkerPool) lanes;
//! * replies route back through a per-session reorder buffer that
//!   preserves the connection's FIFO reply contract.
//!
//! Backpressure is two-level: a global pending cap refuses new work with a
//! [`Message::Busy`] retry hint, and a per-session window stops reading a
//! session's socket (TCP backpressure) so one greedy client cannot starve
//! the rest. Teardown follows the [`Shutdown`] contract: graceful drain
//! (stop accepting, flush everything admitted, then close) bounded by a
//! timeout, with abort as the fallback and the `Drop` path.
//!
//! Wire packets are self-describing (tensor names), so each process
//! resolves names to its graph's interned ids once per request at the
//! boundary; everything inside the frame then runs on the id-indexed
//! store, sharing tensors by refcount.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::Read as _;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::engine::{Engine, HeadFrame};
use crate::coordinator::fault::{Backoff, LinkHealth, RetryPolicy};
use crate::coordinator::pipeline::Reorder;
use crate::coordinator::shutdown::{Shutdown, ShutdownMode};
use crate::coordinator::transport::{read_message, write_message, Message};
use crate::metrics::{OccupancyHist, SimTime};
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::postprocess::Detection;
use crate::telemetry::{self, Counter, Histogram, MetricsServer, Registry};
use crate::tensor::codec::{Packet, Policy};
use crate::util::rng::Rng;

/// Admission, batching, and teardown knobs for [`Server`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Concurrent session cap: connections beyond it are refused at accept
    /// time with a protocol `Error` (stats connections count too).
    pub max_sessions: usize,
    /// Global cap on admitted-but-unanswered tail jobs. An `Infer`
    /// arriving at the cap is refused with [`Message::Busy`] instead of
    /// queued — a soft cap (checked before the increment), so brief
    /// overshoot by a few in-flight admissions is possible.
    pub pending_cap: usize,
    /// Per-session in-flight bound: a session's handler stops reading its
    /// socket while this many of its frames are outstanding, so TCP
    /// backpressure reaches the client and one session cannot consume the
    /// whole pending budget.
    pub session_window: usize,
    /// Graceful-drain deadline: [`Server::shutdown`] aborts whatever is
    /// still in flight once this much time has passed.
    pub drain_timeout: Duration,
    /// Parallel lanes per tail dispatch: each batch is scattered over the
    /// engine's kernel pool in at most this many contiguous ranges.
    pub tail_slots: usize,
    /// Cross-session coalescing policy. The default `max_wait` of zero
    /// adds no latency: a dispatch takes whatever is queued the moment it
    /// looks, so batches grow exactly when the tail is the bottleneck.
    pub batch: BatchPolicy,
    /// Periodic stderr metrics summary (`None` = off).
    pub stats_interval: Option<Duration>,
    /// Serve this server's telemetry registry as a Prometheus `/metrics`
    /// HTTP endpoint on this address (`None` = off). Stable metric names
    /// are documented in `docs/METRICS.md`.
    pub metrics_addr: Option<String>,
    /// Per-session resume-ledger bound: a resumable session keeps at
    /// most this many finished, unacknowledged replies for
    /// retransmission (default [`RESUME_LEDGER_CAP`]).
    pub resume_ledger_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_sessions: 64,
            pending_cap: 256,
            session_window: 32,
            drain_timeout: Duration::from_secs(10),
            tail_slots: 1,
            batch: BatchPolicy {
                max_frames: 8,
                max_wait: Duration::ZERO,
            },
            stats_interval: None,
            metrics_addr: None,
            resume_ledger_cap: RESUME_LEDGER_CAP,
        }
    }
}

/// Wire footprint of a message (header + payload), for byte accounting.
fn wire_len(msg: &Message) -> u64 {
    let payload = match msg {
        Message::Infer { packet, .. } => 9 + packet.len(),
        Message::InferResult { packet, .. } => 16 + packet.len(),
        Message::Error { message, .. } => 8 + message.len(),
        Message::Busy { .. } => 16,
        Message::StatsResult { text } => text.len(),
        Message::Hello { .. } => 16,
        Message::HelloAck { .. } => 8,
        Message::Shutdown | Message::Stats => 0,
    };
    9 + payload as u64
}

/// One admitted tail request travelling from a session handler to the
/// dispatcher. Holds its session alive until the reply is flushed, so a
/// client disconnecting mid-stream never invalidates queued work.
struct TailJob {
    session: Arc<SessionState>,
    /// per-session reply sequence (the reorder buffer's key)
    seq: u64,
    request_id: u64,
    head_len: u8,
    packet: Vec<u8>,
}

/// Per-session in-flight window, guarded by `SessionState::win`.
struct Window {
    in_flight: usize,
    submitted: u64,
}

/// Default ledger cap ([`ServerConfig::resume_ledger_cap`]): a resumable
/// session keeps at most this many finished, unacknowledged replies for
/// retransmission. Evicting the oldest entry is safe — if the client
/// ever retransmits an evicted id it is simply re-admitted and
/// recomputed, and the tail is deterministic, so the recomputed reply is
/// byte-identical.
pub const RESUME_LEDGER_CAP: usize = 256;

/// Cap on parked (disconnected, resumable) sessions held for adoption.
const DETACHED_CAP: usize = 64;

/// How long a resume handshake waits for the dropped session's handler to
/// park its state (the reconnect can race the old handler noticing EOF).
const RESUME_GRACE: Duration = Duration::from_secs(2);

/// Resumable-session state: the per-session ledger that makes reconnect
/// lossless. `token == 0` means the session is not resumable (the
/// default) and every other field stays empty.
#[derive(Default)]
struct ResumeState {
    token: u64,
    /// Request ids admitted into the pipeline: still in flight, or
    /// finished with the reply held in `done`. Retransmissions of these
    /// ids are never admitted twice.
    admitted: BTreeSet<u64>,
    /// Finished replies not yet acknowledged by the client, keyed by
    /// request id, for retransmission after a resume.
    done: BTreeMap<u64, Message>,
    /// Highest request id the client has confirmed delivered.
    acked: u64,
}

/// Everything one connection's handler, jobs, and metrics share.
struct SessionState {
    id: u64,
    peer: String,
    /// Write half. Replies go out under this lock in `seq` order — the
    /// reorder drain runs inside it so concurrent tail workers cannot
    /// interleave one session's replies. Swapped on session resume.
    sock: Mutex<TcpStream>,
    /// Shutdown control handle, outside the write lock: a write blocked on
    /// a stalled client must still be interruptible.
    ctrl: Mutex<TcpStream>,
    /// Parks out-of-order replies until their predecessors land, restoring
    /// the connection's FIFO reply contract.
    replies: Reorder<Message>,
    win: Mutex<Window>,
    win_cv: Condvar,
    /// Cleared on write failure or abort; dead sessions drop replies
    /// instead of erroring the tail workers that computed them (resumable
    /// sessions still *ledger* those replies for retransmission).
    alive: AtomicBool,
    /// Lock-order rule: never wait on `sock` while holding `resume` —
    /// every path gathers what it needs under `resume`, drops it, then
    /// takes `sock` (the reverse nesting, `sock` → `resume`, is allowed).
    resume: Mutex<ResumeState>,
    /// Per-session registry counters (labeled `session="<id>"`),
    /// unregistered when the session truly ends. Still a single relaxed
    /// atomic op per update.
    resumes: Arc<Counter>,
    frames: Arc<Counter>,
    bytes_in: Arc<Counter>,
    bytes_out: Arc<Counter>,
    tail_nanos: Arc<Counter>,
}

/// The request id a reply retransmission would be keyed by.
fn reply_request_id(msg: &Message) -> Option<u64> {
    match msg {
        Message::InferResult { request_id, .. } | Message::Error { request_id, .. } => {
            Some(*request_id)
        }
        _ => None,
    }
}

impl SessionState {
    /// Route one reply: park it in the reorder buffer, flush the
    /// contiguous ready run to the socket, then release window slots for
    /// every flushed frame.
    fn complete(&self, seq: u64, msg: Message, shared: &ServerShared) {
        // Ledger the reply for a resumable session *before* any write
        // attempt: it must survive a dead socket so a resumed client can
        // fetch it by retransmitting the request id.
        {
            let mut r = self.resume.lock().unwrap();
            if r.token != 0 {
                if let Some(rid) = reply_request_id(&msg) {
                    r.done.insert(rid, msg.clone());
                    while r.done.len() > shared.cfg.resume_ledger_cap {
                        if let Some((old, _)) = r.done.pop_first() {
                            r.admitted.remove(&old);
                        }
                    }
                }
            }
        }
        let mut sock = self.sock.lock().unwrap();
        self.replies.complete(seq, msg);
        let ready = self.replies.drain_ready();
        let flushed = ready.len();
        for (_, msg) in ready {
            if self.alive.load(Ordering::Acquire) {
                match write_message(&mut *sock, &msg) {
                    Ok(()) => {
                        let n = wire_len(&msg);
                        self.bytes_out.add(n);
                        shared.metrics.bytes_out.add(n);
                    }
                    Err(_) => self.alive.store(false, Ordering::Release),
                }
            }
        }
        drop(sock);
        if flushed > 0 {
            let mut w = self.win.lock().unwrap();
            w.in_flight -= flushed;
            drop(w);
            self.win_cv.notify_all();
        }
    }
}

/// Server-wide counters: registry-backed handles, pre-interned once at
/// spawn so the hot paths stay single relaxed atomic ops (zero alloc,
/// zero lock). [`ServerStats`] (and the `Stats` wire message) is now a
/// *view* over these handles, so the snapshot and the `/metrics`
/// endpoint can never disagree.
struct ServerMetrics {
    sessions_total: Arc<Counter>,
    frames: Arc<Counter>,
    bytes_in: Arc<Counter>,
    bytes_out: Arc<Counter>,
    tail_nanos: Arc<Counter>,
    tail_batches: Arc<Counter>,
    multi_session_batches: Arc<Counter>,
    busy_rejections: Arc<Counter>,
    accept_refusals: Arc<Counter>,
    session_errors: Arc<Counter>,
    sessions_resumed: Arc<Counter>,
    /// retransmitted `Infer` requests deduplicated (or re-served from the
    /// resume ledger) instead of recomputed
    retransmits: Arc<Counter>,
    /// per-job tail latency distribution (seconds)
    tail_seconds: Arc<Histogram>,
    /// batcher depth sampled at each dispatch, as a fixed-bucket export
    queue_depth: Arc<Histogram>,
    /// batcher depth sampled at each dispatch (exact per-depth counts,
    /// kept alongside the bucketed export for `queue_mean`/`queue_max`)
    queue_occupancy: Mutex<OccupancyHist>,
}

impl ServerMetrics {
    /// Intern every server-wide metric in `reg` (stable names; see
    /// `docs/METRICS.md`).
    fn register(reg: &Registry) -> ServerMetrics {
        let c = |name: &str, help: &str| reg.counter(name, help, &[]);
        ServerMetrics {
            sessions_total: c("sp_server_sessions_total", "Sessions accepted since start"),
            frames: c("sp_server_frames_total", "Tail jobs completed"),
            bytes_in: c("sp_server_uplink_bytes_total", "Request bytes received"),
            bytes_out: c("sp_server_downlink_bytes_total", "Reply bytes sent"),
            tail_nanos: c("sp_server_tail_nanos_total", "Cumulative tail compute, nanoseconds"),
            tail_batches: c("sp_server_tail_batches_total", "Tail dispatches executed"),
            multi_session_batches: c(
                "sp_server_multi_session_batches_total",
                "Tail dispatches that coalesced frames from more than one session",
            ),
            busy_rejections: c(
                "sp_server_busy_rejections_total",
                "Infer requests refused with Busy at the pending cap",
            ),
            accept_refusals: c(
                "sp_server_accept_refusals_total",
                "Connections refused at the session cap",
            ),
            session_errors: c(
                "sp_server_session_errors_total",
                "Sessions ended by a protocol or socket error",
            ),
            sessions_resumed: c(
                "sp_server_sessions_resumed_total",
                "Resumable sessions adopted onto a fresh connection",
            ),
            retransmits: c(
                "sp_server_retransmits_total",
                "Retransmitted requests answered from the resume ledger or dropped as duplicates",
            ),
            tail_seconds: reg.histogram(
                "sp_stage_latency_seconds",
                "Per-stage latency in seconds",
                &[("stage", "tail")],
                &telemetry::latency_buckets(),
            ),
            queue_depth: reg.histogram(
                "sp_queue_depth",
                "Queue depth observed per dispatch",
                &[("queue", "batcher")],
                &telemetry::depth_buckets(),
            ),
            queue_occupancy: Mutex::new(OccupancyHist::new()),
        }
    }
}

/// State shared by the accept loop, session handlers, and dispatcher.
struct ServerShared {
    cfg: ServerConfig,
    engine: Arc<Engine>,
    batcher: Batcher<TailJob>,
    stop: AtomicBool,
    aborted: AtomicBool,
    /// admitted-but-unanswered jobs across all sessions
    pending: AtomicUsize,
    next_session: AtomicU64,
    next_token: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<SessionState>>>,
    /// Resumable sessions whose connection dropped, keyed by token and
    /// waiting for a reconnect to adopt them.
    detached: Mutex<HashMap<u64, Arc<SessionState>>>,
    handlers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Per-server registry (not the process-global one): two servers in
    /// one test process keep exact, independent stats. Served over HTTP
    /// when `cfg.metrics_addr` is set.
    registry: Arc<Registry>,
    metrics: ServerMetrics,
}

impl ServerShared {
    /// Immediate teardown: unblock every reader and writer, drop queued
    /// work (tail jobs already dequeued finish as errors, cheaply).
    fn abort(&self) {
        self.aborted.store(true, Ordering::SeqCst);
        self.stop.store(true, Ordering::SeqCst);
        for sess in self.sessions.lock().unwrap().values() {
            sess.alive.store(false, Ordering::Release);
            let _ = sess.ctrl.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
        self.detached.lock().unwrap().clear();
        self.batcher.close();
    }

    fn snapshot(&self) -> ServerStats {
        let per_session: Vec<SessionSnapshot> = {
            let sessions = self.sessions.lock().unwrap();
            let mut v: Vec<SessionSnapshot> = sessions
                .values()
                .map(|s| {
                    let (in_flight, submitted) = {
                        let w = s.win.lock().unwrap();
                        (w.in_flight, w.submitted)
                    };
                    let ledger = s.resume.lock().unwrap().done.len();
                    SessionSnapshot {
                        id: s.id,
                        peer: s.peer.clone(),
                        frames: s.frames.get(),
                        submitted,
                        uplink_bytes: s.bytes_in.get(),
                        downlink_bytes: s.bytes_out.get(),
                        tail_time: SimTime {
                            nanos: s.tail_nanos.get() as u128,
                        },
                        in_flight,
                        resumes: s.resumes.get(),
                        ledger,
                    }
                })
                .collect();
            v.sort_by_key(|s| s.id);
            v
        };
        let m = &self.metrics;
        let occ = m.queue_occupancy.lock().unwrap();
        ServerStats {
            sessions_active: per_session.len(),
            sessions_total: m.sessions_total.get(),
            frames: m.frames.get(),
            uplink_bytes: m.bytes_in.get(),
            downlink_bytes: m.bytes_out.get(),
            tail_batches: m.tail_batches.get(),
            multi_session_batches: m.multi_session_batches.get(),
            busy_rejections: m.busy_rejections.get(),
            accept_refusals: m.accept_refusals.get(),
            session_errors: m.session_errors.get(),
            sessions_resumed: m.sessions_resumed.get(),
            retransmits: m.retransmits.get(),
            pending: self.pending.load(Ordering::Relaxed),
            tail_time: SimTime {
                nanos: m.tail_nanos.get() as u128,
            },
            queue_mean: occ.mean(),
            queue_max: occ.max(),
            per_session,
        }
    }
}

/// Point-in-time metrics for one live session.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pub id: u64,
    pub peer: String,
    /// tail jobs completed for this session
    pub frames: u64,
    /// requests admitted past the session window (an exact count, read
    /// under the window lock — test harnesses gate teardown on it)
    pub submitted: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub tail_time: SimTime,
    pub in_flight: usize,
    /// times this session was resumed onto a fresh connection
    pub resumes: u64,
    /// finished, unacknowledged replies currently held in the resume
    /// ledger (bounded by [`ServerConfig::resume_ledger_cap`])
    pub ledger: usize,
}

/// Point-in-time server metrics: [`Server::stats`] in process, the
/// `Stats` protocol request (see [`fetch_stats`]) over the wire.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub sessions_active: usize,
    pub sessions_total: u64,
    pub frames: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    /// tail dispatches executed
    pub tail_batches: u64,
    /// dispatches that coalesced frames from more than one session
    pub multi_session_batches: u64,
    /// `Infer` requests refused with `Busy` at the pending cap
    pub busy_rejections: u64,
    /// connections refused at the session cap
    pub accept_refusals: u64,
    /// sessions that ended with a protocol/socket error (isolated)
    pub session_errors: u64,
    /// resumable sessions adopted onto a fresh connection after a drop
    pub sessions_resumed: u64,
    /// retransmitted requests answered from the resume ledger (or dropped
    /// as duplicates) instead of recomputed
    pub retransmits: u64,
    /// admitted-but-unanswered jobs right now
    pub pending: usize,
    /// cumulative tail compute
    pub tail_time: SimTime,
    /// mean batcher depth observed at dispatch time
    pub queue_mean: f64,
    pub queue_max: usize,
    pub per_session: Vec<SessionSnapshot>,
}

impl ServerStats {
    /// One-line operator summary (the periodic stderr heartbeat).
    pub fn summary(&self) -> String {
        format!(
            "server: {} session(s) active, {} total | {} frame(s) in {} batch(es) \
             ({} multi-session), {} pending | up {:.2} MB, down {:.2} MB | \
             tail {:.1} ms total, queue mean {:.2} max {} | {} busy, {} refused, {} error(s), \
             {} resumed",
            self.sessions_active,
            self.sessions_total,
            self.frames,
            self.tail_batches,
            self.multi_session_batches,
            self.pending,
            self.uplink_bytes as f64 / 1e6,
            self.downlink_bytes as f64 / 1e6,
            self.tail_time.as_millis_f64(),
            self.queue_mean,
            self.queue_max,
            self.busy_rejections,
            self.accept_refusals,
            self.session_errors,
            self.sessions_resumed,
        )
    }

    /// Greppable `key=value` lines plus one `session` row per live
    /// session — the `StatsResult` wire payload.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        use std::fmt::Write as _;
        let _ = writeln!(out, "sessions_active={}", self.sessions_active);
        let _ = writeln!(out, "sessions_total={}", self.sessions_total);
        let _ = writeln!(out, "frames={}", self.frames);
        let _ = writeln!(out, "uplink_bytes={}", self.uplink_bytes);
        let _ = writeln!(out, "downlink_bytes={}", self.downlink_bytes);
        let _ = writeln!(out, "tail_batches={}", self.tail_batches);
        let _ = writeln!(out, "multi_session_batches={}", self.multi_session_batches);
        let _ = writeln!(out, "busy_rejections={}", self.busy_rejections);
        let _ = writeln!(out, "accept_refusals={}", self.accept_refusals);
        let _ = writeln!(out, "session_errors={}", self.session_errors);
        let _ = writeln!(out, "sessions_resumed={}", self.sessions_resumed);
        let _ = writeln!(out, "retransmits={}", self.retransmits);
        let _ = writeln!(out, "pending={}", self.pending);
        let _ = writeln!(out, "tail_ms={:.3}", self.tail_time.as_millis_f64());
        let _ = writeln!(out, "queue_mean={:.3}", self.queue_mean);
        let _ = writeln!(out, "queue_max={}", self.queue_max);
        for s in &self.per_session {
            let _ = writeln!(
                out,
                "session id={} peer={} frames={} submitted={} up={} down={} tail_ms={:.3} in_flight={} resumes={} ledger={}",
                s.id,
                s.peer,
                s.frames,
                s.submitted,
                s.uplink_bytes,
                s.downlink_bytes,
                s.tail_time.as_millis_f64(),
                s.in_flight,
                s.resumes,
                s.ledger,
            );
        }
        out
    }
}

/// Concurrent multi-client split server (see the module docs for the
/// architecture). Construct with [`Server::spawn`]/[`Server::spawn_with`]
/// or through [`ServerSession`](crate::coordinator::session::ServerSession).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    accept: Option<std::thread::JoinHandle<()>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    stats_thread: Option<std::thread::JoinHandle<()>>,
    metrics_http: Option<MetricsServer>,
}

impl Server {
    /// Bind and start serving with the default [`ServerConfig`].
    /// `engine` runs the tail side, shared by every session.
    pub fn spawn(addr: &str, engine: Arc<Engine>) -> Result<Server> {
        Server::spawn_with(addr, engine, ServerConfig::default())
    }

    /// Bind and start serving with explicit admission/batching knobs.
    pub fn spawn_with(addr: &str, engine: Arc<Engine>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let registry = Arc::new(Registry::new());
        let metrics = ServerMetrics::register(&registry);
        let shared = Arc::new(ServerShared {
            batcher: Batcher::new(cfg.batch),
            cfg,
            engine: engine.clone(),
            stop: AtomicBool::new(false),
            aborted: AtomicBool::new(false),
            pending: AtomicUsize::new(0),
            next_session: AtomicU64::new(0),
            next_token: AtomicU64::new(0),
            sessions: Mutex::new(HashMap::new()),
            detached: Mutex::new(HashMap::new()),
            handlers: Mutex::new(Vec::new()),
            registry: registry.clone(),
            metrics,
        });

        // Live gauges are pulled at render time by a collector. The weak
        // reference breaks the shared → registry → collector → shared
        // cycle: once the server is gone the gauges simply stop updating.
        {
            let weak = Arc::downgrade(&shared);
            let sessions_active =
                registry.gauge("sp_server_sessions_active", "Live sessions right now", &[]);
            let pending = registry.gauge(
                "sp_server_pending_jobs",
                "Admitted-but-unanswered tail jobs right now",
                &[],
            );
            registry.register_collector(move || {
                if let Some(s) = weak.upgrade() {
                    sessions_active.set(s.sessions.lock().unwrap().len() as f64);
                    pending.set(s.pending.load(Ordering::Relaxed) as f64);
                }
            });
        }
        // Engine / link / runtime provenance: configured RTT, kernel
        // threads, SIMD dispatch level, and the sparse-conv tap counters
        // (cumulative in the runtime, synced monotonically per render).
        {
            let rtt = registry.gauge(
                "sp_link_configured_rtt_seconds",
                "Configured one-way link RTT of the engine's link model",
                &[],
            );
            rtt.set(engine.link().config().rtt_one_way);
            let threads = registry.gauge("sp_runtime_threads", "Kernel worker threads", &[]);
            threads.set(engine.runtime().threads() as f64);
            let dispatch = registry.gauge(
                "sp_runtime_dispatch_info",
                "Always 1; the dispatch label carries the SIMD level",
                &[("dispatch", engine.runtime().simd_dispatch())],
            );
            dispatch.set(1.0);
            let taps_seen = registry.counter(
                "sp_runtime_taps_seen_total",
                "Sparse-conv taps considered by the gather kernels",
                &[],
            );
            let taps_skipped = registry.counter(
                "sp_runtime_taps_skipped_total",
                "Sparse-conv taps skipped by per-tap occupancy masks",
                &[],
            );
            let rt_engine = engine;
            registry.register_collector(move || {
                let (seen, skipped) = rt_engine.runtime().tap_stats();
                taps_seen.merge_total(seen);
                taps_skipped.merge_total(skipped);
            });
        }
        let metrics_http = match shared.cfg.metrics_addr.clone() {
            Some(addr) => Some(MetricsServer::spawn(&addr, registry)?),
            None => None,
        };

        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("sp-server-accept".into())
                .spawn(move || accept_loop(listener, &shared))?
        };
        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("sp-server-dispatch".into())
                .spawn(move || dispatch_loop(&shared))?
        };
        let stats_thread = match shared.cfg.stats_interval {
            Some(interval) => {
                let shared = shared.clone();
                Some(
                    std::thread::Builder::new()
                        .name("sp-server-stats".into())
                        .spawn(move || stats_loop(&shared, interval))?,
                )
            }
            None => None,
        };

        Ok(Server {
            addr: local,
            shared,
            accept: Some(accept),
            dispatcher: Some(dispatcher),
            stats_thread,
            metrics_http,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time metrics snapshot.
    pub fn stats(&self) -> ServerStats {
        self.shared.snapshot()
    }

    /// This server's telemetry registry (per-instance; rendered by the
    /// `/metrics` endpoint when `metrics_addr` is configured).
    pub fn registry(&self) -> Arc<Registry> {
        self.shared.registry.clone()
    }

    /// The bound `/metrics` endpoint address, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_http.as_ref().map(|m| m.addr())
    }

    /// Graceful drain: stop accepting, flush every admitted frame, then
    /// close — bounded by the configured `drain_timeout`, after which
    /// in-flight work is aborted and this errors.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_mode(ShutdownMode::Drain)
    }
}

impl Shutdown for Server {
    fn shutdown_mode(&mut self, mode: ShutdownMode) -> Result<()> {
        if self.accept.is_none() && self.dispatcher.is_none() {
            return Ok(()); // already torn down
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(mut m) = self.metrics_http.take() {
            m.shutdown();
        }
        let accept = self.accept.take();
        let dispatcher = self.dispatcher.take();
        let stats_thread = self.stats_thread.take();
        let shared = self.shared.clone();
        // The full teardown sequence; under Drain it runs on a helper
        // thread so the deadline can interrupt it.
        let drain = move || {
            // no new sessions
            if let Some(t) = accept {
                let _ = t.join();
            }
            // shut every session's read half: handlers see EOF after the
            // requests already buffered, admit nothing more, and exit —
            // write halves stay open so admitted frames still flush
            for sess in shared.sessions.lock().unwrap().values() {
                let _ = sess.ctrl.lock().unwrap().shutdown(std::net::Shutdown::Read);
            }
            // parked resumable sessions can no longer be adopted: drop
            // their ledgers so nothing keeps the registry alive
            shared.detached.lock().unwrap().clear();
            let handlers: Vec<_> = std::mem::take(&mut *shared.handlers.lock().unwrap());
            for h in handlers {
                let _ = h.join();
            }
            // closed + drained: the dispatcher finishes the queue and exits
            shared.batcher.close();
            if let Some(t) = dispatcher {
                let _ = t.join();
            }
            if let Some(t) = stats_thread {
                let _ = t.join();
            }
        };
        match mode {
            ShutdownMode::Abort => {
                self.shared.abort();
                drain();
                Ok(())
            }
            ShutdownMode::Drain => {
                let timeout = self.shared.cfg.drain_timeout;
                let (tx, rx) = std::sync::mpsc::channel();
                let helper = std::thread::Builder::new()
                    .name("sp-server-drain".into())
                    .spawn(move || {
                        drain();
                        let _ = tx.send(());
                    })?;
                match rx.recv_timeout(timeout) {
                    Ok(()) => {
                        let _ = helper.join();
                        Ok(())
                    }
                    Err(_) => {
                        self.shared.abort();
                        let _ = helper.join();
                        bail!("server drain exceeded {timeout:?}; in-flight work aborted")
                    }
                }
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // non-panicking under in-flight sessions; no-op after an explicit
        // shutdown (the thread handles are already taken)
        let _ = self.shutdown_mode(ShutdownMode::Abort);
    }
}

/// Join handler threads that already finished, keeping the registry small
/// on long-lived servers with session churn.
fn reap_finished(shared: &ServerShared) {
    let mut handlers = shared.handlers.lock().unwrap();
    let mut live = Vec::with_capacity(handlers.len());
    for h in handlers.drain(..) {
        if h.is_finished() {
            let _ = h.join();
        } else {
            live.push(h);
        }
    }
    *handlers = live;
}

fn accept_loop(listener: TcpListener, shared: &Arc<ServerShared>) {
    while !shared.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                reap_finished(shared);
                let active = shared.sessions.lock().unwrap().len();
                if active >= shared.cfg.max_sessions {
                    shared.metrics.accept_refusals.inc();
                    let mut stream = stream;
                    let _ = write_message(
                        &mut stream,
                        &Message::Error {
                            request_id: 0,
                            message: format!(
                                "session capacity reached ({active} active, cap {}); retry later",
                                shared.cfg.max_sessions
                            ),
                        },
                    );
                    continue; // refused: the socket drops here
                }
                match spawn_session(shared, stream, peer) {
                    Ok(handle) => shared.handlers.lock().unwrap().push(handle),
                    Err(e) => eprintln!("server: failed to start session for {peer}: {e:#}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn spawn_session(
    shared: &Arc<ServerShared>,
    stream: TcpStream,
    peer: SocketAddr,
) -> Result<std::thread::JoinHandle<()>> {
    let id = shared.next_session.fetch_add(1, Ordering::Relaxed) + 1;
    let reader = stream.try_clone()?;
    let ctrl = stream.try_clone()?;
    let sid = id.to_string();
    let labels = [("session", sid.as_str())];
    let reg = &shared.registry;
    let sess = Arc::new(SessionState {
        id,
        peer: peer.to_string(),
        sock: Mutex::new(stream),
        ctrl: Mutex::new(ctrl),
        replies: Reorder::new(),
        win: Mutex::new(Window {
            in_flight: 0,
            submitted: 0,
        }),
        win_cv: Condvar::new(),
        alive: AtomicBool::new(true),
        resume: Mutex::new(ResumeState::default()),
        resumes: reg.counter(
            "sp_server_session_resumes_total",
            "Resume adoptions per session",
            &labels,
        ),
        frames: reg.counter(
            "sp_server_session_frames_total",
            "Tail jobs completed per session",
            &labels,
        ),
        bytes_in: reg.counter(
            "sp_server_session_uplink_bytes_total",
            "Request bytes received per session",
            &labels,
        ),
        bytes_out: reg.counter(
            "sp_server_session_downlink_bytes_total",
            "Reply bytes sent per session",
            &labels,
        ),
        tail_nanos: reg.counter(
            "sp_server_session_tail_nanos_total",
            "Cumulative tail compute per session, nanoseconds",
            &labels,
        ),
    });
    shared.sessions.lock().unwrap().insert(id, sess.clone());
    shared.metrics.sessions_total.inc();
    let shared = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("sp-server-sess-{id}"))
        .spawn(move || run_session(&shared, &sess, reader));
    match spawned {
        Ok(handle) => Ok(handle),
        Err(e) => {
            // roll the registration back so the slot frees immediately
            shared.sessions.lock().unwrap().remove(&id);
            unregister_session_metrics(&shared, id);
            Err(e).context("spawning session handler")
        }
    }
}

/// Drop a finished session's per-session metrics from its server's
/// registry. Handles still held by in-flight tail jobs keep counting;
/// the label set just stops rendering.
fn unregister_session_metrics(shared: &ServerShared, id: u64) {
    let sid = id.to_string();
    let labels = [("session", sid.as_str())];
    for name in [
        "sp_server_session_resumes_total",
        "sp_server_session_frames_total",
        "sp_server_session_uplink_bytes_total",
        "sp_server_session_downlink_bytes_total",
        "sp_server_session_tail_nanos_total",
    ] {
        shared.registry.unregister(name, &labels);
    }
}

/// How one pass of [`session_loop`] ended.
enum SessionEnd {
    /// Clean close (client `Shutdown`, or teardown): forget the session.
    Closed,
    /// The connection died. A resumable session is parked for adoption
    /// instead of being torn down.
    Lost,
    /// The client sent a resume handshake: this fresh connection should
    /// adopt the parked session behind `token`.
    ResumeInto { token: u64, acked_up_to: u64 },
}

/// Mint a resume token: unguessable enough to not collide, never zero
/// (zero is the "not resumable" sentinel on the wire).
fn next_resume_token(shared: &ServerShared) -> u64 {
    let counter = shared.next_token.fetch_add(1, Ordering::Relaxed);
    let clock = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    Rng::new(counter ^ clock.rotate_left(32)).next_u64().max(1)
}

/// Park a dropped resumable session for later adoption. Returns `false`
/// when the session is not resumable (or the server is stopping) and
/// should be torn down instead.
fn park_session(shared: &ServerShared, sess: &Arc<SessionState>) -> bool {
    if sess.resume.lock().unwrap().token == 0 || shared.stop.load(Ordering::Acquire) {
        return false;
    }
    let token = sess.resume.lock().unwrap().token;
    // dead socket: tail workers must ledger replies, not write them
    sess.alive.store(false, Ordering::Release);
    shared.sessions.lock().unwrap().remove(&sess.id);
    let mut detached = shared.detached.lock().unwrap();
    while detached.len() >= DETACHED_CAP {
        match detached.values().map(|s| s.id).min() {
            Some(oldest) => {
                detached.retain(|_, s| s.id != oldest);
            }
            None => break,
        }
    }
    detached.insert(token, sess.clone());
    true
}

/// Adopt a parked session onto the fresh connection that sent
/// `Hello { token, acked_up_to }`: prune the ledger up to the client's
/// ack watermark, swap the sockets in, and re-register the old session
/// under its original id. Returns the adopted session; the fresh
/// connection's placeholder state is discarded by the caller.
fn adopt_session(
    shared: &Arc<ServerShared>,
    fresh: &Arc<SessionState>,
    token: u64,
    acked_up_to: u64,
) -> Result<Arc<SessionState>> {
    // The reconnect can beat the old handler noticing EOF: poll briefly
    // for the park to land before declaring the token unknown.
    let deadline = Instant::now() + RESUME_GRACE;
    let old = loop {
        if let Some(old) = shared.detached.lock().unwrap().remove(&token) {
            break old;
        }
        if shared.stop.load(Ordering::Acquire) {
            bail!("server stopping; resume refused");
        }
        if Instant::now() >= deadline {
            let reply = Message::Error {
                request_id: 0,
                message: "unknown resume token".into(),
            };
            let mut sock = fresh.sock.lock().unwrap();
            let _ = write_message(&mut *sock, &reply);
            bail!("resume with unknown token {token:#x}");
        }
        std::thread::sleep(Duration::from_millis(10));
    };

    let new_sock = fresh.sock.lock().unwrap().try_clone()?;
    let new_ctrl = fresh.ctrl.lock().unwrap().try_clone()?;
    {
        let mut r = old.resume.lock().unwrap();
        r.acked = r.acked.max(acked_up_to);
        let acked = r.acked;
        r.done.retain(|&id, _| id > acked);
        r.admitted.retain(|&id| id > acked);
    }
    *old.sock.lock().unwrap() = new_sock;
    *old.ctrl.lock().unwrap() = new_ctrl;
    old.alive.store(true, Ordering::Release);
    old.resumes.inc();
    shared.metrics.sessions_resumed.inc();
    {
        let mut sessions = shared.sessions.lock().unwrap();
        sessions.remove(&fresh.id);
        sessions.insert(old.id, old.clone());
    }
    // the fresh connection's placeholder state is discarded: drop its
    // per-session metrics with it
    unregister_session_metrics(shared, fresh.id);
    let ack = Message::HelloAck { token };
    let n = wire_len(&ack);
    let mut sock = old.sock.lock().unwrap();
    write_message(&mut *sock, &ack).context("acking session resume")?;
    drop(sock);
    old.bytes_out.add(n);
    shared.metrics.bytes_out.add(n);
    Ok(old)
}

/// Session handler wrapper: errors are logged and isolated — a malformed
/// frame or a mid-frame disconnect ends *this* session only, never the
/// accept loop or the shared batcher. A resumable session whose link
/// drops is parked for adoption instead of torn down, and a connection
/// that presents a resume token becomes the parked session it names.
fn run_session(shared: &Arc<ServerShared>, sess: &Arc<SessionState>, reader: TcpStream) {
    let mut sess = sess.clone();
    let mut reader = reader;
    loop {
        match session_loop(shared, &sess, &mut reader) {
            Ok(SessionEnd::Closed) => break,
            Ok(SessionEnd::Lost) => {
                if park_session(shared, &sess) {
                    return; // parked: keep the registry entry out, ledger in
                }
                break;
            }
            Ok(SessionEnd::ResumeInto { token, acked_up_to }) => {
                match adopt_session(shared, &sess, token, acked_up_to) {
                    Ok(adopted) => {
                        sess = adopted;
                        continue; // same reader socket, adopted state
                    }
                    Err(e) => {
                        shared.metrics.session_errors.inc();
                        eprintln!(
                            "server: session {} ({}) resume failed: {e:#}",
                            sess.id, sess.peer
                        );
                        break;
                    }
                }
            }
            Err(e) => {
                // a mid-frame cut on a resumable session is the event
                // resume exists for — park it, don't count an error
                if park_session(shared, &sess) {
                    return;
                }
                shared.metrics.session_errors.inc();
                eprintln!(
                    "server: session {} ({}) ended with error (others unaffected): {e:#}",
                    sess.id, sess.peer
                );
                break;
            }
        }
    }
    shared.sessions.lock().unwrap().remove(&sess.id);
    unregister_session_metrics(shared, sess.id);
    // tail jobs still in flight hold the session Arc: their replies flush
    // (or are dropped if the socket died) and the window drains after us.
}

/// What to do with an `Infer` whose request id a resumable session has
/// seen before.
enum Dedup {
    Admit,
    Drop,
    Resend(Message),
}

fn session_loop(
    shared: &Arc<ServerShared>,
    sess: &Arc<SessionState>,
    reader: &mut TcpStream,
) -> Result<SessionEnd> {
    loop {
        // Distinguish a clean close (EOF *between* frames — a client that
        // just went away) from a mid-frame cut (malformed peer): read one
        // byte manually, then parse the rest of the frame behind it.
        let mut first = [0u8; 1];
        let n = loop {
            match reader.read(&mut first) {
                Ok(n) => break n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) if shared.stop.load(Ordering::Acquire) => return Ok(SessionEnd::Closed),
                Err(e) => return Err(e).context("reading session socket"),
            }
        };
        if n == 0 {
            // EOF at a frame boundary: drain teardown or a client that
            // went away (a resumable one may come back)
            if shared.stop.load(Ordering::Acquire) {
                return Ok(SessionEnd::Closed);
            }
            return Ok(SessionEnd::Lost);
        }
        let msg = match read_message(&mut (&first[..]).chain(&mut *reader)) {
            Ok(m) => m,
            // cut mid-read by teardown
            Err(_) if shared.stop.load(Ordering::Acquire) => return Ok(SessionEnd::Closed),
            Err(e) => return Err(e).context("malformed frame"),
        };
        match msg {
            Message::Shutdown => return Ok(SessionEnd::Closed),
            Message::Hello {
                token: 0,
                acked_up_to: _,
            } => {
                // open a new resumable session: mint a token, remember it,
                // hand it back
                let token = next_resume_token(shared);
                sess.resume.lock().unwrap().token = token;
                let ack = Message::HelloAck { token };
                let n = wire_len(&ack);
                let mut sock = sess.sock.lock().unwrap();
                write_message(&mut *sock, &ack).context("acking resumable hello")?;
                drop(sock);
                sess.bytes_out.add(n);
                shared.metrics.bytes_out.add(n);
            }
            Message::Hello {
                token,
                acked_up_to,
            } => return Ok(SessionEnd::ResumeInto { token, acked_up_to }),
            Message::Stats => {
                let text = shared.snapshot().to_text();
                let reply = Message::StatsResult { text };
                let n = wire_len(&reply);
                let mut sock = sess.sock.lock().unwrap();
                write_message(&mut *sock, &reply).context("writing stats reply")?;
                drop(sock);
                sess.bytes_out.add(n);
                shared.metrics.bytes_out.add(n);
            }
            Message::Infer {
                request_id,
                head_len,
                packet,
            } => {
                let rx_bytes = 18 + packet.len() as u64;
                sess.bytes_in.add(rx_bytes);
                shared.metrics.bytes_in.add(rx_bytes);

                // resumable-session dedup: a retransmitted request id is
                // never executed twice — drop it (in flight or already
                // acknowledged) or re-serve the ledgered reply
                let dedup = {
                    let r = sess.resume.lock().unwrap();
                    if r.token == 0 {
                        Dedup::Admit
                    } else if request_id <= r.acked {
                        Dedup::Drop
                    } else if r.admitted.contains(&request_id) {
                        match r.done.get(&request_id) {
                            Some(reply) => Dedup::Resend(reply.clone()),
                            None => Dedup::Drop, // still in flight
                        }
                    } else {
                        Dedup::Admit
                    }
                };
                match dedup {
                    Dedup::Admit => {}
                    Dedup::Drop => {
                        shared.metrics.retransmits.inc();
                        continue;
                    }
                    Dedup::Resend(reply) => {
                        shared.metrics.retransmits.inc();
                        let tx_bytes = wire_len(&reply);
                        let mut sock = sess.sock.lock().unwrap();
                        write_message(&mut *sock, &reply).context("resending ledgered reply")?;
                        drop(sock);
                        sess.bytes_out.add(tx_bytes);
                        shared.metrics.bytes_out.add(tx_bytes);
                        continue;
                    }
                }

                // global admission: refuse (with a retry hint) rather than
                // queue unboundedly
                let pending = shared.pending.load(Ordering::Acquire);
                if pending >= shared.cfg.pending_cap {
                    shared.metrics.busy_rejections.inc();
                    let reply = Message::Busy {
                        request_id,
                        pending: pending as u64,
                    };
                    let tx_bytes = wire_len(&reply);
                    let mut sock = sess.sock.lock().unwrap();
                    write_message(&mut *sock, &reply).context("writing busy reply")?;
                    drop(sock);
                    sess.bytes_out.add(tx_bytes);
                    shared.metrics.bytes_out.add(tx_bytes);
                    continue;
                }

                // per-session window: stop reading this socket until a
                // slot frees (TCP backpressure reaches the client)
                let seq = {
                    let mut w = sess.win.lock().unwrap();
                    loop {
                        if w.in_flight < shared.cfg.session_window {
                            break;
                        }
                        if shared.aborted.load(Ordering::Acquire) {
                            return Ok(SessionEnd::Closed);
                        }
                        let (guard, _) = sess
                            .win_cv
                            .wait_timeout(w, Duration::from_millis(100))
                            .unwrap();
                        w = guard;
                    }
                    w.in_flight += 1;
                    let seq = w.submitted;
                    w.submitted += 1;
                    seq
                };
                {
                    // register the admitted id before the push so a
                    // concurrent retransmission can never double-admit
                    let mut r = sess.resume.lock().unwrap();
                    if r.token != 0 {
                        r.admitted.insert(request_id);
                    }
                }
                shared.pending.fetch_add(1, Ordering::AcqRel);
                let job = TailJob {
                    session: sess.clone(),
                    seq,
                    request_id,
                    head_len,
                    packet,
                };
                if !shared.batcher.push(job) {
                    // only reachable once teardown closed the queue; keep
                    // the reply chain gap-free so earlier frames still flush
                    shared.pending.fetch_sub(1, Ordering::AcqRel);
                    sess.complete(
                        seq,
                        Message::Error {
                            request_id,
                            message: "server draining; resubmit".into(),
                        },
                        shared,
                    );
                }
            }
            other => bail!("server got unexpected {other:?}"),
        }
    }
}

/// Dispatcher: pull coalesced batches off the shared queue and scatter
/// them over the engine's kernel pool. Exits when the batcher is closed
/// and drained (teardown).
fn dispatch_loop(shared: &Arc<ServerShared>) {
    let mut batch: Vec<TailJob> = Vec::new();
    while shared.batcher.next_batch_into(&mut batch) {
        let depth = shared.batcher.pending();
        shared.metrics.queue_occupancy.lock().unwrap().record(depth);
        shared.metrics.queue_depth.observe(depth as f64);
        shared.metrics.tail_batches.inc();
        let mut ids: Vec<u64> = batch.iter().map(|j| j.session.id).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() > 1 {
            shared.metrics.multi_session_batches.inc();
        }

        let slots = shared.cfg.tail_slots.clamp(1, batch.len());
        let jobs = &batch;
        match shared.engine.runtime().kernel_pool() {
            Some(pool) if slots > 1 => pool.scatter_ranges(jobs.len(), slots, |range| {
                for job in &jobs[range] {
                    run_tail_job(shared, job);
                }
            }),
            _ => {
                for job in jobs {
                    run_tail_job(shared, job);
                }
            }
        }
        let done = batch.len();
        batch.clear(); // drops the session Arcs
        shared.pending.fetch_sub(done, Ordering::AcqRel);
    }
}

/// Execute one tail job and route its reply. Each frame's tail work is
/// independent (own store, shared read-only weights), so batch membership
/// and lane assignment never change the computed bytes — the determinism
/// contract cross-client batching rests on.
fn run_tail_job(shared: &ServerShared, job: &TailJob) {
    // A resumable session with a dead socket still computes: the reply is
    // ledgered by `complete` and retransmitted after the resume.
    let resumable = job.session.resume.lock().unwrap().token != 0;
    if shared.aborted.load(Ordering::Acquire)
        || (!job.session.alive.load(Ordering::Acquire) && !resumable)
    {
        // aborting, or the client is gone for good: keep the reply chain
        // gap-free without burning tail compute
        job.session.complete(
            job.seq,
            Message::Error {
                request_id: job.request_id,
                message: "server aborted".into(),
            },
            shared,
        );
        return;
    }
    let reply = match serve_infer(&shared.engine, job.head_len as usize, &job.packet) {
        Ok((server_nanos, bytes)) => {
            job.session.tail_nanos.add(server_nanos);
            shared.metrics.tail_nanos.add(server_nanos);
            shared.metrics.tail_seconds.observe(server_nanos as f64 / 1e9);
            Message::InferResult {
                request_id: job.request_id,
                server_nanos,
                packet: bytes,
            }
        }
        Err(e) => Message::Error {
            request_id: job.request_id,
            message: format!("{e:#}"),
        },
    };
    job.session.frames.inc();
    shared.metrics.frames.inc();
    job.session.complete(job.seq, reply, shared);
}

/// Periodic stderr heartbeat (opt-in via `ServerConfig::stats_interval`).
fn stats_loop(shared: &Arc<ServerShared>, interval: Duration) {
    let mut last = Instant::now();
    while !shared.stop.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_millis(100));
        if last.elapsed() >= interval {
            eprintln!("{}", shared.snapshot().summary());
            last = Instant::now();
        }
    }
}

/// Fetch a server's metrics snapshot over the wire (the `Stats` protocol
/// request) on a dedicated short-lived connection.
pub fn fetch_stats<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<String> {
    let mut stream =
        TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
    stream.set_nodelay(true)?;
    write_message(&mut stream, &Message::Stats)?;
    match read_message(&mut stream)? {
        Message::StatsResult { text } => {
            let _ = write_message(&mut stream, &Message::Shutdown);
            Ok(text)
        }
        Message::Error { message, .. } => bail!("server error: {message}"),
        other => bail!("unexpected stats reply {other:?}"),
    }
}

/// Run the tail for one request. Returns (server compute nanos, response).
fn serve_infer(engine: &Engine, head_len: usize, packet: &[u8]) -> Result<(u64, Vec<u8>)> {
    let graph = engine.graph();
    let start = head_len.min(graph.len());
    let sp = SplitPoint { head_len: start };
    let decoded = Packet::decode(packet)?;
    let mut store = engine.new_store();
    for (name, t) in decoded.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("wire tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }

    let t0 = Instant::now();
    for idx in start..graph.len() {
        engine.run_node(idx, &mut store)?;
    }
    let server_nanos = t0.elapsed().as_nanos() as u64;

    let reply = Packet::from_shared(
        graph
            .response_ids(sp)
            .iter()
            .map(|&id| -> Result<_> {
                Ok((
                    graph.tensor_name(id).to_string(),
                    store
                        .get(id)
                        .cloned()
                        .with_context(|| {
                            format!("response tensor '{}' missing", graph.tensor_name(id))
                        })?,
                ))
            })
            .collect::<Result<_>>()?,
    );
    let bytes = reply.encode(engine.config().codec);
    engine.reclaim_scratch(&mut store);
    Ok((server_nanos, bytes))
}

/// Per-frame wire byte accounting extracted alongside the bytes by
/// [`wire_with_v1`]: the actual frame plus the v1 and f32/v2 baselines
/// (and the v3 cost when a lossy precision shipped).
struct WireCost {
    v1: usize,
    f32b: usize,
    v3: usize,
}

/// Take a head frame's wire bytes for the TCP protocol (an encoded empty
/// packet when the live set is empty — the protocol always ships one),
/// plus the v1-framing / f32-precision cost of what actually ships: for
/// an empty packet the framing is identical under every version, so the
/// baselines are charged symmetrically and `wire_savings` /
/// `quant_savings` stay honest.
fn wire_with_v1(head: &mut HeadFrame, codec: Policy) -> (Vec<u8>, WireCost) {
    let v1 = head.wire_v1_bytes();
    let f32b = head.wire_f32_bytes();
    let v3 = head.wire_v3_bytes();
    let bytes = head
        .take_wire()
        .unwrap_or_else(|| Packet::from_shared(Vec::new()).encode(codec));
    let cost = WireCost {
        v1: if v1 == 0 { bytes.len() } else { v1 },
        f32b: if f32b == 0 { bytes.len() } else { f32b },
        v3,
    };
    (bytes, cost)
}

/// Timing of one remote frame (wall-clock, realtime).
#[derive(Debug, Clone)]
pub struct RemoteTiming {
    pub edge_compute: SimTime,
    pub uplink_bytes: usize,
    /// legacy v1-framing cost of the same live set (wire-savings metric)
    pub uplink_v1_bytes: usize,
    /// exact-f32 (v2 framing) cost of the same live set — the baseline
    /// quantized runs are measured against; equals `uplink_bytes` on f32
    /// sessions
    pub uplink_f32_bytes: usize,
    /// bytes actually shipped under v3 quantized framing (0 on f32 runs)
    pub uplink_v3_bytes: usize,
    /// send → result received (uplink + server + downlink)
    pub round_trip: SimTime,
    pub server_compute: SimTime,
    pub inference_time: SimTime,
}

/// Client-side resilience knobs shared by [`EdgeClient`] and
/// [`EdgeStream`].
#[derive(Debug, Clone, Default)]
pub struct ClientOptions {
    /// Backoff schedule for `Busy` refusals and (with `resume` on)
    /// reconnect attempts. [`RetryPolicy::none()`] restores the
    /// fail-fast behavior.
    pub retry: RetryPolicy,
    /// Open the session with a resume handshake so a dropped connection
    /// is transparently re-established with no frame lost or duplicated.
    /// Off by default: the clean-path byte stream is unchanged.
    pub resume: bool,
}

/// Link-resilience counters, written by the client/stream retry paths and
/// read by the policy plane through `Transport::link_health`.
#[derive(Debug, Default)]
pub struct LinkCounters {
    pub retries: AtomicU64,
    pub reconnects: AtomicU64,
    pub backoff_nanos: AtomicU64,
}

impl LinkCounters {
    pub fn health(&self) -> LinkHealth {
        LinkHealth {
            retries: self.retries.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            backoff_time: SimTime {
                nanos: self.backoff_nanos.load(Ordering::Relaxed) as u128,
            },
            stall_time: SimTime::ZERO,
            rtt: None,
        }
    }
}

/// Sleep one backoff delay, accounting it into the counters.
fn sleep_backoff(counters: &LinkCounters, delay: Duration) {
    counters
        .backoff_nanos
        .fetch_add(delay.as_nanos() as u64, Ordering::Relaxed);
    std::thread::sleep(delay);
}

/// One server reply to an `Infer`, classified for the retry loop: links
/// fail with `Err` (reconnectable under resume), the server answers with
/// one of these.
enum InferReply {
    Done { server_nanos: u64, packet: Vec<u8> },
    Busy { pending: u64 },
    Failed(String),
}

/// Read the server's reply to `expected_id` without applying it. Replies
/// for ids *below* `expected_id` are stale duplicates — a retransmit
/// racing the in-flight original after a resume can produce one — and are
/// skipped (request ids are monotonic, so "below expected" is exactly
/// "already delivered" on the serial client).
fn read_infer_reply(stream: &mut TcpStream, expected_id: u64) -> Result<InferReply> {
    loop {
        match read_message(stream)? {
            Message::InferResult {
                request_id: rid,
                server_nanos,
                packet,
            } => {
                if rid < expected_id {
                    continue;
                }
                if rid != expected_id {
                    bail!("response id {rid} != request {expected_id}");
                }
                return Ok(InferReply::Done {
                    server_nanos,
                    packet,
                });
            }
            Message::Busy {
                request_id: rid,
                pending,
            } => {
                if rid < expected_id {
                    continue;
                }
                return Ok(InferReply::Busy { pending });
            }
            Message::Error {
                request_id: rid,
                message,
            } => {
                if rid != 0 && rid < expected_id {
                    continue;
                }
                return Ok(InferReply::Failed(message));
            }
            other => bail!("unexpected reply {other:?}"),
        }
    }
}

/// Apply a successful reply: decode the response tensors into `store`,
/// finalize, reclaim scratch.
fn finalize_reply(
    engine: &Engine,
    store: &mut crate::model::graph::TensorStore,
    resp_packet: &[u8],
) -> Result<Vec<Detection>> {
    let graph = engine.graph();
    for (name, t) in Packet::decode(resp_packet)?.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("response tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }
    let detections = engine.finalize(store)?;
    engine.reclaim_scratch(store);
    Ok(detections)
}

/// Open a resumable session on a fresh connection: `Hello { token: 0 }`
/// asks the server to mint a token; the `HelloAck` carries it back.
fn open_resumable(stream: &mut TcpStream) -> Result<u64> {
    let hello = Message::Hello {
        token: 0,
        acked_up_to: 0,
    };
    write_message(stream, &hello)?;
    match read_message(stream)? {
        Message::HelloAck { token } => Ok(token),
        Message::Error { message, .. } => {
            bail!("server refused resumable session: {message}")
        }
        other => bail!("unexpected handshake reply {other:?}"),
    }
}

/// Reconnect and present a resume token. `Ok(None)` means the attempt
/// failed in a retryable way (server not back yet); `Err` means the
/// server actively refused the resume — don't keep trying.
fn dial_resume(addr: SocketAddr, token: u64, acked: u64) -> Result<Option<TcpStream>> {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return Ok(None),
    };
    if stream.set_nodelay(true).is_err() {
        return Ok(None);
    }
    let hello = Message::Hello {
        token,
        acked_up_to: acked,
    };
    if write_message(&mut stream, &hello).is_err() {
        return Ok(None);
    }
    match read_message(&mut stream) {
        Ok(Message::HelloAck { token: t }) if t == token => Ok(Some(stream)),
        Ok(Message::Error { message, .. }) => bail!("server refused resume: {message}"),
        Ok(other) => bail!("unexpected resume reply {other:?}"),
        Err(_) => Ok(None),
    }
}

/// Edge-device client for a remote server.
pub struct EdgeClient {
    stream: TcpStream,
    engine: Arc<Engine>,
    next_id: u64,
    /// resolved server address, kept for reconnects
    addr: Option<SocketAddr>,
    opts: ClientOptions,
    /// resume token from the handshake (0 = session not resumable)
    token: u64,
    /// highest request id fully delivered (the resume ack watermark)
    acked: u64,
    counters: Arc<LinkCounters>,
}

impl EdgeClient {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        engine: Arc<Engine>,
    ) -> Result<EdgeClient> {
        EdgeClient::connect_with(addr, engine, ClientOptions::default())
    }

    /// Connect with explicit resilience knobs. With `opts.resume` the
    /// session opens with a `Hello` handshake and survives link drops;
    /// otherwise the wire traffic is byte-identical to [`EdgeClient::connect`].
    pub fn connect_with<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        engine: Arc<Engine>,
        opts: ClientOptions,
    ) -> Result<EdgeClient> {
        let resolved = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr:?}"))?
            .next();
        let mut stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        stream.set_nodelay(true)?;
        let token = if opts.resume {
            open_resumable(&mut stream)?
        } else {
            0
        };
        Ok(EdgeClient {
            stream,
            engine,
            next_id: 1,
            addr: resolved,
            opts,
            token,
            acked: 0,
            counters: Arc::new(LinkCounters::default()),
        })
    }

    /// The client's link-resilience counters (shared with any
    /// [`EdgeStream`] it is converted into).
    pub fn counters(&self) -> Arc<LinkCounters> {
        self.counters.clone()
    }

    /// Replace the dead connection via the resume handshake, driving the
    /// shared backoff budget. `cause` is returned when the session is not
    /// resumable or the budget runs out.
    fn reconnect(&mut self, backoff: &mut Backoff, cause: anyhow::Error) -> Result<()> {
        let addr = match self.addr {
            Some(a) if self.token != 0 => a,
            _ => return Err(cause),
        };
        loop {
            let delay = match backoff.next_delay() {
                Some(d) => d,
                None => {
                    return Err(cause).with_context(|| {
                        format!(
                            "link lost; reconnect budget exhausted after {} attempt(s)",
                            backoff.attempts()
                        )
                    })
                }
            };
            sleep_backoff(&self.counters, delay);
            match dial_resume(addr, self.token, self.acked)? {
                Some(stream) => {
                    self.stream = stream;
                    self.counters.reconnects.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                None => continue,
            }
        }
    }

    /// Run one frame: head locally, tail on the server. The head half is
    /// the engine's own [`Engine::head_stage`] — the TCP client is a thin
    /// shell that ships the stage's wire bytes over a real socket.
    pub fn run_frame(
        &mut self,
        cloud: &PointCloud,
        sp: SplitPoint,
    ) -> Result<(Vec<Detection>, RemoteTiming)> {
        let engine = self.engine.clone();
        let t_start = Instant::now();

        let mut head = engine.head_stage(cloud, sp)?;
        let (bytes, wire_cost) = wire_with_v1(&mut head, engine.config().codec);
        let (mut store, _) = head.into_store();
        let edge_compute = SimTime::from_duration(t_start.elapsed());

        let request_id = self.next_id;
        self.next_id += 1;
        let uplink_bytes = bytes.len();
        let msg = Message::Infer {
            request_id,
            head_len: sp.head_len as u8,
            packet: bytes,
        };
        // Busy refusals back off and resubmit; link errors reconnect and
        // retransmit when the session is resumable. The server dedups
        // retransmissions by request id, so a frame is never executed
        // twice. `round_trip` includes any backoff — that is the observed
        // latency under a hostile link, which is the point.
        let mut backoff = self.opts.retry.backoff(request_id);
        let t_send = Instant::now();
        let (server_nanos, resp_packet) = loop {
            let attempt = write_message(&mut self.stream, &msg)
                .and_then(|()| read_infer_reply(&mut self.stream, request_id));
            match attempt {
                Ok(InferReply::Done {
                    server_nanos,
                    packet,
                }) => break (server_nanos, packet),
                Ok(InferReply::Failed(message)) => bail!("server error: {message}"),
                Ok(InferReply::Busy { pending }) => {
                    let delay = backoff.next_delay().with_context(|| {
                        format!(
                            "server saturated ({pending} request(s) pending); \
                             gave up after {} retries",
                            backoff.max_retries()
                        )
                    })?;
                    self.counters.retries.fetch_add(1, Ordering::Relaxed);
                    sleep_backoff(&self.counters, delay);
                }
                Err(e) => self.reconnect(&mut backoff, e)?,
            }
        };
        let round_trip = SimTime::from_duration(t_send.elapsed());
        let detections = finalize_reply(&engine, &mut store, &resp_packet)?;
        self.acked = request_id;
        let inference_time = SimTime::from_duration(t_start.elapsed());

        Ok((
            detections,
            RemoteTiming {
                edge_compute,
                uplink_bytes,
                uplink_v1_bytes: wire_cost.v1,
                uplink_f32_bytes: wire_cost.f32b,
                uplink_v3_bytes: wire_cost.v3,
                round_trip,
                server_compute: SimTime {
                    nanos: server_nanos as u128,
                },
                inference_time,
            },
        ))
    }

    /// Graceful close: tell the server the session is over.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_mode(ShutdownMode::Drain)
    }

    /// Convert this client into a persistent incremental stream handle
    /// (see [`EdgeStream`]): frames are submitted one at a time and the
    /// in-flight window survives across submit bursts, so a session
    /// feeding segments into the handle never drains the pipe at a
    /// segment boundary. `depth` caps in-flight frames; `depth <= 1`
    /// still overlaps head(N+1) with the server round trip of frame N
    /// one frame at a time.
    pub fn into_stream(self, depth: usize) -> Result<EdgeStream> {
        EdgeStream::spawn(self, depth)
    }

    /// Pipelined streaming: overlap the local head compute of frame N+1
    /// with the server round trip of frame N.
    ///
    /// A writer thread runs [`Engine::head_stage`] per frame and sends the
    /// wire packet; this thread receives responses and finalizes, in
    /// submission order (the server preserves a connection's FIFO reply
    /// order even when it batches across sessions). `depth` caps in-flight
    /// frames: `depth <= 1` degenerates to the serial
    /// [`EdgeClient::run_frame`] loop. Per-frame `round_trip` now includes
    /// queueing — at the server, and on the client side whenever
    /// backpressure stalls the writer before the request reaches the
    /// socket — which is the point: latency is traded for the throughput
    /// that overlap buys.
    pub fn run_stream(
        &mut self,
        clouds: &[PointCloud],
        sp: SplitPoint,
        depth: usize,
    ) -> Result<Vec<(Vec<Detection>, RemoteTiming)>> {
        if depth <= 1 {
            return clouds.iter().map(|c| self.run_frame(c, sp)).collect();
        }
        let engine = self.engine.clone();
        let mut write_stream = self.stream.try_clone()?;
        let first_id = self.next_id;
        self.next_id += clouds.len() as u64;
        // the channel bounds in-flight requests: the writer blocks sending
        // the pending record once `depth` frames are outstanding
        let (tx, rx) = std::sync::mpsc::sync_channel::<PendingRequest>(depth.max(1));

        // scoped writer thread: borrows `clouds` directly (no up-front
        // deep copy of the whole stream) and is always joined before this
        // function returns
        let (read_all, write_res) = std::thread::scope(|scope| {
            let writer = scope.spawn(move || -> Result<()> {
                let sent = send_stream(&engine, &mut write_stream, clouds, sp, first_id, &tx);
                if sent.is_err() {
                    // unblock the reader, which would otherwise wait on a
                    // reply that will never be sent
                    let _ = write_stream.shutdown(std::net::Shutdown::Both);
                }
                sent
            });
            let read_all = self.recv_stream(&rx);
            // drop the receiver before joining: a writer blocked on a full
            // channel fails its send and exits
            drop(rx);
            if read_all.is_err() {
                // unblock a writer stuck in a socket write: with the reader
                // gone the TCP windows can back up and block it forever
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
            }
            let write_res = writer
                .join()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("edge writer thread panicked")));
            (read_all, write_res)
        });
        let frames = match (read_all, write_res) {
            (Ok(frames), Ok(())) => frames,
            // reader finished but the writer failed — the write error is
            // the only cause
            (Ok(_), Err(w)) => return Err(w),
            // reader failed, writer fine (e.g. a server Error reply)
            (Err(r), Ok(())) => return Err(r),
            // both failed: either side's shutdown fails the other, so keep
            // both causes visible instead of guessing the root
            (Err(r), Err(w)) => {
                return Err(anyhow::anyhow!(
                    "pipelined stream failed — reader: {r:#}; writer: {w:#}"
                ))
            }
        };
        if frames.len() != clouds.len() {
            bail!(
                "stream ended early: {} of {} frames completed",
                frames.len(),
                clouds.len()
            );
        }
        Ok(frames)
    }

    /// Reader half of the pipelined stream: for every pending request (in
    /// FIFO order) receive the server's reply, decode the response tensors
    /// into the request's store and finalize. Ends when the writer drops
    /// its sender and the channel drains.
    fn recv_stream(
        &mut self,
        rx: &std::sync::mpsc::Receiver<PendingRequest>,
    ) -> Result<Vec<(Vec<Detection>, RemoteTiming)>> {
        let engine = self.engine.clone();
        let mut out = Vec::new();
        while let Ok(mut pending) = rx.recv() {
            let (detections, server_nanos, round_trip) = receive_reply(
                &mut self.stream,
                &engine,
                pending.request_id,
                &mut pending.store,
                pending.t_send,
            )?;
            out.push((
                detections,
                RemoteTiming {
                    edge_compute: pending.edge_compute,
                    uplink_bytes: pending.uplink_bytes,
                    uplink_v1_bytes: pending.uplink_v1_bytes,
                    uplink_f32_bytes: pending.uplink_f32_bytes,
                    uplink_v3_bytes: pending.uplink_v3_bytes,
                    round_trip,
                    server_compute: SimTime {
                        nanos: server_nanos as u128,
                    },
                    inference_time: SimTime::from_duration(pending.t_start.elapsed()),
                },
            ));
        }
        Ok(out)
    }
}

impl Shutdown for EdgeClient {
    fn shutdown_mode(&mut self, mode: ShutdownMode) -> Result<()> {
        match mode {
            // the serial client never has frames in flight between calls:
            // drain == telling the server the session is over
            ShutdownMode::Drain => write_message(&mut self.stream, &Message::Shutdown),
            ShutdownMode::Abort => {
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                Ok(())
            }
        }
    }
}

/// Receive and apply one server reply for `expected_id` (shared by the
/// serial and pipelined clients, which the tests assert are equivalent):
/// match the `InferResult`, decode the response tensors into `store`,
/// finalize, reclaim scratch. Returns the detections, the server's
/// self-reported compute nanos and the send→receive round trip.
fn receive_reply(
    stream: &mut TcpStream,
    engine: &Engine,
    expected_id: u64,
    store: &mut crate::model::graph::TensorStore,
    t_send: Instant,
) -> Result<(Vec<Detection>, u64, SimTime)> {
    let reply = read_message(stream)?;
    let round_trip = SimTime::from_duration(t_send.elapsed());
    let (server_nanos, resp_packet) = match reply {
        Message::InferResult {
            request_id: rid,
            server_nanos,
            packet,
        } => {
            if rid != expected_id {
                bail!("response id {rid} != request {expected_id}");
            }
            (server_nanos, packet)
        }
        Message::Busy { pending, .. } => {
            bail!("server saturated ({pending} request(s) pending); retry later")
        }
        Message::Error { message, .. } => bail!("server error: {message}"),
        other => bail!("unexpected reply {other:?}"),
    };
    let graph = engine.graph();
    for (name, t) in Packet::decode(&resp_packet)?.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("response tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }
    let detections = engine.finalize(store)?;
    engine.reclaim_scratch(store);
    Ok((detections, server_nanos, round_trip))
}

/// One frame of the writer half, shared by the one-shot
/// [`EdgeClient::run_stream`] and the persistent [`EdgeStream`]: head
/// compute, wire encode, park the pending record on the bounded channel
/// (*before* the socket write, so the channel capacity caps in-flight
/// frames and the reader always has the store a reply refers to), then
/// send the Infer message. Returns `Ok(false)` when the reader went away
/// (stop quietly), `Ok(true)` on success.
fn send_frame(
    engine: &Engine,
    stream: &mut TcpStream,
    cloud: &PointCloud,
    sp: SplitPoint,
    request_id: u64,
    tx: &std::sync::mpsc::SyncSender<PendingRequest>,
) -> Result<bool> {
    let t_start = Instant::now();
    let mut head = engine.head_stage(cloud, sp)?;
    let (bytes, wire_cost) = wire_with_v1(&mut head, engine.config().codec);
    let (store, _) = head.into_store();
    let pending = PendingRequest {
        request_id,
        store,
        edge_compute: SimTime::from_duration(t_start.elapsed()),
        uplink_bytes: bytes.len(),
        uplink_v1_bytes: wire_cost.v1,
        uplink_f32_bytes: wire_cost.f32b,
        uplink_v3_bytes: wire_cost.v3,
        t_start,
        t_send: Instant::now(),
    };
    if tx.send(pending).is_err() {
        return Ok(false); // reader bailed
    }
    write_message(
        stream,
        &Message::Infer {
            request_id,
            head_len: sp.head_len as u8,
            packet: bytes,
        },
    )?;
    Ok(true)
}

/// [`send_frame`] for the resilient [`EdgeStream`]: same shape, but the
/// socket write goes through the shared write lock and the message is
/// journaled first whenever retries or resume are on — a failed write on
/// a resumable session is *not* an error (the reader reconnects and the
/// journal is replayed).
fn stream_send_frame(
    engine: &Engine,
    shared: &StreamShared,
    cloud: &PointCloud,
    sp: SplitPoint,
    request_id: u64,
    tx: &std::sync::mpsc::SyncSender<PendingRequest>,
) -> Result<bool> {
    let t_start = Instant::now();
    let mut head = engine.head_stage(cloud, sp)?;
    let (bytes, wire_cost) = wire_with_v1(&mut head, engine.config().codec);
    let (store, _) = head.into_store();
    let pending = PendingRequest {
        request_id,
        store,
        edge_compute: SimTime::from_duration(t_start.elapsed()),
        uplink_bytes: bytes.len(),
        uplink_v1_bytes: wire_cost.v1,
        uplink_f32_bytes: wire_cost.f32b,
        uplink_v3_bytes: wire_cost.v3,
        t_start,
        t_send: Instant::now(),
    };
    if tx.send(pending).is_err() {
        return Ok(false); // reader bailed
    }
    let msg = Message::Infer {
        request_id,
        head_len: sp.head_len as u8,
        packet: bytes,
    };
    if shared.opts.resume || shared.opts.retry.max_retries > 0 {
        // journal before the write (never hold `unanswered` across a
        // potentially blocking socket write)
        shared
            .unanswered
            .lock()
            .unwrap()
            .insert(request_id, msg.clone());
    }
    let res = write_message(&mut *shared.sock.lock().unwrap(), &msg);
    match res {
        Ok(()) => Ok(true),
        // journaled: the reader's reconnect replays it
        Err(_) if shared.opts.resume => Ok(true),
        Err(e) => Err(e),
    }
}

/// Writer half of the pipelined stream: [`send_frame`] for every cloud,
/// in order.
fn send_stream(
    engine: &Engine,
    stream: &mut TcpStream,
    clouds: &[PointCloud],
    sp: SplitPoint,
    first_id: u64,
    tx: &std::sync::mpsc::SyncSender<PendingRequest>,
) -> Result<()> {
    for (i, cloud) in clouds.iter().enumerate() {
        if !send_frame(engine, stream, cloud, sp, first_id + i as u64, tx)? {
            return Ok(()); // reader bailed; stop quietly
        }
    }
    Ok(())
}

/// A request in flight on the pipelined edge client: everything the reader
/// needs to finalize the frame once the server replies.
struct PendingRequest {
    request_id: u64,
    store: crate::model::graph::TensorStore,
    edge_compute: SimTime,
    uplink_bytes: usize,
    uplink_v1_bytes: usize,
    uplink_f32_bytes: usize,
    uplink_v3_bytes: usize,
    t_start: Instant,
    t_send: Instant,
}

/// One frame queued into an [`EdgeStream`]: the split travels with the
/// frame, so a policy flip needs no new connection — only the flush the
/// session already performs.
struct StreamJob {
    cloud: PointCloud,
    sp: SplitPoint,
}

/// Persistent incremental streaming handle over one TCP connection — the
/// session-facing inverse of the one-shot [`EdgeClient::run_stream`].
///
/// `run_stream` drains its whole in-flight window before returning, which
/// costs ~depth×RTT of idle wire at every segment boundary of a
/// fixed-policy stream. An `EdgeStream` instead keeps a writer thread and
/// the bounded pending queue alive across submit bursts: callers
/// interleave [`EdgeStream::submit`] and [`EdgeStream::recv`] (results
/// come back in submission order, byte-identical to the serial client —
/// both ends run the same stage functions), and the window only empties
/// when the caller explicitly drains it.
///
/// In-flight frames are capped by the pending channel: the writer blocks
/// forwarding request `depth + 1` until a reply has been received, so a
/// caller that never lets `in_flight()` exceed `depth` before submitting
/// can never deadlock.
pub struct EdgeStream {
    /// reader half (and shutdown control) of the shared socket, replaced
    /// on a resume reconnect
    stream: TcpStream,
    engine: Arc<Engine>,
    shared: Arc<StreamShared>,
    job_tx: Option<std::sync::mpsc::SyncSender<StreamJob>>,
    pending_rx: Option<std::sync::mpsc::Receiver<PendingRequest>>,
    writer: Option<std::thread::JoinHandle<Result<()>>>,
    submitted: u64,
    delivered: u64,
    /// highest request id fully delivered (the resume ack watermark)
    acked: u64,
    /// replies that arrived ahead of the frame the reader is waiting on
    /// (Busy-retry and resume replay can reorder), keyed by request id
    parked: HashMap<u64, (u64, Vec<u8>)>,
}

/// State shared between an [`EdgeStream`]'s reader (the owning thread)
/// and its writer thread. Lock-order rule: never *wait* on `sock` while
/// holding `unanswered` — journal first, drop the guard, then write.
struct StreamShared {
    /// resolved server address, kept for reconnects
    addr: Option<SocketAddr>,
    opts: ClientOptions,
    /// resume token from the handshake (0 = session not resumable)
    token: u64,
    /// write half of the connection, shared so a resume reconnect can
    /// swap it under the writer
    sock: Mutex<TcpStream>,
    /// journal of sent-but-undelivered `Infer` messages for replay, kept
    /// whenever Busy retries or resume are enabled (bounded by depth)
    unanswered: Mutex<BTreeMap<u64, Message>>,
    counters: Arc<LinkCounters>,
}

impl EdgeStream {
    fn spawn(client: EdgeClient, depth: usize) -> Result<EdgeStream> {
        let EdgeClient {
            stream,
            engine,
            next_id,
            addr,
            opts,
            token,
            acked,
            counters,
        } = client;
        let depth = depth.max(1);
        let shared = Arc::new(StreamShared {
            addr,
            opts,
            token,
            sock: Mutex::new(stream.try_clone()?),
            unanswered: Mutex::new(BTreeMap::new()),
            counters,
        });
        let writer_engine = engine.clone();
        let writer_shared = shared.clone();
        // jobs hand off one at a time; the *pending* channel is what caps
        // the in-flight window (same scheme as `run_stream`)
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<StreamJob>(1);
        let (pending_tx, pending_rx) = std::sync::mpsc::sync_channel::<PendingRequest>(depth);
        let writer = std::thread::Builder::new()
            .name("sp-edge-stream".into())
            .spawn(move || -> Result<()> {
                let mut request_id = next_id;
                while let Ok(job) = job_rx.recv() {
                    let sent = stream_send_frame(
                        &writer_engine,
                        &writer_shared,
                        &job.cloud,
                        job.sp,
                        request_id,
                        &pending_tx,
                    );
                    match sent {
                        Ok(true) => request_id += 1,
                        Ok(false) => return Ok(()), // reader bailed; stop quietly
                        Err(e) => {
                            // unblock a reader waiting on a reply that
                            // will never arrive
                            let _ = writer_shared
                                .sock
                                .lock()
                                .unwrap()
                                .shutdown(std::net::Shutdown::Both);
                            return Err(e);
                        }
                    }
                }
                Ok(())
            })?;
        Ok(EdgeStream {
            stream,
            engine,
            shared,
            job_tx: Some(job_tx),
            pending_rx: Some(pending_rx),
            writer: Some(writer),
            submitted: 0,
            delivered: 0,
            acked,
            parked: HashMap::new(),
        })
    }

    /// The stream's link-resilience counters (shared with the
    /// [`EdgeClient`] it was converted from).
    pub fn counters(&self) -> Arc<LinkCounters> {
        self.shared.counters.clone()
    }

    /// Frames submitted but not yet delivered through [`EdgeStream::recv`].
    pub fn in_flight(&self) -> usize {
        (self.submitted - self.delivered) as usize
    }

    /// Queue one frame at split `sp`. Returns as soon as the writer thread
    /// has the frame; keep `in_flight()` at or below the stream's depth
    /// before calling (the session's window loop) so the writer can always
    /// make progress.
    pub fn submit(&mut self, cloud: PointCloud, sp: SplitPoint) -> Result<()> {
        let tx = self.job_tx.as_ref().context("edge stream already finished")?;
        if tx.send(StreamJob { cloud, sp }).is_err() {
            return Err(self.writer_error());
        }
        self.submitted += 1;
        Ok(())
    }

    /// Receive the next completed frame, in submission order. Blocks until
    /// the server's reply lands; erroring with nothing in flight.
    pub fn recv(&mut self) -> Result<(Vec<Detection>, RemoteTiming)> {
        if self.in_flight() == 0 {
            bail!("edge stream recv with no frame in flight");
        }
        let rx = self.pending_rx.as_ref().context("edge stream already finished")?;
        let mut pending = match rx.recv() {
            Ok(p) => p,
            Err(_) => return Err(self.writer_error()),
        };
        let engine = self.engine.clone();
        let (server_nanos, resp_packet) = match self.await_reply(pending.request_id) {
            Ok(r) => r,
            Err(e) => {
                // unblock a writer stuck in a socket write before the
                // error propagates (mirrors `run_stream`)
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                return Err(e);
            }
        };
        let round_trip = SimTime::from_duration(pending.t_send.elapsed());
        let detections = match finalize_reply(&engine, &mut pending.store, &resp_packet) {
            Ok(d) => d,
            Err(e) => {
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                return Err(e);
            }
        };
        self.delivered += 1;
        self.acked = pending.request_id;
        self.shared
            .unanswered
            .lock()
            .unwrap()
            .remove(&pending.request_id);
        Ok((
            detections,
            RemoteTiming {
                edge_compute: pending.edge_compute,
                uplink_bytes: pending.uplink_bytes,
                uplink_v1_bytes: pending.uplink_v1_bytes,
                uplink_f32_bytes: pending.uplink_f32_bytes,
                uplink_v3_bytes: pending.uplink_v3_bytes,
                round_trip,
                server_compute: SimTime {
                    nanos: server_nanos as u128,
                },
                inference_time: SimTime::from_duration(pending.t_start.elapsed()),
            },
        ))
    }

    /// Wait for the reply to `expected`, absorbing everything a hostile
    /// link throws at the pipeline: `Busy` refusals (back off, resubmit
    /// from the journal), replies arriving out of order after a resume
    /// replay (parked), stale duplicates (dropped by the ack watermark),
    /// and link failures (reconnect + replay when resumable).
    fn await_reply(&mut self, expected: u64) -> Result<(u64, Vec<u8>)> {
        if let Some(hit) = self.parked.remove(&expected) {
            return Ok(hit);
        }
        let mut backoff = self.shared.opts.retry.backoff(expected);
        loop {
            match read_message(&mut self.stream) {
                Ok(Message::InferResult {
                    request_id: rid,
                    server_nanos,
                    packet,
                }) => {
                    if rid == expected {
                        return Ok((server_nanos, packet));
                    }
                    if rid > self.acked {
                        self.parked.entry(rid).or_insert((server_nanos, packet));
                    }
                    // rid <= acked: stale duplicate — drop
                }
                Ok(Message::Busy {
                    request_id: rid,
                    pending,
                }) => {
                    if rid <= self.acked {
                        continue; // stale refusal of a delivered frame
                    }
                    let delay = backoff.next_delay().with_context(|| {
                        format!(
                            "server saturated ({pending} request(s) pending); \
                             gave up after {} retries",
                            backoff.max_retries()
                        )
                    })?;
                    self.shared.counters.retries.fetch_add(1, Ordering::Relaxed);
                    sleep_backoff(&self.shared.counters, delay);
                    self.retransmit(rid)?;
                }
                Ok(Message::Error {
                    request_id: rid,
                    message,
                }) => {
                    if rid != 0 && rid <= self.acked {
                        continue; // stale
                    }
                    bail!("server error: {message}");
                }
                Ok(other) => bail!("unexpected reply {other:?}"),
                Err(e) => self.reconnect_stream(&mut backoff, e)?,
            }
        }
    }

    /// Resubmit one journaled frame (after its `Busy` backoff).
    fn retransmit(&mut self, rid: u64) -> Result<()> {
        let msg = self.shared.unanswered.lock().unwrap().get(&rid).cloned();
        let msg = match msg {
            Some(m) => m,
            // journaling off (retries without journal can't happen —
            // `stream_send_frame` journals whenever retries are on) or
            // already delivered; nothing to do
            None => return Ok(()),
        };
        let res = write_message(&mut *self.shared.sock.lock().unwrap(), &msg);
        match res {
            Ok(()) => Ok(()),
            // journaled: the reconnect path replays it
            Err(_) if self.shared.opts.resume => Ok(()),
            Err(e) => Err(e),
        }
    }

    /// Re-establish the connection via the resume handshake and replay
    /// the journal. Holds the shared write lock for the whole handshake
    /// so the writer cannot interleave new frames into the replay.
    fn reconnect_stream(&mut self, backoff: &mut Backoff, cause: anyhow::Error) -> Result<()> {
        let token = self.shared.token;
        let addr = match self.shared.addr {
            Some(a) if self.shared.opts.resume && token != 0 => a,
            _ => return Err(cause),
        };
        let mut sock = self.shared.sock.lock().unwrap();
        loop {
            let delay = match backoff.next_delay() {
                Some(d) => d,
                None => {
                    return Err(cause).with_context(|| {
                        format!(
                            "link lost; reconnect budget exhausted after {} attempt(s)",
                            backoff.attempts()
                        )
                    })
                }
            };
            sleep_backoff(&self.shared.counters, delay);
            let mut fresh = match dial_resume(addr, token, self.acked)? {
                Some(s) => s,
                None => continue,
            };
            // replay every unanswered frame in id order; the server
            // dedups anything it already admitted or answered
            let msgs: Vec<Message> = self
                .shared
                .unanswered
                .lock()
                .unwrap()
                .values()
                .cloned()
                .collect();
            if msgs.iter().any(|m| write_message(&mut fresh, m).is_err()) {
                continue; // fresh link died mid-replay; try again
            }
            *sock = fresh.try_clone()?;
            self.stream = fresh;
            self.shared.counters.reconnects.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
    }

    /// Stop the writer and join it, surfacing its error. Idempotent.
    fn teardown(&mut self) -> Result<()> {
        self.job_tx.take();
        self.pending_rx.take();
        match self.writer.take() {
            Some(w) => w
                .join()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("edge stream writer panicked"))),
            None => Ok(()),
        }
    }

    fn writer_error(&mut self) -> anyhow::Error {
        match self.teardown() {
            Err(e) => e,
            Ok(()) => anyhow::anyhow!("edge stream writer exited early"),
        }
    }

    /// Close the stream: drain cleanly when nothing is in flight,
    /// otherwise abandon the window and shut the socket so neither side
    /// can block forever (the historical error-path semantics).
    pub fn shutdown(mut self) -> Result<()> {
        if self.in_flight() > 0 {
            self.shutdown_mode(ShutdownMode::Abort)
        } else {
            self.shutdown_mode(ShutdownMode::Drain)
        }
    }
}

impl Shutdown for EdgeStream {
    fn shutdown_mode(&mut self, mode: ShutdownMode) -> Result<()> {
        match mode {
            ShutdownMode::Drain => {
                // flush the window: receive (and discard) every in-flight
                // reply so no submitted frame is dropped
                while self.in_flight() > 0 {
                    self.recv()?;
                }
                let res = self.teardown();
                let msg = write_message(&mut self.stream, &Message::Shutdown);
                res.and(msg)
            }
            ShutdownMode::Abort => {
                // the writer's error (if any) is one this abort just
                // caused by shutting the socket under it — swallow it,
                // abort must not fail
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                let _ = self.teardown();
                Ok(())
            }
        }
    }
}

impl Drop for EdgeStream {
    fn drop(&mut self) {
        if self.writer.is_some() {
            // never joined: unblock a writer stuck in a socket write, then
            // reap it — the abandon-and-close path, never blocking on the
            // server
            let _ = self.shutdown_mode(ShutdownMode::Abort);
        }
    }
}
