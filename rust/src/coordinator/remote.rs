//! Real two-process deployment: TCP edge server + edge-device client.
//!
//! This is the paper's Fig 1/2 topology executed for real: the head runs in
//! the edge process, the live set crosses an actual socket, the tail runs
//! in the server process, and predictions come back. Realtime mode —
//! timings are wall-clock on this host (no device scaling), so the numbers
//! demonstrate the mechanism; the calibrated virtual-clock engine produces
//! the paper-comparable figures.
//!
//! Wire packets are self-describing (tensor names), so each process
//! resolves names to its graph's interned ids once per request at the
//! boundary; everything inside the frame then runs on the id-indexed
//! store, sharing tensors by refcount.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::{Engine, HeadFrame};
use crate::coordinator::transport::{read_message, write_message, Message};
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::postprocess::Detection;
use crate::tensor::codec::{Packet, Policy};

/// Server handle: accept loop runs on background threads until shutdown.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving. `engine` runs the tail side.
    pub fn spawn(addr: &str, engine: Arc<Engine>) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();

        let accept_thread = std::thread::Builder::new()
            .name("sp-server-accept".into())
            .spawn(move || {
                let mut workers = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let engine = engine.clone();
                            workers.push(std::thread::spawn(move || {
                                let _ = handle_connection(stream, engine);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })?;

        Ok(Server {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// One connection: a stream of Infer frames until Shutdown/EOF.
fn handle_connection(mut stream: TcpStream, engine: Arc<Engine>) -> Result<()> {
    loop {
        let msg = match read_message(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // peer closed
        };
        match msg {
            Message::Shutdown => return Ok(()),
            Message::Infer {
                request_id,
                head_len,
                packet,
            } => {
                let reply = serve_infer(&engine, head_len as usize, &packet);
                match reply {
                    Ok((server_nanos, bytes)) => write_message(
                        &mut stream,
                        &Message::InferResult {
                            request_id,
                            server_nanos,
                            packet: bytes,
                        },
                    )?,
                    Err(e) => write_message(
                        &mut stream,
                        &Message::Error {
                            request_id,
                            message: format!("{e:#}"),
                        },
                    )?,
                }
            }
            other => bail!("server got unexpected {other:?}"),
        }
    }
}

/// Run the tail for one request. Returns (server compute nanos, response).
fn serve_infer(engine: &Engine, head_len: usize, packet: &[u8]) -> Result<(u64, Vec<u8>)> {
    let graph = engine.graph();
    let start = head_len.min(graph.len());
    let sp = SplitPoint { head_len: start };
    let decoded = Packet::decode(packet)?;
    let mut store = engine.new_store();
    for (name, t) in decoded.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("wire tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }

    let t0 = Instant::now();
    for idx in start..graph.len() {
        engine.run_node(idx, &mut store)?;
    }
    let server_nanos = t0.elapsed().as_nanos() as u64;

    let reply = Packet::from_shared(
        graph
            .response_ids(sp)
            .iter()
            .map(|&id| -> Result<_> {
                Ok((
                    graph.tensor_name(id).to_string(),
                    store
                        .get(id)
                        .cloned()
                        .with_context(|| {
                            format!("response tensor '{}' missing", graph.tensor_name(id))
                        })?,
                ))
            })
            .collect::<Result<_>>()?,
    );
    let bytes = reply.encode(engine.config().codec);
    engine.reclaim_scratch(&mut store);
    Ok((server_nanos, bytes))
}

/// Take a head frame's wire bytes for the TCP protocol (an encoded empty
/// packet when the live set is empty — the protocol always ships one),
/// plus the v1-framing cost of what actually ships: for an empty packet
/// the framing is identical under both versions, so the v1 side is
/// charged symmetrically and `wire_savings` stays honest.
fn wire_with_v1(head: &mut HeadFrame, codec: Policy) -> (Vec<u8>, usize) {
    let v1 = head.wire_v1_bytes();
    let bytes = head
        .take_wire()
        .unwrap_or_else(|| Packet::from_shared(Vec::new()).encode(codec));
    let v1 = if v1 == 0 { bytes.len() } else { v1 };
    (bytes, v1)
}

/// Timing of one remote frame (wall-clock, realtime).
#[derive(Debug, Clone)]
pub struct RemoteTiming {
    pub edge_compute: SimTime,
    pub uplink_bytes: usize,
    /// legacy v1-framing cost of the same live set (wire-savings metric)
    pub uplink_v1_bytes: usize,
    /// send → result received (uplink + server + downlink)
    pub round_trip: SimTime,
    pub server_compute: SimTime,
    pub inference_time: SimTime,
}

/// Edge-device client for a remote server.
pub struct EdgeClient {
    stream: TcpStream,
    engine: Arc<Engine>,
    next_id: u64,
}

impl EdgeClient {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        engine: Arc<Engine>,
    ) -> Result<EdgeClient> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        stream.set_nodelay(true)?;
        Ok(EdgeClient {
            stream,
            engine,
            next_id: 1,
        })
    }

    /// Run one frame: head locally, tail on the server. The head half is
    /// the engine's own [`Engine::head_stage`] — the TCP client is a thin
    /// shell that ships the stage's wire bytes over a real socket.
    pub fn run_frame(
        &mut self,
        cloud: &PointCloud,
        sp: SplitPoint,
    ) -> Result<(Vec<Detection>, RemoteTiming)> {
        let engine = self.engine.clone();
        let t_start = Instant::now();

        let mut head = engine.head_stage(cloud, sp)?;
        let (bytes, uplink_v1_bytes) = wire_with_v1(&mut head, engine.config().codec);
        let (mut store, _) = head.into_store();
        let edge_compute = SimTime::from_duration(t_start.elapsed());

        let request_id = self.next_id;
        self.next_id += 1;
        let t_send = Instant::now();
        let uplink_bytes = bytes.len();
        write_message(
            &mut self.stream,
            &Message::Infer {
                request_id,
                head_len: sp.head_len as u8,
                packet: bytes,
            },
        )?;
        let (detections, server_nanos, round_trip) =
            receive_reply(&mut self.stream, &engine, request_id, &mut store, t_send)?;
        let inference_time = SimTime::from_duration(t_start.elapsed());

        Ok((
            detections,
            RemoteTiming {
                edge_compute,
                uplink_bytes,
                uplink_v1_bytes,
                round_trip,
                server_compute: SimTime {
                    nanos: server_nanos as u128,
                },
                inference_time,
            },
        ))
    }

    pub fn shutdown(mut self) -> Result<()> {
        write_message(&mut self.stream, &Message::Shutdown)
    }

    /// Convert this client into a persistent incremental stream handle
    /// (see [`EdgeStream`]): frames are submitted one at a time and the
    /// in-flight window survives across submit bursts, so a session
    /// feeding segments into the handle never drains the pipe at a
    /// segment boundary. `depth` caps in-flight frames; `depth <= 1`
    /// still overlaps head(N+1) with the server round trip of frame N
    /// one frame at a time.
    pub fn into_stream(self, depth: usize) -> Result<EdgeStream> {
        EdgeStream::spawn(self.stream, self.engine, self.next_id, depth)
    }

    /// Pipelined streaming: overlap the local head compute of frame N+1
    /// with the server round trip of frame N.
    ///
    /// A writer thread runs [`Engine::head_stage`] per frame and sends the
    /// wire packet; this thread receives responses and finalizes, in
    /// submission order (the server processes one connection's requests
    /// sequentially, so replies are FIFO). `depth` caps in-flight frames:
    /// `depth <= 1` degenerates to the serial [`EdgeClient::run_frame`]
    /// loop. Per-frame `round_trip` now includes queueing — at the server,
    /// and on the client side whenever backpressure stalls the writer
    /// before the request reaches the socket — which is the point:
    /// latency is traded for the throughput that overlap buys.
    pub fn run_stream(
        &mut self,
        clouds: &[PointCloud],
        sp: SplitPoint,
        depth: usize,
    ) -> Result<Vec<(Vec<Detection>, RemoteTiming)>> {
        if depth <= 1 {
            return clouds.iter().map(|c| self.run_frame(c, sp)).collect();
        }
        let engine = self.engine.clone();
        let mut write_stream = self.stream.try_clone()?;
        let first_id = self.next_id;
        self.next_id += clouds.len() as u64;
        // the channel bounds in-flight requests: the writer blocks sending
        // the pending record once `depth` frames are outstanding
        let (tx, rx) = std::sync::mpsc::sync_channel::<PendingRequest>(depth.max(1));

        // scoped writer thread: borrows `clouds` directly (no up-front
        // deep copy of the whole stream) and is always joined before this
        // function returns
        let (read_all, write_res) = std::thread::scope(|scope| {
            let writer = scope.spawn(move || -> Result<()> {
                let sent = send_stream(&engine, &mut write_stream, clouds, sp, first_id, &tx);
                if sent.is_err() {
                    // unblock the reader, which would otherwise wait on a
                    // reply that will never be sent
                    let _ = write_stream.shutdown(std::net::Shutdown::Both);
                }
                sent
            });
            let read_all = self.recv_stream(&rx);
            // drop the receiver before joining: a writer blocked on a full
            // channel fails its send and exits
            drop(rx);
            if read_all.is_err() {
                // unblock a writer stuck in a socket write: with the reader
                // gone the TCP windows can back up and block it forever
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
            }
            let write_res = writer
                .join()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("edge writer thread panicked")));
            (read_all, write_res)
        });
        let frames = match (read_all, write_res) {
            (Ok(frames), Ok(())) => frames,
            // reader finished but the writer failed — the write error is
            // the only cause
            (Ok(_), Err(w)) => return Err(w),
            // reader failed, writer fine (e.g. a server Error reply)
            (Err(r), Ok(())) => return Err(r),
            // both failed: either side's shutdown fails the other, so keep
            // both causes visible instead of guessing the root
            (Err(r), Err(w)) => {
                return Err(anyhow::anyhow!(
                    "pipelined stream failed — reader: {r:#}; writer: {w:#}"
                ))
            }
        };
        if frames.len() != clouds.len() {
            bail!(
                "stream ended early: {} of {} frames completed",
                frames.len(),
                clouds.len()
            );
        }
        Ok(frames)
    }

    /// Reader half of the pipelined stream: for every pending request (in
    /// FIFO order) receive the server's reply, decode the response tensors
    /// into the request's store and finalize. Ends when the writer drops
    /// its sender and the channel drains.
    fn recv_stream(
        &mut self,
        rx: &std::sync::mpsc::Receiver<PendingRequest>,
    ) -> Result<Vec<(Vec<Detection>, RemoteTiming)>> {
        let engine = self.engine.clone();
        let mut out = Vec::new();
        while let Ok(mut pending) = rx.recv() {
            let (detections, server_nanos, round_trip) = receive_reply(
                &mut self.stream,
                &engine,
                pending.request_id,
                &mut pending.store,
                pending.t_send,
            )?;
            out.push((
                detections,
                RemoteTiming {
                    edge_compute: pending.edge_compute,
                    uplink_bytes: pending.uplink_bytes,
                    uplink_v1_bytes: pending.uplink_v1_bytes,
                    round_trip,
                    server_compute: SimTime {
                        nanos: server_nanos as u128,
                    },
                    inference_time: SimTime::from_duration(pending.t_start.elapsed()),
                },
            ));
        }
        Ok(out)
    }
}

/// Receive and apply one server reply for `expected_id` (shared by the
/// serial and pipelined clients, which the tests assert are equivalent):
/// match the `InferResult`, decode the response tensors into `store`,
/// finalize, reclaim scratch. Returns the detections, the server's
/// self-reported compute nanos and the send→receive round trip.
fn receive_reply(
    stream: &mut TcpStream,
    engine: &Engine,
    expected_id: u64,
    store: &mut crate::model::graph::TensorStore,
    t_send: Instant,
) -> Result<(Vec<Detection>, u64, SimTime)> {
    let reply = read_message(stream)?;
    let round_trip = SimTime::from_duration(t_send.elapsed());
    let (server_nanos, resp_packet) = match reply {
        Message::InferResult {
            request_id: rid,
            server_nanos,
            packet,
        } => {
            if rid != expected_id {
                bail!("response id {rid} != request {expected_id}");
            }
            (server_nanos, packet)
        }
        Message::Error { message, .. } => bail!("server error: {message}"),
        other => bail!("unexpected reply {other:?}"),
    };
    let graph = engine.graph();
    for (name, t) in Packet::decode(&resp_packet)?.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("response tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }
    let detections = engine.finalize(store)?;
    engine.reclaim_scratch(store);
    Ok((detections, server_nanos, round_trip))
}

/// One frame of the writer half, shared by the one-shot
/// [`EdgeClient::run_stream`] and the persistent [`EdgeStream`]: head
/// compute, wire encode, park the pending record on the bounded channel
/// (*before* the socket write, so the channel capacity caps in-flight
/// frames and the reader always has the store a reply refers to), then
/// send the Infer message. Returns `Ok(false)` when the reader went away
/// (stop quietly), `Ok(true)` on success.
fn send_frame(
    engine: &Engine,
    stream: &mut TcpStream,
    cloud: &PointCloud,
    sp: SplitPoint,
    request_id: u64,
    tx: &std::sync::mpsc::SyncSender<PendingRequest>,
) -> Result<bool> {
    let t_start = Instant::now();
    let mut head = engine.head_stage(cloud, sp)?;
    let (bytes, uplink_v1_bytes) = wire_with_v1(&mut head, engine.config().codec);
    let (store, _) = head.into_store();
    let pending = PendingRequest {
        request_id,
        store,
        edge_compute: SimTime::from_duration(t_start.elapsed()),
        uplink_bytes: bytes.len(),
        uplink_v1_bytes,
        t_start,
        t_send: Instant::now(),
    };
    if tx.send(pending).is_err() {
        return Ok(false); // reader bailed
    }
    write_message(
        stream,
        &Message::Infer {
            request_id,
            head_len: sp.head_len as u8,
            packet: bytes,
        },
    )?;
    Ok(true)
}

/// Writer half of the pipelined stream: [`send_frame`] for every cloud,
/// in order.
fn send_stream(
    engine: &Engine,
    stream: &mut TcpStream,
    clouds: &[PointCloud],
    sp: SplitPoint,
    first_id: u64,
    tx: &std::sync::mpsc::SyncSender<PendingRequest>,
) -> Result<()> {
    for (i, cloud) in clouds.iter().enumerate() {
        if !send_frame(engine, stream, cloud, sp, first_id + i as u64, tx)? {
            return Ok(()); // reader bailed; stop quietly
        }
    }
    Ok(())
}

/// A request in flight on the pipelined edge client: everything the reader
/// needs to finalize the frame once the server replies.
struct PendingRequest {
    request_id: u64,
    store: crate::model::graph::TensorStore,
    edge_compute: SimTime,
    uplink_bytes: usize,
    uplink_v1_bytes: usize,
    t_start: Instant,
    t_send: Instant,
}

/// One frame queued into an [`EdgeStream`]: the split travels with the
/// frame, so a policy flip needs no new connection — only the flush the
/// session already performs.
struct StreamJob {
    cloud: PointCloud,
    sp: SplitPoint,
}

/// Persistent incremental streaming handle over one TCP connection — the
/// session-facing inverse of the one-shot [`EdgeClient::run_stream`].
///
/// `run_stream` drains its whole in-flight window before returning, which
/// costs ~depth×RTT of idle wire at every segment boundary of a
/// fixed-policy stream. An `EdgeStream` instead keeps a writer thread and
/// the bounded pending queue alive across submit bursts: callers
/// interleave [`EdgeStream::submit`] and [`EdgeStream::recv`] (results
/// come back in submission order, byte-identical to the serial client —
/// both ends run the same stage functions), and the window only empties
/// when the caller explicitly drains it.
///
/// In-flight frames are capped by the pending channel: the writer blocks
/// forwarding request `depth + 1` until a reply has been received, so a
/// caller that never lets `in_flight()` exceed `depth` before submitting
/// can never deadlock.
pub struct EdgeStream {
    /// reader half (and shutdown control) of the shared socket
    stream: TcpStream,
    engine: Arc<Engine>,
    job_tx: Option<std::sync::mpsc::SyncSender<StreamJob>>,
    pending_rx: Option<std::sync::mpsc::Receiver<PendingRequest>>,
    writer: Option<std::thread::JoinHandle<Result<()>>>,
    submitted: u64,
    delivered: u64,
}

impl EdgeStream {
    fn spawn(
        stream: TcpStream,
        engine: Arc<Engine>,
        first_id: u64,
        depth: usize,
    ) -> Result<EdgeStream> {
        let depth = depth.max(1);
        let mut write_stream = stream.try_clone()?;
        let writer_engine = engine.clone();
        // jobs hand off one at a time; the *pending* channel is what caps
        // the in-flight window (same scheme as `run_stream`)
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<StreamJob>(1);
        let (pending_tx, pending_rx) = std::sync::mpsc::sync_channel::<PendingRequest>(depth);
        let writer = std::thread::Builder::new()
            .name("sp-edge-stream".into())
            .spawn(move || -> Result<()> {
                let mut request_id = first_id;
                while let Ok(job) = job_rx.recv() {
                    let sent = send_frame(
                        &writer_engine,
                        &mut write_stream,
                        &job.cloud,
                        job.sp,
                        request_id,
                        &pending_tx,
                    );
                    match sent {
                        Ok(true) => request_id += 1,
                        Ok(false) => return Ok(()), // reader bailed; stop quietly
                        Err(e) => {
                            // unblock a reader waiting on a reply that
                            // will never arrive
                            let _ = write_stream.shutdown(std::net::Shutdown::Both);
                            return Err(e);
                        }
                    }
                }
                Ok(())
            })?;
        Ok(EdgeStream {
            stream,
            engine,
            job_tx: Some(job_tx),
            pending_rx: Some(pending_rx),
            writer: Some(writer),
            submitted: 0,
            delivered: 0,
        })
    }

    /// Frames submitted but not yet delivered through [`EdgeStream::recv`].
    pub fn in_flight(&self) -> usize {
        (self.submitted - self.delivered) as usize
    }

    /// Queue one frame at split `sp`. Returns as soon as the writer thread
    /// has the frame; keep `in_flight()` at or below the stream's depth
    /// before calling (the session's window loop) so the writer can always
    /// make progress.
    pub fn submit(&mut self, cloud: PointCloud, sp: SplitPoint) -> Result<()> {
        let tx = self.job_tx.as_ref().context("edge stream already finished")?;
        if tx.send(StreamJob { cloud, sp }).is_err() {
            return Err(self.writer_error());
        }
        self.submitted += 1;
        Ok(())
    }

    /// Receive the next completed frame, in submission order. Blocks until
    /// the server's reply lands; erroring with nothing in flight.
    pub fn recv(&mut self) -> Result<(Vec<Detection>, RemoteTiming)> {
        if self.in_flight() == 0 {
            bail!("edge stream recv with no frame in flight");
        }
        let rx = self.pending_rx.as_ref().context("edge stream already finished")?;
        let mut pending = match rx.recv() {
            Ok(p) => p,
            Err(_) => return Err(self.writer_error()),
        };
        let engine = self.engine.clone();
        let reply = receive_reply(
            &mut self.stream,
            &engine,
            pending.request_id,
            &mut pending.store,
            pending.t_send,
        );
        let (detections, server_nanos, round_trip) = match reply {
            Ok(r) => r,
            Err(e) => {
                // unblock a writer stuck in a socket write before the
                // error propagates (mirrors `run_stream`)
                let _ = self.stream.shutdown(std::net::Shutdown::Both);
                return Err(e);
            }
        };
        self.delivered += 1;
        Ok((
            detections,
            RemoteTiming {
                edge_compute: pending.edge_compute,
                uplink_bytes: pending.uplink_bytes,
                uplink_v1_bytes: pending.uplink_v1_bytes,
                round_trip,
                server_compute: SimTime {
                    nanos: server_nanos as u128,
                },
                inference_time: SimTime::from_duration(pending.t_start.elapsed()),
            },
        ))
    }

    /// Stop the writer and join it, surfacing its error. Idempotent.
    fn teardown(&mut self) -> Result<()> {
        self.job_tx.take();
        self.pending_rx.take();
        match self.writer.take() {
            Some(w) => w
                .join()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("edge stream writer panicked"))),
            None => Ok(()),
        }
    }

    fn writer_error(&mut self) -> anyhow::Error {
        match self.teardown() {
            Err(e) => e,
            Ok(()) => anyhow::anyhow!("edge stream writer exited early"),
        }
    }

    /// Close the stream: join the writer and send the protocol Shutdown.
    /// Frames still in flight (error paths) are abandoned — the socket is
    /// shut down instead so neither side can block forever.
    pub fn shutdown(mut self) -> Result<()> {
        if self.in_flight() > 0 {
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            return self.teardown();
        }
        let res = self.teardown();
        let msg = write_message(&mut self.stream, &Message::Shutdown);
        res.and(msg)
    }
}

impl Drop for EdgeStream {
    fn drop(&mut self) {
        if self.writer.is_some() {
            // never joined: unblock a writer stuck in a socket write first
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            let _ = self.teardown();
        }
    }
}
