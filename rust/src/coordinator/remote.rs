//! Real two-process deployment: TCP edge server + edge-device client.
//!
//! This is the paper's Fig 1/2 topology executed for real: the head runs in
//! the edge process, the live set crosses an actual socket, the tail runs
//! in the server process, and predictions come back. Realtime mode —
//! timings are wall-clock on this host (no device scaling), so the numbers
//! demonstrate the mechanism; the calibrated virtual-clock engine produces
//! the paper-comparable figures.
//!
//! Wire packets are self-describing (tensor names), so each process
//! resolves names to its graph's interned ids once per request at the
//! boundary; everything inside the frame then runs on the id-indexed
//! store, sharing tensors by refcount.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::transport::{read_message, write_message, Message};
use crate::metrics::SimTime;
use crate::model::graph::SplitPoint;
use crate::pointcloud::PointCloud;
use crate::postprocess::Detection;
use crate::tensor::codec::Packet;

/// Server handle: accept loop runs on background threads until shutdown.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving. `engine` runs the tail side.
    pub fn spawn(addr: &str, engine: Arc<Engine>) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();

        let accept_thread = std::thread::Builder::new()
            .name("sp-server-accept".into())
            .spawn(move || {
                let mut workers = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let engine = engine.clone();
                            workers.push(std::thread::spawn(move || {
                                let _ = handle_connection(stream, engine);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })?;

        Ok(Server {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// One connection: a stream of Infer frames until Shutdown/EOF.
fn handle_connection(mut stream: TcpStream, engine: Arc<Engine>) -> Result<()> {
    loop {
        let msg = match read_message(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // peer closed
        };
        match msg {
            Message::Shutdown => return Ok(()),
            Message::Infer {
                request_id,
                head_len,
                packet,
            } => {
                let reply = serve_infer(&engine, head_len as usize, &packet);
                match reply {
                    Ok((server_nanos, bytes)) => write_message(
                        &mut stream,
                        &Message::InferResult {
                            request_id,
                            server_nanos,
                            packet: bytes,
                        },
                    )?,
                    Err(e) => write_message(
                        &mut stream,
                        &Message::Error {
                            request_id,
                            message: format!("{e:#}"),
                        },
                    )?,
                }
            }
            other => bail!("server got unexpected {other:?}"),
        }
    }
}

/// Run the tail for one request. Returns (server compute nanos, response).
fn serve_infer(engine: &Engine, head_len: usize, packet: &[u8]) -> Result<(u64, Vec<u8>)> {
    let graph = engine.graph();
    let start = head_len.min(graph.len());
    let sp = SplitPoint { head_len: start };
    let decoded = Packet::decode(packet)?;
    let mut store = engine.new_store();
    for (name, t) in decoded.tensors {
        let id = graph
            .tensor_id(&name)
            .with_context(|| format!("wire tensor '{name}' not in this pipeline"))?;
        store.insert(id, t);
    }

    let t0 = Instant::now();
    for idx in start..graph.len() {
        engine.run_node(idx, &mut store)?;
    }
    let server_nanos = t0.elapsed().as_nanos() as u64;

    let reply = Packet::from_shared(
        graph
            .response_ids(sp)
            .iter()
            .map(|&id| -> Result<_> {
                Ok((
                    graph.tensor_name(id).to_string(),
                    store
                        .get(id)
                        .cloned()
                        .with_context(|| {
                            format!("response tensor '{}' missing", graph.tensor_name(id))
                        })?,
                ))
            })
            .collect::<Result<_>>()?,
    );
    let bytes = reply.encode(engine.config().codec);
    engine.reclaim_scratch(&mut store);
    Ok((server_nanos, bytes))
}

/// Timing of one remote frame (wall-clock, realtime).
#[derive(Debug, Clone)]
pub struct RemoteTiming {
    pub edge_compute: SimTime,
    pub uplink_bytes: usize,
    /// send → result received (uplink + server + downlink)
    pub round_trip: SimTime,
    pub server_compute: SimTime,
    pub inference_time: SimTime,
}

/// Edge-device client for a remote server.
pub struct EdgeClient {
    stream: TcpStream,
    engine: Arc<Engine>,
    next_id: u64,
}

impl EdgeClient {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        engine: Arc<Engine>,
    ) -> Result<EdgeClient> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        stream.set_nodelay(true)?;
        Ok(EdgeClient {
            stream,
            engine,
            next_id: 1,
        })
    }

    /// Run one frame: head locally, tail on the server.
    pub fn run_frame(
        &mut self,
        cloud: &PointCloud,
        sp: SplitPoint,
    ) -> Result<(Vec<Detection>, RemoteTiming)> {
        let engine = self.engine.clone();
        let graph = engine.graph();
        let t_start = Instant::now();

        let mut store = engine.new_store();
        store.insert(graph.primal_id(), Arc::new(cloud.to_tensor()));
        for idx in 0..sp.head_len.min(graph.len()) {
            engine.run_node(idx, &mut store)?;
        }
        let packet = Packet::from_shared(
            graph
                .live_ids(sp)
                .iter()
                .map(|&id| -> Result<_> {
                    Ok((
                        graph.tensor_name(id).to_string(),
                        store
                            .get(id)
                            .cloned()
                            .with_context(|| {
                                format!("live tensor '{}' missing", graph.tensor_name(id))
                            })?,
                    ))
                })
                .collect::<Result<_>>()?,
        );
        let bytes = packet.encode(engine.config().codec);
        drop(packet); // release shared grids so frame teardown can recycle
        let edge_compute = SimTime::from_duration(t_start.elapsed());

        let request_id = self.next_id;
        self.next_id += 1;
        let t_send = Instant::now();
        let uplink_bytes = bytes.len();
        write_message(
            &mut self.stream,
            &Message::Infer {
                request_id,
                head_len: sp.head_len as u8,
                packet: bytes,
            },
        )?;
        let reply = read_message(&mut self.stream)?;
        let round_trip = SimTime::from_duration(t_send.elapsed());

        let (server_nanos, resp_packet) = match reply {
            Message::InferResult {
                request_id: rid,
                server_nanos,
                packet,
            } => {
                if rid != request_id {
                    bail!("response id {rid} != request {request_id}");
                }
                (server_nanos, packet)
            }
            Message::Error { message, .. } => bail!("server error: {message}"),
            other => bail!("unexpected reply {other:?}"),
        };
        for (name, t) in Packet::decode(&resp_packet)?.tensors {
            let id = graph
                .tensor_id(&name)
                .with_context(|| format!("response tensor '{name}' not in this pipeline"))?;
            store.insert(id, t);
        }
        let detections = engine.finalize(&store)?;
        engine.reclaim_scratch(&mut store);
        let inference_time = SimTime::from_duration(t_start.elapsed());

        Ok((
            detections,
            RemoteTiming {
                edge_compute,
                uplink_bytes,
                round_trip,
                server_compute: SimTime {
                    nanos: server_nanos as u128,
                },
                inference_time,
            },
        ))
    }

    pub fn shutdown(mut self) -> Result<()> {
        write_message(&mut self.stream, &Message::Shutdown)
    }
}
