//! CI perf-regression gate: compare the fresh `current` section of
//! `BENCH_micro.json` against the committed `baseline` and exit non-zero
//! on a regression.
//!
//!   cargo run --release --bin perf-guard -- \
//!       [--file BENCH_micro.json] [--baseline-file COMMITTED.json] \
//!       [--threshold 0.15] [--report BENCH_diff.md]
//!
//! Run it right after `cargo bench --bench micro -- --json`. Pass
//! `--baseline-file` a pristine copy of the *committed* file (CI copies it
//! before the bench run): the bench binary seeds missing baseline entries
//! into the file it rewrites, so gating a fresh file against itself would
//! let brand-new benches gate vacuously. Without `--baseline-file`, the
//! measured file's own baseline section is used. With no committed
//! baseline at all the gate **fails** — an unmeasured tree must not
//! green-light; commit the freshly written `BENCH_micro.json` (CI uploads
//! it as an artifact on every run, pass or fail) to seed and arm it.

use splitpoint::bench::regression;

fn main() -> anyhow::Result<()> {
    let mut file = "BENCH_micro.json".to_string();
    let mut baseline_file: Option<String> = None;
    let mut threshold = 0.15f64;
    let mut report_path: Option<String> = None;

    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut value = || {
            it.next()
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("{a} needs a value"))
        };
        match a.as_str() {
            "--file" => file = value()?,
            "--baseline-file" => baseline_file = Some(value()?),
            "--threshold" => {
                let raw = value()?;
                threshold = raw.parse().map_err(|_| {
                    anyhow::anyhow!("--threshold: cannot parse '{raw}' (want e.g. 0.15)")
                })?;
            }
            "--report" => report_path = Some(value()?),
            other => anyhow::bail!("unknown argument '{other}'"),
        }
    }

    let read = |path: &str| {
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))
    };
    let current_text = read(&file)?;
    let gate = match &baseline_file {
        Some(b) => regression::gate_against(&read(b)?, &current_text, threshold)?,
        None => regression::gate_file(&current_text, threshold)?,
    };
    let md = gate.to_markdown();
    println!("{md}");
    if let Some(path) = report_path {
        std::fs::write(&path, &md)
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    }
    if !gate.passed() {
        if gate.baseline_missing {
            eprintln!(
                "[perf-guard] FAIL: no committed baseline — commit the freshly \
                 measured BENCH_micro.json (uploaded as the BENCH_micro CI \
                 artifact) to seed and arm the gate"
            );
        } else {
            eprintln!(
                "[perf-guard] FAIL: {} bench(es) regressed more than {:.0}%",
                gate.regressions.len(),
                threshold * 100.0
            );
        }
        std::process::exit(1);
    }
    eprintln!("[perf-guard] pass");
    Ok(())
}
