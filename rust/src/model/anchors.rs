//! Anchor grid generation, mirroring the L2 dense head's implicit layout.
//!
//! Ordering contract with `python/compile/model.py::bev_head`: anchors are
//! enumerated (bev_row, bev_col, class, rotation) with rotation fastest —
//! i.e. flat index = ((h * W + w) * C + cls) * R + rot.

use crate::model::manifest::ModelConfig;

/// One anchor box: (cx, cy, cz, l, w, h, ry) in metric space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Anchor {
    pub center: [f32; 3],
    pub dims: [f32; 3],
    pub ry: f32,
    pub class: usize,
}

/// Generate the dense anchor grid.
pub fn generate(cfg: &ModelConfig) -> Vec<Anchor> {
    let mut anchors =
        Vec::with_capacity(cfg.bev_h * cfg.bev_w * cfg.anchors_per_cell);
    let (x0, x1) = cfg.pc_range_x;
    let (y0, y1) = cfg.pc_range_y;
    let cell_x = (x1 - x0) / cfg.bev_w as f64;
    let cell_y = (y1 - y0) / cfg.bev_h as f64;

    for hy in 0..cfg.bev_h {
        for wx in 0..cfg.bev_w {
            let cy = y0 + (hy as f64 + 0.5) * cell_y;
            let cx = x0 + (wx as f64 + 0.5) * cell_x;
            for (cls, size) in cfg.anchor_sizes.iter().enumerate() {
                for &rot in &cfg.anchor_rotations {
                    anchors.push(Anchor {
                        center: [cx as f32, cy as f32, cfg.anchor_z[cls] as f32],
                        dims: [size[0] as f32, size[1] as f32, size[2] as f32],
                        ry: rot as f32,
                        class: cls,
                    });
                }
            }
        }
    }
    debug_assert_eq!(anchors.len(), cfg.num_anchors);
    anchors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    #[test]
    fn count_and_order() {
        let cfg = test_manifest().config;
        let a = generate(&cfg);
        assert_eq!(a.len(), cfg.num_anchors);
        // rotation fastest: consecutive anchors differ only in ry
        assert_eq!(a[0].center, a[1].center);
        assert_eq!(a[0].class, a[1].class);
        assert_ne!(a[0].ry, a[1].ry);
        // then class (same BEV cell, class-specific z)
        assert_eq!(a[0].center[..2], a[2].center[..2]);
        assert_ne!(a[0].class, a[2].class);
    }

    #[test]
    fn centers_inside_range() {
        let cfg = test_manifest().config;
        for a in generate(&cfg) {
            assert!(a.center[0] as f64 >= cfg.pc_range_x.0);
            assert!((a.center[0] as f64) <= cfg.pc_range_x.1);
            assert!(a.center[1] as f64 >= cfg.pc_range_y.0);
            assert!((a.center[1] as f64) <= cfg.pc_range_y.1);
        }
    }

    #[test]
    fn first_cell_is_grid_corner() {
        let cfg = test_manifest().config;
        let a = generate(&cfg);
        let cell = 46.08 / cfg.bev_w as f64;
        assert!((a[0].center[0] as f64 - cell * 0.5).abs() < 1e-5);
        assert!((a[0].center[1] as f64 - (-23.04 + cell * 0.5)).abs() < 1e-4);
    }
}
