//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// One named tensor endpoint of a module.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// One AOT'd module (an HLO artifact).
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub artifact: PathBuf,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One Backbone3D stage's geometry.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub stride: [usize; 3],
    pub submanifold: bool,
    pub out_shape: [usize; 4],
}

/// Anchor-generation and model geometry constants (mirrors python config).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub pc_range_x: (f64, f64),
    pub pc_range_y: (f64, f64),
    pub pc_range_z: (f64, f64),
    pub voxel_size: [f64; 3], // (z, y, x)
    pub grid: [usize; 3],     // (D, H, W)
    pub point_features: usize,
    pub stages: Vec<StageSpec>,
    pub bev_h: usize,
    pub bev_w: usize,
    /// MapToBEV channel count (last stage's D * C); input width of the
    /// 2D backbone.
    pub bev_channels: usize,
    /// Backbone2D working width.
    pub bev_backbone_channels: usize,
    pub num_classes: usize,
    pub anchor_sizes: Vec<[f64; 3]>,
    pub anchor_z: Vec<f64>,
    pub anchor_rotations: Vec<f64>,
    pub anchors_per_cell: usize,
    pub num_anchors: usize,
    pub box_code_size: usize,
    pub num_proposals: usize,
    /// RoI grid side length (G; G^3 sample points per RoI per scale).
    pub roi_grid: usize,
    /// Backbone scales pooled by the RoI head, in concat order.
    pub roi_pool_scales: Vec<String>,
    /// Per-scale projection width before the shared point MLP.
    pub roi_pool_channels: usize,
    /// Shared per-grid-point MLP width (the RoI head's compute bulk).
    pub roi_mlp: usize,
    /// Post-pool FC width.
    pub roi_fc: usize,
    pub weights_seed: u64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub use_pallas: bool,
    pub config: ModelConfig,
    pub modules: Vec<ModuleSpec>,
}

fn f64_pair(v: &Value) -> Result<(f64, f64)> {
    let a = v.as_f64_vec().context("expected [f64, f64]")?;
    if a.len() != 2 {
        bail!("expected 2-element range");
    }
    Ok((a[0], a[1]))
}

fn tensor_specs(v: &Value) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Value::as_str)
                    .context("tensor name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Value::as_usize_vec)
                    .context("tensor shape")?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = json::parse(text).context("manifest.json")?;
        let cfg = v.get("config").context("manifest missing config")?;

        let stages = cfg
            .get("stages")
            .and_then(Value::as_arr)
            .context("config.stages")?
            .iter()
            .map(|s| -> Result<StageSpec> {
                let stride = s
                    .get("stride")
                    .and_then(Value::as_usize_vec)
                    .context("stage stride")?;
                let out = s
                    .get("out_shape")
                    .and_then(Value::as_usize_vec)
                    .context("stage out_shape")?;
                Ok(StageSpec {
                    name: s
                        .get("name")
                        .and_then(Value::as_str)
                        .context("stage name")?
                        .to_string(),
                    cin: s.get("cin").and_then(Value::as_usize).context("cin")?,
                    cout: s.get("cout").and_then(Value::as_usize).context("cout")?,
                    stride: [stride[0], stride[1], stride[2]],
                    submanifold: s
                        .get("submanifold")
                        .and_then(Value::as_bool)
                        .unwrap_or(false),
                    out_shape: [out[0], out[1], out[2], out[3]],
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let grid = cfg
            .get("grid")
            .and_then(Value::as_usize_vec)
            .context("config.grid")?;
        let voxel = cfg
            .get("voxel_size")
            .and_then(Value::as_f64_vec)
            .context("config.voxel_size")?;
        let anchor_sizes = cfg
            .get("anchor_sizes")
            .and_then(Value::as_arr)
            .context("anchor_sizes")?
            .iter()
            .map(|a| {
                let v = a.as_f64_vec().context("anchor size")?;
                Ok([v[0], v[1], v[2]])
            })
            .collect::<Result<Vec<_>>>()?;

        // Derived fallbacks keep older manifests (without the explicit bev
        // channel / roi keys) parsing: MapToBEV folds the last stage's
        // depth into channels, and the RoI defaults mirror
        // python/compile/config.py.
        let last_stage = stages.last();
        let bev_channels_default = last_stage
            .map(|s| s.out_shape[0] * s.out_shape[3])
            .unwrap_or(0);

        let config = ModelConfig {
            pc_range_x: f64_pair(cfg.at(&["pc_range", "x"]).context("pc_range.x")?)?,
            pc_range_y: f64_pair(cfg.at(&["pc_range", "y"]).context("pc_range.y")?)?,
            pc_range_z: f64_pair(cfg.at(&["pc_range", "z"]).context("pc_range.z")?)?,
            voxel_size: [voxel[0], voxel[1], voxel[2]],
            grid: [grid[0], grid[1], grid[2]],
            point_features: cfg
                .get("point_features")
                .and_then(Value::as_usize)
                .context("point_features")?,
            stages,
            bev_h: cfg.at(&["bev", "h"]).and_then(Value::as_usize).context("bev.h")?,
            bev_w: cfg.at(&["bev", "w"]).and_then(Value::as_usize).context("bev.w")?,
            bev_channels: cfg
                .at(&["bev", "channels"])
                .and_then(Value::as_usize)
                .unwrap_or(bev_channels_default),
            bev_backbone_channels: cfg
                .at(&["bev", "backbone_channels"])
                .and_then(Value::as_usize)
                .unwrap_or(64),
            num_classes: cfg
                .get("num_classes")
                .and_then(Value::as_usize)
                .context("num_classes")?,
            anchor_sizes,
            anchor_z: cfg
                .get("anchor_z")
                .and_then(Value::as_f64_vec)
                .context("anchor_z")?,
            anchor_rotations: cfg
                .get("anchor_rotations")
                .and_then(Value::as_f64_vec)
                .context("anchor_rotations")?,
            anchors_per_cell: cfg
                .get("anchors_per_cell")
                .and_then(Value::as_usize)
                .context("anchors_per_cell")?,
            num_anchors: cfg
                .get("num_anchors")
                .and_then(Value::as_usize)
                .context("num_anchors")?,
            box_code_size: cfg
                .get("box_code_size")
                .and_then(Value::as_usize)
                .context("box_code_size")?,
            num_proposals: cfg
                .get("num_proposals")
                .and_then(Value::as_usize)
                .context("num_proposals")?,
            roi_grid: cfg
                .get("roi_grid")
                .and_then(Value::as_usize)
                .unwrap_or(6),
            roi_pool_scales: cfg
                .get("roi_pool_scales")
                .and_then(Value::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Value::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_else(|| {
                    vec!["conv2".to_string(), "conv3".to_string(), "conv4".to_string()]
                }),
            roi_pool_channels: cfg
                .get("roi_pool_channels")
                .and_then(Value::as_usize)
                .unwrap_or(16),
            roi_mlp: cfg.get("roi_mlp").and_then(Value::as_usize).unwrap_or(128),
            roi_fc: cfg.get("roi_fc").and_then(Value::as_usize).unwrap_or(128),
            weights_seed: cfg
                .get("weights_seed")
                .and_then(Value::as_usize)
                .context("weights_seed")? as u64,
        };

        let modules = v
            .get("modules")
            .and_then(Value::as_arr)
            .context("manifest.modules")?
            .iter()
            .map(|m| -> Result<ModuleSpec> {
                Ok(ModuleSpec {
                    name: m
                        .get("name")
                        .and_then(Value::as_str)
                        .context("module name")?
                        .to_string(),
                    artifact: dir.join(
                        m.get("artifact")
                            .and_then(Value::as_str)
                            .context("module artifact")?,
                    ),
                    sha256: m
                        .get("sha256")
                        .and_then(Value::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    inputs: tensor_specs(m.get("inputs").context("module inputs")?)?,
                    outputs: tensor_specs(m.get("outputs").context("module outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        if modules.is_empty() {
            bail!("manifest declares no modules");
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            use_pallas: v
                .get("use_pallas")
                .and_then(Value::as_bool)
                .unwrap_or(true),
            config,
            modules,
        })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("module '{name}' not in manifest"))
    }

    pub fn module_names(&self) -> Vec<&str> {
        self.modules.iter().map(|m| m.name.as_str()).collect()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A small synthetic manifest for unit tests that don't need artifacts.
    pub(crate) fn test_manifest_json() -> String {
        r#"{
 "version": 1, "use_pallas": true,
 "config": {
  "pc_range": {"x": [0.0, 46.08], "y": [-23.04, 23.04], "z": [-3.0, 1.0]},
  "voxel_size": [0.25, 0.36, 0.36],
  "grid": [16, 128, 128],
  "point_features": 4,
  "vfe_channels": 4,
  "stages": [
   {"name": "conv1", "cin": 4, "cout": 16, "stride": [1,1,1], "submanifold": false, "out_shape": [16,128,128,16]},
   {"name": "conv2", "cin": 16, "cout": 32, "stride": [2,1,1], "submanifold": false, "out_shape": [8,128,128,32]},
   {"name": "conv3", "cin": 32, "cout": 64, "stride": [2,2,2], "submanifold": false, "out_shape": [4,64,64,64]},
   {"name": "conv4", "cin": 64, "cout": 128, "stride": [2,2,2], "submanifold": false, "out_shape": [2,32,32,128]}
  ],
  "bev": {"h": 32, "w": 32, "channels": 256, "backbone_channels": 64},
  "num_classes": 3,
  "anchor_sizes": [[3.9,1.6,1.56],[0.8,0.6,1.73],[1.76,0.6,1.73]],
  "anchor_z": [-1.0,-0.6,-0.6],
  "anchor_rotations": [0.0,1.5707963],
  "anchors_per_cell": 6,
  "num_anchors": 6144,
  "box_code_size": 7,
  "num_proposals": 96,
  "roi_grid": 4,
  "roi_pool_scales": ["conv2","conv3","conv4"],
  "roi_pool_channels": 32,
  "weights_seed": 20250710
 },
 "modules": [
  {"name": "vfe", "artifact": "vfe.hlo.txt", "sha256": "", "inputs": [{"name": "points_sum", "shape": [16,128,128,4]}, {"name": "points_cnt", "shape": [16,128,128,1]}], "outputs": [{"name": "vfe_feat", "shape": [16,128,128,4]}, {"name": "vfe_mask", "shape": [16,128,128,1]}]},
  {"name": "conv1", "artifact": "conv1.hlo.txt", "sha256": "", "inputs": [{"name": "vfe_feat", "shape": [16,128,128,4]}, {"name": "vfe_mask", "shape": [16,128,128,1]}], "outputs": [{"name": "conv1_feat", "shape": [16,128,128,16]}, {"name": "conv1_mask", "shape": [16,128,128,1]}]},
  {"name": "conv2", "artifact": "conv2.hlo.txt", "sha256": "", "inputs": [{"name": "conv1_feat", "shape": [16,128,128,16]}, {"name": "conv1_mask", "shape": [16,128,128,1]}], "outputs": [{"name": "conv2_feat", "shape": [8,128,128,32]}, {"name": "conv2_mask", "shape": [8,128,128,1]}]},
  {"name": "conv3", "artifact": "conv3.hlo.txt", "sha256": "", "inputs": [{"name": "conv2_feat", "shape": [8,128,128,32]}, {"name": "conv2_mask", "shape": [8,128,128,1]}], "outputs": [{"name": "conv3_feat", "shape": [4,64,64,64]}, {"name": "conv3_mask", "shape": [4,64,64,1]}]},
  {"name": "conv4", "artifact": "conv4.hlo.txt", "sha256": "", "inputs": [{"name": "conv3_feat", "shape": [4,64,64,64]}, {"name": "conv3_mask", "shape": [4,64,64,1]}], "outputs": [{"name": "conv4_feat", "shape": [2,32,32,128]}, {"name": "conv4_mask", "shape": [2,32,32,1]}]},
  {"name": "bev_head", "artifact": "bev_head.hlo.txt", "sha256": "", "inputs": [{"name": "conv4_feat", "shape": [2,32,32,128]}], "outputs": [{"name": "cls_logits", "shape": [6144]}, {"name": "box_preds", "shape": [6144,7]}, {"name": "dir_logits", "shape": [6144,2]}]},
  {"name": "roi_head", "artifact": "roi_head.hlo.txt", "sha256": "", "inputs": [{"name": "conv2_feat", "shape": [8,128,128,32]}, {"name": "conv3_feat", "shape": [4,64,64,64]}, {"name": "conv4_feat", "shape": [2,32,32,128]}, {"name": "rois", "shape": [96,7]}], "outputs": [{"name": "roi_scores", "shape": [96]}, {"name": "roi_boxes", "shape": [96,7]}]}
 ]
}"#
        .to_string()
    }

    pub(crate) fn test_manifest() -> Manifest {
        Manifest::parse(&test_manifest_json(), Path::new("/nonexistent")).unwrap()
    }

    #[test]
    fn parses_test_manifest() {
        let m = test_manifest();
        assert_eq!(m.modules.len(), 7);
        assert_eq!(m.config.grid, [16, 128, 128]);
        assert_eq!(m.config.stages[1].stride, [2, 1, 1]);
        assert_eq!(m.module("roi_head").unwrap().inputs.len(), 4);
        assert!(m.module("nope").is_err());
    }

    #[test]
    fn parses_bev_and_roi_geometry() {
        let m = test_manifest();
        assert_eq!(m.config.bev_channels, 256);
        assert_eq!(m.config.bev_backbone_channels, 64);
        assert_eq!(m.config.roi_grid, 4);
        assert_eq!(m.config.roi_pool_scales, ["conv2", "conv3", "conv4"]);
        assert_eq!(m.config.roi_pool_channels, 32);
        // unspecified widths fall back to the python config defaults
        assert_eq!(m.config.roi_mlp, 128);
        assert_eq!(m.config.roi_fc, 128);
    }

    #[test]
    fn tensor_spec_sizes() {
        let m = test_manifest();
        let vfe = m.module("vfe").unwrap();
        assert_eq!(vfe.inputs[0].numel(), 16 * 128 * 128 * 4);
        assert_eq!(vfe.inputs[1].size_bytes(), 16 * 128 * 128 * 4);
    }
}
