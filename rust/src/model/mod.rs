//! Model description: artifact manifest, pipeline graph, anchors.

pub mod anchors;
pub mod graph;
pub mod manifest;
