//! Pipeline dataflow graph and split-point liveness analysis.
//!
//! This is the static-analysis core of the paper's contribution: given the
//! OpenPCDet-style ordered module list and each module's tensor I/O, compute
//! for every split point exactly which tensors must cross the edge→server
//! link — the paper's Table II, generalized to any cut.
//!
//! The graph contains two rust-executed pseudo-modules alongside the XLA
//! artifacts: `preprocess` (point→voxel scatter, runs before VFE) and
//! `proposal` (sigmoid + top-K + NMS between DenseHead and RoIHead, kept
//! out of the HLO because its shapes are dynamic).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Where a node executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// rust voxelizer (`voxel::Voxelizer`)
    Preprocess,
    /// AOT'd XLA artifact, executed by `runtime::XlaRuntime`
    Xla,
    /// rust proposal stage (`postprocess`): decode + top-K + NMS
    Proposal,
}

/// One stage of the ordered pipeline.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub kind: NodeKind,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The tensor crossing the sensor boundary into the pipeline.
pub const PRIMAL: &str = "points";
/// Tensors returned to the requester. `roi_classes` is produced by the rust
/// proposal stage (class labels ride outside the RoI head, as in OpenPCDet).
pub const FINAL_OUTPUTS: [&str; 3] = ["roi_scores", "roi_boxes", "roi_classes"];

/// A split point: the first `head_len` nodes run on the edge device, the
/// rest on the edge server. `head_len == 0` is the raw-offload baseline
/// (ship the point cloud); `head_len == graph.len()` is edge-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SplitPoint {
    pub head_len: usize,
}

#[derive(Debug, Clone)]
pub struct PipelineGraph {
    nodes: Vec<Node>,
    /// tensor name -> producing node index (primal tensors absent).
    produced_by: HashMap<String, usize>,
}

impl PipelineGraph {
    /// Build the Voxel R-CNN pipeline graph from the artifact manifest.
    pub fn from_manifest(m: &Manifest) -> Result<PipelineGraph> {
        let mut nodes = vec![Node {
            name: "preprocess".into(),
            kind: NodeKind::Preprocess,
            inputs: vec![PRIMAL.into()],
            outputs: vec!["points_sum".into(), "points_cnt".into()],
        }];
        for spec in &m.modules {
            // the rust proposal stage slots between bev_head and roi_head
            if spec.name == "roi_head" {
                nodes.push(Node {
                    name: "proposal".into(),
                    kind: NodeKind::Proposal,
                    inputs: vec![
                        "cls_logits".into(),
                        "box_preds".into(),
                        "dir_logits".into(),
                    ],
                    outputs: vec!["rois".into(), "roi_classes".into()],
                });
            }
            nodes.push(Node {
                name: spec.name.clone(),
                kind: NodeKind::Xla,
                inputs: spec.inputs.iter().map(|t| t.name.clone()).collect(),
                outputs: spec.outputs.iter().map(|t| t.name.clone()).collect(),
            });
        }
        Self::new(nodes)
    }

    /// Build from an explicit node list (tests, alternative models).
    pub fn new(nodes: Vec<Node>) -> Result<PipelineGraph> {
        let mut produced_by = HashMap::new();
        for (i, n) in nodes.iter().enumerate() {
            for o in &n.outputs {
                if produced_by.insert(o.clone(), i).is_some() {
                    bail!("tensor '{o}' produced twice");
                }
                if o == PRIMAL {
                    bail!("'{PRIMAL}' is reserved for the sensor input");
                }
            }
        }
        // dataflow must be a forward DAG over the ordered list
        for (i, n) in nodes.iter().enumerate() {
            for inp in &n.inputs {
                if inp == PRIMAL {
                    continue;
                }
                match produced_by.get(inp) {
                    Some(&p) if p < i => {}
                    Some(&p) => bail!(
                        "node '{}' consumes '{inp}' produced later (node {p})",
                        n.name
                    ),
                    None => bail!("node '{}' consumes undeclared '{inp}'", n.name),
                }
            }
        }
        for f in FINAL_OUTPUTS {
            if !produced_by.contains_key(f) {
                bail!("graph never produces final output '{f}'");
            }
        }
        Ok(PipelineGraph { nodes, produced_by })
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node_index(&self, name: &str) -> Result<usize> {
        self.nodes
            .iter()
            .position(|n| n.name == name)
            .with_context(|| format!("no node named '{name}'"))
    }

    /// The split point placed immediately after `node_name`.
    pub fn split_after(&self, node_name: &str) -> Result<SplitPoint> {
        Ok(SplitPoint {
            head_len: self.node_index(node_name)? + 1,
        })
    }

    /// Raw offload: the whole pipeline runs on the server.
    pub fn split_raw(&self) -> SplitPoint {
        SplitPoint { head_len: 0 }
    }

    /// Edge only: no server involvement.
    pub fn split_edge_only(&self) -> SplitPoint {
        SplitPoint {
            head_len: self.len(),
        }
    }

    /// Parse a split-point name: `raw`, `edge_only`, or `after:<node>` /
    /// bare node name.
    pub fn split_by_name(&self, name: &str) -> Result<SplitPoint> {
        match name {
            "raw" => Ok(self.split_raw()),
            "edge_only" | "edge-only" => Ok(self.split_edge_only()),
            n => self.split_after(n.strip_prefix("after:").unwrap_or(n)),
        }
    }

    /// Human-readable label for a split point.
    pub fn split_label(&self, sp: SplitPoint) -> String {
        if sp.head_len == 0 {
            "raw".into()
        } else if sp.head_len == self.len() {
            "edge_only".into()
        } else {
            format!("after:{}", self.nodes[sp.head_len - 1].name)
        }
    }

    /// All valid split points, raw → edge_only.
    pub fn all_splits(&self) -> Vec<SplitPoint> {
        (0..=self.len()).map(|h| SplitPoint { head_len: h }).collect()
    }

    /// **Table II**: tensors that must cross the edge→server link for a
    /// split — produced on the head side (or primal) and consumed on the
    /// tail side. Deterministic order: by producing node, then declaration.
    pub fn live_set(&self, sp: SplitPoint) -> Vec<String> {
        if sp.head_len >= self.len() {
            return vec![]; // edge-only: nothing crosses
        }
        let mut live: Vec<String> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        // primal first
        for tail in &self.nodes[sp.head_len..] {
            for inp in &tail.inputs {
                let produced_in_head = match self.produced_by.get(inp) {
                    None => true, // primal: captured at the sensor (edge side)
                    Some(&p) => p < sp.head_len,
                };
                if produced_in_head && seen.insert(inp.clone()) {
                    live.push(inp.clone());
                }
            }
        }
        // order by producer for determinism (primal = front)
        live.sort_by_key(|t| self.produced_by.get(t).map_or(-1, |&p| p as i64));
        live
    }

    /// Tensors returned server→edge: the final outputs that were produced
    /// on the server side (those already on the edge don't cross back).
    pub fn response_set(&self, sp: SplitPoint) -> Vec<String> {
        FINAL_OUTPUTS
            .iter()
            .filter(|f| {
                self.produced_by
                    .get(**f)
                    .is_some_and(|&p| p >= sp.head_len)
            })
            .map(|s| s.to_string())
            .collect()
    }

    /// Nodes on the edge side of the split.
    pub fn head_nodes(&self, sp: SplitPoint) -> &[Node] {
        &self.nodes[..sp.head_len.min(self.len())]
    }

    /// Nodes on the server side of the split.
    pub fn tail_nodes(&self, sp: SplitPoint) -> &[Node] {
        &self.nodes[sp.head_len.min(self.len())..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    fn graph() -> PipelineGraph {
        PipelineGraph::from_manifest(&test_manifest()).unwrap()
    }

    #[test]
    fn node_order_matches_openpcdet() {
        let g = graph();
        let names: Vec<_> = g.nodes().iter().map(|n| n.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "preprocess", "vfe", "conv1", "conv2", "conv3", "conv4",
                "bev_head", "proposal", "roi_head"
            ]
        );
    }

    #[test]
    fn table2_live_sets() {
        // Paper Table II: conv1 -> {conv1}; conv2 -> {conv2};
        // conv3 -> {conv2, conv3}; conv4 -> {conv2, conv3, conv4}.
        // Masks ride along for the stages whose features feed the next conv.
        let g = graph();
        let ls = |n: &str| g.live_set(g.split_after(n).unwrap());
        assert_eq!(ls("conv1"), ["conv1_feat", "conv1_mask"]);
        assert_eq!(ls("conv2"), ["conv2_feat", "conv2_mask"]);
        assert_eq!(ls("conv3"), ["conv2_feat", "conv3_feat", "conv3_mask"]);
        assert_eq!(ls("conv4"), ["conv2_feat", "conv3_feat", "conv4_feat"]);
    }

    #[test]
    fn raw_and_vfe_and_edge_only() {
        let g = graph();
        assert_eq!(g.live_set(g.split_raw()), ["points"]);
        assert_eq!(
            g.live_set(g.split_after("preprocess").unwrap()),
            ["points_sum", "points_cnt"]
        );
        assert_eq!(
            g.live_set(g.split_after("vfe").unwrap()),
            ["vfe_feat", "vfe_mask"]
        );
        assert!(g.live_set(g.split_edge_only()).is_empty());
        assert!(g.response_set(g.split_edge_only()).is_empty());
        assert_eq!(
            g.response_set(g.split_raw()),
            ["roi_scores", "roi_boxes", "roi_classes"]
        );
        // proposal on the edge: its classes stay there, only RoI-head
        // outputs cross back
        assert_eq!(
            g.response_set(g.split_after("proposal").unwrap()),
            ["roi_scores", "roi_boxes"]
        );
    }

    #[test]
    fn proposal_split_wires_rois_plus_roi_inputs() {
        let g = graph();
        let ls = g.live_set(g.split_after("proposal").unwrap());
        assert_eq!(ls, ["conv2_feat", "conv3_feat", "conv4_feat", "rois"]);
    }

    #[test]
    fn split_labels_roundtrip() {
        let g = graph();
        for sp in g.all_splits() {
            let label = g.split_label(sp);
            assert_eq!(g.split_by_name(&label).unwrap(), sp, "{label}");
        }
    }

    #[test]
    fn rejects_malformed_graphs() {
        // consumes-before-produced
        let bad = vec![
            Node {
                name: "a".into(),
                kind: NodeKind::Xla,
                inputs: vec!["t".into()],
                outputs: vec!["roi_scores".into(), "roi_boxes".into()],
            },
            Node {
                name: "b".into(),
                kind: NodeKind::Xla,
                inputs: vec![PRIMAL.into()],
                outputs: vec!["t".into()],
            },
        ];
        assert!(PipelineGraph::new(bad).is_err());
        // double production
        let dup = vec![Node {
            name: "a".into(),
            kind: NodeKind::Xla,
            inputs: vec![PRIMAL.into()],
            outputs: vec!["x".into(), "x".into()],
        }];
        assert!(PipelineGraph::new(dup).is_err());
        // missing final outputs
        let nofinal = vec![Node {
            name: "a".into(),
            kind: NodeKind::Xla,
            inputs: vec![PRIMAL.into()],
            outputs: vec!["x".into()],
        }];
        assert!(PipelineGraph::new(nofinal).is_err());
    }

    #[test]
    fn head_tail_partition() {
        let g = graph();
        for sp in g.all_splits() {
            assert_eq!(g.head_nodes(sp).len() + g.tail_nodes(sp).len(), g.len());
        }
    }
}
