//! Pipeline dataflow graph and split-point liveness analysis.
//!
//! This is the static-analysis core of the paper's contribution: given the
//! OpenPCDet-style ordered module list and each module's tensor I/O, compute
//! for every split point exactly which tensors must cross the edge→server
//! link — the paper's Table II, generalized to any cut.
//!
//! The graph contains two rust-executed pseudo-modules alongside the XLA
//! artifacts: `preprocess` (point→voxel scatter, runs before VFE) and
//! `proposal` (sigmoid + top-K + NMS between DenseHead and RoIHead, kept
//! out of the HLO because its shapes are dynamic).
//!
//! Every tensor name is interned to a dense [`TensorId`] at build time and
//! the per-split live/response sets are precomputed as id lists, so the
//! per-frame execution path ([`crate::coordinator::engine`]) indexes a
//! [`TensorStore`] slot vector instead of hashing `String`s.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use crate::tensor::Tensor;

/// Where a node executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// rust voxelizer (`voxel::Voxelizer`)
    Preprocess,
    /// AOT'd XLA artifact, executed by `runtime::XlaRuntime`
    Xla,
    /// rust proposal stage (`postprocess`): decode + top-K + NMS
    Proposal,
}

/// Dense id of an interned tensor name (graph-scoped).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

impl TensorId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One stage of the ordered pipeline.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub kind: NodeKind,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    input_ids: Vec<TensorId>,
    output_ids: Vec<TensorId>,
}

impl Node {
    /// Build a node from its declared I/O. Tensor ids are assigned when
    /// the node list is handed to [`PipelineGraph::new`].
    pub fn new(
        name: impl Into<String>,
        kind: NodeKind,
        inputs: Vec<String>,
        outputs: Vec<String>,
    ) -> Node {
        Node {
            name: name.into(),
            kind,
            inputs,
            outputs,
            input_ids: Vec::new(),
            output_ids: Vec::new(),
        }
    }

    /// Interned input ids, aligned with `inputs`.
    pub fn input_ids(&self) -> &[TensorId] {
        &self.input_ids
    }

    /// Interned output ids, aligned with `outputs`.
    pub fn output_ids(&self) -> &[TensorId] {
        &self.output_ids
    }
}

/// The tensor crossing the sensor boundary into the pipeline.
pub const PRIMAL: &str = "points";
/// Tensors returned to the requester. `roi_classes` is produced by the rust
/// proposal stage (class labels ride outside the RoI head, as in OpenPCDet).
pub const FINAL_OUTPUTS: [&str; 3] = ["roi_scores", "roi_boxes", "roi_classes"];

/// A split point: the first `head_len` nodes run on the edge device, the
/// rest on the edge server. `head_len == 0` is the raw-offload baseline
/// (ship the point cloud); `head_len == graph.len()` is edge-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SplitPoint {
    pub head_len: usize,
}

#[derive(Debug, Clone)]
pub struct PipelineGraph {
    nodes: Vec<Node>,
    /// id -> name (id 0 is always the primal).
    tensor_names: Vec<String>,
    /// name -> id; only used at build time and by cross-process decoders.
    tensor_ids: HashMap<String, TensorId>,
    /// id -> producing node index (-1 for the primal).
    producer: Vec<i64>,
    /// precomputed live set per head_len (0..=len), as ids.
    live_ids: Vec<Vec<TensorId>>,
    /// precomputed response set per head_len (0..=len), as ids.
    response_ids: Vec<Vec<TensorId>>,
    /// ids of FINAL_OUTPUTS, in declaration order.
    final_ids: [TensorId; 3],
}

impl PipelineGraph {
    /// Build the Voxel R-CNN pipeline graph from the artifact manifest.
    pub fn from_manifest(m: &Manifest) -> Result<PipelineGraph> {
        let mut nodes = vec![Node::new(
            "preprocess",
            NodeKind::Preprocess,
            vec![PRIMAL.into()],
            vec!["points_sum".into(), "points_cnt".into()],
        )];
        for spec in &m.modules {
            // the rust proposal stage slots between bev_head and roi_head
            if spec.name == "roi_head" {
                nodes.push(Node::new(
                    "proposal",
                    NodeKind::Proposal,
                    vec![
                        "cls_logits".into(),
                        "box_preds".into(),
                        "dir_logits".into(),
                    ],
                    vec!["rois".into(), "roi_classes".into()],
                ));
            }
            nodes.push(Node::new(
                spec.name.clone(),
                NodeKind::Xla,
                spec.inputs.iter().map(|t| t.name.clone()).collect(),
                spec.outputs.iter().map(|t| t.name.clone()).collect(),
            ));
        }
        Self::new(nodes)
    }

    /// Build from an explicit node list (tests, alternative models).
    pub fn new(mut nodes: Vec<Node>) -> Result<PipelineGraph> {
        let mut produced_by = HashMap::new();
        for (i, n) in nodes.iter().enumerate() {
            for o in &n.outputs {
                if produced_by.insert(o.clone(), i).is_some() {
                    bail!("tensor '{o}' produced twice");
                }
                if o == PRIMAL {
                    bail!("'{PRIMAL}' is reserved for the sensor input");
                }
            }
        }
        // dataflow must be a forward DAG over the ordered list
        for (i, n) in nodes.iter().enumerate() {
            for inp in &n.inputs {
                if inp == PRIMAL {
                    continue;
                }
                match produced_by.get(inp) {
                    Some(&p) if p < i => {}
                    Some(&p) => bail!(
                        "node '{}' consumes '{inp}' produced later (node {p})",
                        n.name
                    ),
                    None => bail!("node '{}' consumes undeclared '{inp}'", n.name),
                }
            }
        }
        for f in FINAL_OUTPUTS {
            if !produced_by.contains_key(f) {
                bail!("graph never produces final output '{f}'");
            }
        }

        // ---- intern every tensor name to a dense id (primal first)
        let mut tensor_names: Vec<String> = vec![PRIMAL.to_string()];
        let mut tensor_ids: HashMap<String, TensorId> = HashMap::new();
        tensor_ids.insert(PRIMAL.to_string(), TensorId(0));
        let mut intern = |name: &str,
                          names: &mut Vec<String>,
                          ids: &mut HashMap<String, TensorId>| {
            if let Some(&id) = ids.get(name) {
                return id;
            }
            let id = TensorId(names.len() as u32);
            names.push(name.to_string());
            ids.insert(name.to_string(), id);
            id
        };
        for n in nodes.iter_mut() {
            n.input_ids = n
                .inputs
                .iter()
                .map(|t| intern(t, &mut tensor_names, &mut tensor_ids))
                .collect();
            n.output_ids = n
                .outputs
                .iter()
                .map(|t| intern(t, &mut tensor_names, &mut tensor_ids))
                .collect();
        }
        let mut producer = vec![-1i64; tensor_names.len()];
        for (i, n) in nodes.iter().enumerate() {
            for id in &n.output_ids {
                producer[id.index()] = i as i64;
            }
        }

        // ---- precompute per-split live and response sets (paper Table II)
        let len = nodes.len();
        let mut live_ids = Vec::with_capacity(len + 1);
        let mut response_ids = Vec::with_capacity(len + 1);
        let final_id = |name: &str| tensor_ids[name];
        let finals = [
            final_id(FINAL_OUTPUTS[0]),
            final_id(FINAL_OUTPUTS[1]),
            final_id(FINAL_OUTPUTS[2]),
        ];
        for h in 0..=len {
            let mut live: Vec<TensorId> = Vec::new();
            if h < len {
                let mut seen = vec![false; tensor_names.len()];
                for tail in &nodes[h..] {
                    for &inp in &tail.input_ids {
                        let in_head = producer[inp.index()] < h as i64;
                        if in_head && !seen[inp.index()] {
                            seen[inp.index()] = true;
                            live.push(inp);
                        }
                    }
                }
                // order by producer for determinism (primal = front);
                // stable sort preserves first-seen order within a producer
                live.sort_by_key(|id| producer[id.index()]);
            }
            live_ids.push(live);
            response_ids.push(
                finals
                    .iter()
                    .copied()
                    .filter(|id| producer[id.index()] >= h as i64)
                    .collect(),
            );
        }

        Ok(PipelineGraph {
            nodes,
            tensor_names,
            tensor_ids,
            producer,
            live_ids,
            response_ids,
            final_ids: finals,
        })
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Number of interned tensors (the slot count of a [`TensorStore`]).
    pub fn tensor_count(&self) -> usize {
        self.tensor_names.len()
    }

    /// Interned id of a tensor name, if the graph declares it.
    pub fn tensor_id(&self, name: &str) -> Option<TensorId> {
        self.tensor_ids.get(name).copied()
    }

    /// Name of an interned tensor id.
    pub fn tensor_name(&self, id: TensorId) -> &str {
        &self.tensor_names[id.index()]
    }

    /// Id of the sensor-input tensor (`points`).
    pub fn primal_id(&self) -> TensorId {
        TensorId(0)
    }

    /// Ids of [`FINAL_OUTPUTS`], in declaration order.
    pub fn final_output_ids(&self) -> [TensorId; 3] {
        self.final_ids
    }

    pub fn node_index(&self, name: &str) -> Result<usize> {
        self.nodes
            .iter()
            .position(|n| n.name == name)
            .with_context(|| format!("no node named '{name}'"))
    }

    /// The split point placed immediately after `node_name`.
    pub fn split_after(&self, node_name: &str) -> Result<SplitPoint> {
        Ok(SplitPoint {
            head_len: self.node_index(node_name)? + 1,
        })
    }

    /// Raw offload: the whole pipeline runs on the server.
    pub fn split_raw(&self) -> SplitPoint {
        SplitPoint { head_len: 0 }
    }

    /// Edge only: no server involvement.
    pub fn split_edge_only(&self) -> SplitPoint {
        SplitPoint {
            head_len: self.len(),
        }
    }

    /// Parse a split-point name: `raw`, `edge_only`, or `after:<node>` /
    /// bare node name.
    pub fn split_by_name(&self, name: &str) -> Result<SplitPoint> {
        match name {
            "raw" => Ok(self.split_raw()),
            "edge_only" | "edge-only" => Ok(self.split_edge_only()),
            n => self.split_after(n.strip_prefix("after:").unwrap_or(n)),
        }
    }

    /// Human-readable label for a split point.
    pub fn split_label(&self, sp: SplitPoint) -> String {
        if sp.head_len == 0 {
            "raw".into()
        } else if sp.head_len == self.len() {
            "edge_only".into()
        } else {
            format!("after:{}", self.nodes[sp.head_len - 1].name)
        }
    }

    /// All valid split points, raw → edge_only.
    pub fn all_splits(&self) -> Vec<SplitPoint> {
        (0..=self.len()).map(|h| SplitPoint { head_len: h }).collect()
    }

    /// **Table II** as interned ids, precomputed at build time: tensors
    /// that must cross the edge→server link for a split — produced on the
    /// head side (or primal) and consumed on the tail side. Deterministic
    /// order: by producing node, then declaration.
    pub fn live_ids(&self, sp: SplitPoint) -> &[TensorId] {
        self.live_ids
            .get(sp.head_len)
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// [`Self::live_ids`] resolved to names (reports, cross-process wire).
    pub fn live_set(&self, sp: SplitPoint) -> Vec<String> {
        self.live_ids(sp)
            .iter()
            .map(|&id| self.tensor_name(id).to_string())
            .collect()
    }

    /// Tensors returned server→edge, as precomputed ids: the final outputs
    /// produced on the server side (those already on the edge don't cross
    /// back).
    pub fn response_ids(&self, sp: SplitPoint) -> &[TensorId] {
        self.response_ids
            .get(sp.head_len.min(self.len()))
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// [`Self::response_ids`] resolved to names.
    pub fn response_set(&self, sp: SplitPoint) -> Vec<String> {
        self.response_ids(sp)
            .iter()
            .map(|&id| self.tensor_name(id).to_string())
            .collect()
    }

    /// Producing node index of a tensor id (-1 for the primal).
    pub fn producer_of(&self, id: TensorId) -> i64 {
        self.producer[id.index()]
    }

    /// Nodes on the edge side of the split.
    pub fn head_nodes(&self, sp: SplitPoint) -> &[Node] {
        &self.nodes[..sp.head_len.min(self.len())]
    }

    /// Nodes on the server side of the split.
    pub fn tail_nodes(&self, sp: SplitPoint) -> &[Node] {
        &self.nodes[sp.head_len.min(self.len())..]
    }
}

// -------------------------------------------------------------- the store

/// Per-frame tensor store: one refcounted slot per interned tensor id.
/// Replaces the `HashMap<String, Tensor>` of the stringly-typed engine —
/// no hashing, no deep clones; tensors flow between nodes, packets and
/// finalize as `Arc<Tensor>`.
#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    slots: Vec<Option<Arc<Tensor>>>,
}

impl TensorStore {
    /// An empty store sized for `graph`.
    pub fn for_graph(graph: &PipelineGraph) -> TensorStore {
        TensorStore {
            slots: vec![None; graph.tensor_count()],
        }
    }

    pub fn insert(&mut self, id: TensorId, t: Arc<Tensor>) {
        self.slots[id.index()] = Some(t);
    }

    pub fn get(&self, id: TensorId) -> Option<&Arc<Tensor>> {
        self.slots.get(id.index()).and_then(Option::as_ref)
    }

    /// Remove and return a slot (frame teardown hands buffers back to
    /// pools through here).
    pub fn take(&mut self, id: TensorId) -> Option<Arc<Tensor>> {
        self.slots.get_mut(id.index()).and_then(Option::take)
    }

    /// Clear every slot, keeping the allocation for the next frame.
    pub fn clear(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
    }

    /// Number of occupied slots.
    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    fn graph() -> PipelineGraph {
        PipelineGraph::from_manifest(&test_manifest()).unwrap()
    }

    #[test]
    fn node_order_matches_openpcdet() {
        let g = graph();
        let names: Vec<_> = g.nodes().iter().map(|n| n.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "preprocess", "vfe", "conv1", "conv2", "conv3", "conv4",
                "bev_head", "proposal", "roi_head"
            ]
        );
    }

    #[test]
    fn table2_live_sets() {
        // Paper Table II: conv1 -> {conv1}; conv2 -> {conv2};
        // conv3 -> {conv2, conv3}; conv4 -> {conv2, conv3, conv4}.
        // Masks ride along for the stages whose features feed the next conv.
        let g = graph();
        let ls = |n: &str| g.live_set(g.split_after(n).unwrap());
        assert_eq!(ls("conv1"), ["conv1_feat", "conv1_mask"]);
        assert_eq!(ls("conv2"), ["conv2_feat", "conv2_mask"]);
        assert_eq!(ls("conv3"), ["conv2_feat", "conv3_feat", "conv3_mask"]);
        assert_eq!(ls("conv4"), ["conv2_feat", "conv3_feat", "conv4_feat"]);
    }

    #[test]
    fn raw_and_vfe_and_edge_only() {
        let g = graph();
        assert_eq!(g.live_set(g.split_raw()), ["points"]);
        assert_eq!(
            g.live_set(g.split_after("preprocess").unwrap()),
            ["points_sum", "points_cnt"]
        );
        assert_eq!(
            g.live_set(g.split_after("vfe").unwrap()),
            ["vfe_feat", "vfe_mask"]
        );
        assert!(g.live_set(g.split_edge_only()).is_empty());
        assert!(g.response_set(g.split_edge_only()).is_empty());
        assert_eq!(
            g.response_set(g.split_raw()),
            ["roi_scores", "roi_boxes", "roi_classes"]
        );
        // proposal on the edge: its classes stay there, only RoI-head
        // outputs cross back
        assert_eq!(
            g.response_set(g.split_after("proposal").unwrap()),
            ["roi_scores", "roi_boxes"]
        );
    }

    #[test]
    fn proposal_split_wires_rois_plus_roi_inputs() {
        let g = graph();
        let ls = g.live_set(g.split_after("proposal").unwrap());
        assert_eq!(ls, ["conv2_feat", "conv3_feat", "conv4_feat", "rois"]);
    }

    #[test]
    fn split_labels_roundtrip() {
        let g = graph();
        for sp in g.all_splits() {
            let label = g.split_label(sp);
            assert_eq!(g.split_by_name(&label).unwrap(), sp, "{label}");
        }
    }

    #[test]
    fn interned_ids_are_consistent() {
        let g = graph();
        assert_eq!(g.tensor_name(g.primal_id()), PRIMAL);
        for (i, n) in g.nodes().iter().enumerate() {
            assert_eq!(n.input_ids().len(), n.inputs.len(), "node {i}");
            assert_eq!(n.output_ids().len(), n.outputs.len(), "node {i}");
            for (name, &id) in n.inputs.iter().zip(n.input_ids()) {
                assert_eq!(g.tensor_name(id), name);
                assert_eq!(g.tensor_id(name), Some(id));
            }
            for (name, &id) in n.outputs.iter().zip(n.output_ids()) {
                assert_eq!(g.tensor_name(id), name);
                assert_eq!(g.producer_of(id), i as i64);
            }
        }
        assert_eq!(g.tensor_id("no_such_tensor"), None);
    }

    #[test]
    fn live_ids_match_live_names_at_every_split() {
        let g = graph();
        for sp in g.all_splits() {
            let by_id: Vec<&str> =
                g.live_ids(sp).iter().map(|&id| g.tensor_name(id)).collect();
            let by_name = g.live_set(sp);
            assert_eq!(by_id, by_name, "{}", g.split_label(sp));
            let resp_id: Vec<&str> = g
                .response_ids(sp)
                .iter()
                .map(|&id| g.tensor_name(id))
                .collect();
            assert_eq!(resp_id, g.response_set(sp), "{}", g.split_label(sp));
        }
    }

    #[test]
    fn store_slots_roundtrip() {
        let g = graph();
        let mut store = TensorStore::for_graph(&g);
        assert_eq!(store.occupied(), 0);
        let id = g.tensor_id("vfe_feat").unwrap();
        let t = Arc::new(Tensor::zeros(&[2, 2]));
        store.insert(id, t.clone());
        assert_eq!(store.occupied(), 1);
        assert!(Arc::ptr_eq(store.get(id).unwrap(), &t));
        let back = store.take(id).unwrap();
        assert!(Arc::ptr_eq(&back, &t));
        assert!(store.get(id).is_none());
        store.insert(id, t);
        store.clear();
        assert_eq!(store.occupied(), 0);
    }

    #[test]
    fn rejects_malformed_graphs() {
        // consumes-before-produced
        let bad = vec![
            Node::new(
                "a",
                NodeKind::Xla,
                vec!["t".into()],
                vec!["roi_scores".into(), "roi_boxes".into()],
            ),
            Node::new("b", NodeKind::Xla, vec![PRIMAL.into()], vec!["t".into()]),
        ];
        assert!(PipelineGraph::new(bad).is_err());
        // double production
        let dup = vec![Node::new(
            "a",
            NodeKind::Xla,
            vec![PRIMAL.into()],
            vec!["x".into(), "x".into()],
        )];
        assert!(PipelineGraph::new(dup).is_err());
        // missing final outputs
        let nofinal = vec![Node::new(
            "a",
            NodeKind::Xla,
            vec![PRIMAL.into()],
            vec!["x".into()],
        )];
        assert!(PipelineGraph::new(nofinal).is_err());
    }

    #[test]
    fn head_tail_partition() {
        let g = graph();
        for sp in g.all_splits() {
            assert_eq!(g.head_nodes(sp).len() + g.tail_nodes(sp).len(), g.len());
        }
    }
}
