//! Point-cloud substrate: frame types, synthetic KITTI-like scene
//! generation, and a reader for real KITTI velodyne `.bin` files.
//!
//! Substitution (DESIGN.md §3): the paper evaluates on KITTI scans captured
//! by a Velodyne HDL-64E; this environment has no dataset access, so
//! [`scene`] synthesizes scenes with KITTI-like statistics (ground plane,
//! boxy vehicles/pedestrians/cyclists, radial ring sampling with
//! range-dependent density). Every measured quantity in the paper's
//! evaluation depends on the cloud only through point count and voxel
//! occupancy, which the generator calibrates to the dataset's range.

pub mod kitti;
pub mod scene;

pub use kitti::{RecordedSource, RecorderSink};

/// One LiDAR return: metric xyz + reflectance intensity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub intensity: f32,
}

/// A single LiDAR sweep from one sensor.
#[derive(Debug, Clone, Default)]
pub struct PointCloud {
    pub points: Vec<Point>,
}

impl PointCloud {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Wire size if shipped raw (the paper's Fig 8 "input point cloud data"
    /// baseline): 4 f32 per point, KITTI's on-disk format.
    pub fn size_bytes(&self) -> usize {
        self.points.len() * 16
    }

    /// Flatten to an (N, 4) row-major buffer.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.points.len() * 4);
        for p in &self.points {
            v.extend_from_slice(&[p.x, p.y, p.z, p.intensity]);
        }
        v
    }

    /// Rebuild from an (N, 4) row-major buffer.
    pub fn from_flat(data: &[f32]) -> PointCloud {
        assert_eq!(data.len() % 4, 0, "flat cloud length must be 4N");
        PointCloud {
            points: data
                .chunks_exact(4)
                .map(|c| Point {
                    x: c[0],
                    y: c[1],
                    z: c[2],
                    intensity: c[3],
                })
                .collect(),
        }
    }

    /// As a rust [`crate::Tensor`] for the wire codec (raw-offload split).
    pub fn to_tensor(&self) -> crate::Tensor {
        crate::Tensor::from_vec(&[self.points.len(), 4], self.to_flat())
            .expect("flat cloud is always consistent")
    }
}

/// A frame: one cloud plus provenance (sensor id, sequence number).
#[derive(Debug, Clone)]
pub struct Frame {
    pub sensor_id: u32,
    pub seq: u64,
    pub cloud: PointCloud,
}

/// A stream of LiDAR frames — the input half of a
/// [`crate::coordinator::session::SplitSession`].
///
/// Implementations pull frames from wherever they live (the synthetic
/// generator, a KITTI `.bin` directory, a recorded file) and the session,
/// the staged pipeline ([`crate::coordinator::pipeline::run_source`]) and
/// the [`crate::coordinator::batcher::Batcher`] consume them uniformly.
/// Sources are `Send` so a feeder thread can drive them while the caller
/// drains results.
pub trait FrameSource: Send {
    /// Next frame in the stream; `None` once exhausted. Sources are pull
    /// based, so backpressure from a bounded consumer throttles I/O for
    /// free.
    fn next_frame(&mut self) -> anyhow::Result<Option<Frame>>;

    /// Remaining-frame count, when the source knows it (directory listings
    /// and replays do; unbounded generators return `None`).
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// Human-readable description for logs and session banners.
    fn describe(&self) -> String {
        "frames".to_string()
    }
}

/// Replay a recorded set of clouds, optionally looping the whole sequence
/// `repeat` times — the deterministic source the equivalence tests pin the
/// session against, and the `replay:<file>.bin` CLI spec.
#[derive(Debug, Clone)]
pub struct ReplaySource {
    clouds: std::sync::Arc<Vec<PointCloud>>,
    label: String,
    next: usize,
    total: usize,
}

impl ReplaySource {
    /// Replay an in-memory sequence once.
    pub fn from_clouds(clouds: Vec<PointCloud>) -> ReplaySource {
        let total = clouds.len();
        ReplaySource {
            clouds: std::sync::Arc::new(clouds),
            label: "replay".to_string(),
            next: 0,
            total,
        }
    }

    /// Replay one recorded KITTI-format `.bin` scan (see
    /// [`kitti::read_bin`]).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<ReplaySource> {
        let cloud = kitti::read_bin(path)?;
        let mut s = Self::from_clouds(vec![cloud]);
        s.label = format!("replay:{}", path.display());
        Ok(s)
    }

    /// Loop the recorded sequence until `repeat` copies have been played.
    pub fn repeated(mut self, repeat: usize) -> ReplaySource {
        self.total = self.clouds.len() * repeat;
        self
    }
}

/// Tee wrapper: pass every frame of `inner` through unchanged while
/// recording it into a [`RecorderSink`] replay corpus — how a session's
/// `record:<dir>` sink spec captures whatever it streamed (synthetic,
/// KITTI, multi-sensor fan-in …) as a deterministic regression corpus.
/// The manifest is written when the inner source ends (and best-effort on
/// drop for streams abandoned mid-way).
pub struct RecordingSource {
    inner: Box<dyn FrameSource>,
    sink: RecorderSink,
}

impl RecordingSource {
    pub fn new(
        inner: Box<dyn FrameSource>,
        dir: &std::path::Path,
    ) -> anyhow::Result<RecordingSource> {
        Ok(RecordingSource {
            inner,
            sink: RecorderSink::create(dir)?,
        })
    }
}

impl FrameSource for RecordingSource {
    fn next_frame(&mut self) -> anyhow::Result<Option<Frame>> {
        match self.inner.next_frame()? {
            Some(frame) => {
                self.sink.record(&frame)?;
                Ok(Some(frame))
            }
            None => {
                self.sink.finish()?;
                Ok(None)
            }
        }
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn describe(&self) -> String {
        format!(
            "{} → record:{}",
            self.inner.describe(),
            self.sink.dir().display()
        )
    }
}

impl FrameSource for ReplaySource {
    fn next_frame(&mut self) -> anyhow::Result<Option<Frame>> {
        if self.next >= self.total || self.clouds.is_empty() {
            return Ok(None);
        }
        let seq = self.next as u64;
        let cloud = self.clouds[self.next % self.clouds.len()].clone();
        self.next += 1;
        Ok(Some(Frame {
            sensor_id: 0,
            seq,
            cloud,
        }))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.total - self.next.min(self.total))
    }

    fn describe(&self) -> String {
        format!("{} ({} frame(s))", self.label, self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_roundtrip() {
        let pc = PointCloud {
            points: vec![
                Point { x: 1.0, y: 2.0, z: 3.0, intensity: 0.5 },
                Point { x: -1.0, y: 0.0, z: 0.25, intensity: 0.0 },
            ],
        };
        let back = PointCloud::from_flat(&pc.to_flat());
        assert_eq!(back.points, pc.points);
        assert_eq!(pc.size_bytes(), 32);
    }

    #[test]
    fn tensor_shape() {
        let pc = PointCloud::from_flat(&[0.0; 40]);
        assert_eq!(pc.to_tensor().shape(), &[10, 4]);
    }

    fn cloud_of(n: usize) -> PointCloud {
        PointCloud::from_flat(&vec![1.0; n * 4])
    }

    #[test]
    fn replay_source_plays_in_order_with_hint() {
        let mut s = ReplaySource::from_clouds(vec![cloud_of(1), cloud_of(2), cloud_of(3)]);
        assert_eq!(s.len_hint(), Some(3));
        for expect in [1usize, 2, 3] {
            let f = s.next_frame().unwrap().expect("frame");
            assert_eq!(f.cloud.len(), expect);
            assert_eq!(f.seq as usize + 1, expect);
        }
        assert!(s.next_frame().unwrap().is_none());
        assert_eq!(s.len_hint(), Some(0));
    }

    #[test]
    fn replay_source_repeats_the_sequence() {
        let mut s = ReplaySource::from_clouds(vec![cloud_of(1), cloud_of(2)]).repeated(2);
        let sizes: Vec<usize> = std::iter::from_fn(|| s.next_frame().unwrap())
            .map(|f| f.cloud.len())
            .collect();
        assert_eq!(sizes, [1, 2, 1, 2]);
    }

    #[test]
    fn empty_replay_ends_immediately() {
        let mut s = ReplaySource::from_clouds(Vec::new()).repeated(5);
        assert!(s.next_frame().unwrap().is_none());
    }

    #[test]
    fn recording_source_tees_frames_and_writes_the_manifest_at_eos() {
        let dir = std::env::temp_dir().join("splitpoint_recording_source");
        let _ = std::fs::remove_dir_all(&dir);
        let clouds = vec![cloud_of(1), cloud_of(2)];
        let inner = Box::new(ReplaySource::from_clouds(clouds.clone()));
        let mut rec = RecordingSource::new(inner, &dir).unwrap();
        assert_eq!(rec.len_hint(), Some(2));
        let mut passed = Vec::new();
        while let Some(f) = rec.next_frame().unwrap() {
            passed.push(f.cloud);
        }
        assert_eq!(passed.len(), 2, "frames pass through unchanged");
        assert_eq!(passed[0].points, clouds[0].points);

        // EOS wrote the manifest: the corpus replays bit-exactly
        let mut replay = RecordedSource::open(&dir).unwrap();
        assert_eq!(replay.len_hint(), Some(2));
        let f0 = replay.next_frame().unwrap().unwrap();
        assert_eq!(f0.cloud.points, clouds[0].points);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
