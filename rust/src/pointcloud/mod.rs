//! Point-cloud substrate: frame types, synthetic KITTI-like scene
//! generation, and a reader for real KITTI velodyne `.bin` files.
//!
//! Substitution (DESIGN.md §3): the paper evaluates on KITTI scans captured
//! by a Velodyne HDL-64E; this environment has no dataset access, so
//! [`scene`] synthesizes scenes with KITTI-like statistics (ground plane,
//! boxy vehicles/pedestrians/cyclists, radial ring sampling with
//! range-dependent density). Every measured quantity in the paper's
//! evaluation depends on the cloud only through point count and voxel
//! occupancy, which the generator calibrates to the dataset's range.

pub mod kitti;
pub mod scene;

/// One LiDAR return: metric xyz + reflectance intensity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub intensity: f32,
}

/// A single LiDAR sweep from one sensor.
#[derive(Debug, Clone, Default)]
pub struct PointCloud {
    pub points: Vec<Point>,
}

impl PointCloud {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Wire size if shipped raw (the paper's Fig 8 "input point cloud data"
    /// baseline): 4 f32 per point, KITTI's on-disk format.
    pub fn size_bytes(&self) -> usize {
        self.points.len() * 16
    }

    /// Flatten to an (N, 4) row-major buffer.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.points.len() * 4);
        for p in &self.points {
            v.extend_from_slice(&[p.x, p.y, p.z, p.intensity]);
        }
        v
    }

    /// Rebuild from an (N, 4) row-major buffer.
    pub fn from_flat(data: &[f32]) -> PointCloud {
        assert_eq!(data.len() % 4, 0, "flat cloud length must be 4N");
        PointCloud {
            points: data
                .chunks_exact(4)
                .map(|c| Point {
                    x: c[0],
                    y: c[1],
                    z: c[2],
                    intensity: c[3],
                })
                .collect(),
        }
    }

    /// As a rust [`crate::Tensor`] for the wire codec (raw-offload split).
    pub fn to_tensor(&self) -> crate::Tensor {
        crate::Tensor::from_vec(&[self.points.len(), 4], self.to_flat())
            .expect("flat cloud is always consistent")
    }
}

/// A frame: one cloud plus provenance (sensor id, sequence number).
#[derive(Debug, Clone)]
pub struct Frame {
    pub sensor_id: u32,
    pub seq: u64,
    pub cloud: PointCloud,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_roundtrip() {
        let pc = PointCloud {
            points: vec![
                Point { x: 1.0, y: 2.0, z: 3.0, intensity: 0.5 },
                Point { x: -1.0, y: 0.0, z: 0.25, intensity: 0.0 },
            ],
        };
        let back = PointCloud::from_flat(&pc.to_flat());
        assert_eq!(back.points, pc.points);
        assert_eq!(pc.size_bytes(), 32);
    }

    #[test]
    fn tensor_shape() {
        let pc = PointCloud::from_flat(&[0.0; 40]);
        assert_eq!(pc.to_tensor().shape(), &[10, 4]);
    }
}
