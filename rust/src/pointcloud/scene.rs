//! Synthetic KITTI-like LiDAR scene generator.
//!
//! Generates scenes in the front-camera FoV wedge that the model's voxel
//! grid covers: a ground plane, roadside clutter, and N objects (cars /
//! pedestrians / cyclists) as point-sampled boxes, swept by a radial ring
//! pattern whose return density falls off with range like a spinning
//! LiDAR's. Produces 15–40 k in-range points per scene, matching the
//! KITTI-cropped-to-FoV regime the paper's numbers come from.

use crate::util::rng::Rng;

use super::{Frame, FrameSource, Point, PointCloud};

/// Object class priors (l, w, h in metres) — KITTI metric means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectClass {
    Car,
    Pedestrian,
    Cyclist,
}

impl ObjectClass {
    pub fn dims(self) -> (f64, f64, f64) {
        match self {
            ObjectClass::Car => (3.9, 1.6, 1.56),
            ObjectClass::Pedestrian => (0.8, 0.6, 1.73),
            ObjectClass::Cyclist => (1.76, 0.6, 1.73),
        }
    }

    pub fn index(self) -> usize {
        match self {
            ObjectClass::Car => 0,
            ObjectClass::Pedestrian => 1,
            ObjectClass::Cyclist => 2,
        }
    }
}

/// Ground-truth box of a placed object: (cx, cy, cz, l, w, h, ry).
#[derive(Debug, Clone, Copy)]
pub struct GtBox {
    pub class: ObjectClass,
    pub center: [f64; 3],
    pub dims: [f64; 3],
    pub ry: f64,
}

impl GtBox {
    pub fn as_array(&self) -> [f32; 7] {
        [
            self.center[0] as f32,
            self.center[1] as f32,
            self.center[2] as f32,
            self.dims[0] as f32,
            self.dims[1] as f32,
            self.dims[2] as f32,
            self.ry as f32,
        ]
    }
}

/// Scene generation parameters.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    /// metric extent matching the model grid (DESIGN.md §3)
    pub x_range: (f64, f64),
    pub y_range: (f64, f64),
    pub z_range: (f64, f64),
    /// objects per scene (uniform in this range)
    pub objects: (usize, usize),
    /// LiDAR elevation rings intersecting the FoV
    pub rings: usize,
    /// azimuth step in degrees (0.2° ≈ 10 Hz HDL-64E)
    pub azimuth_step_deg: f64,
    /// per-return dropout probability
    pub dropout: f64,
    /// gaussian range noise σ in metres
    pub range_noise: f64,
    /// lateral beam jitter σ in metres (spreads returns across voxels the
    /// way real beam divergence + vehicle vibration does; calibrates the
    /// voxels-per-point ratio to the KITTI regime — DESIGN.md §3)
    pub xy_noise: f64,
}

impl Default for SceneConfig {
    fn default() -> Self {
        SceneConfig {
            x_range: (0.0, 46.08),
            y_range: (-23.04, 23.04),
            z_range: (-3.0, 1.0),
            objects: (6, 16),
            rings: 64,
            azimuth_step_deg: 0.30,
            dropout: 0.30,
            range_noise: 0.015,
            xy_noise: 0.30,
        }
    }
}

/// A generated scene: the cloud plus its ground truth.
#[derive(Debug, Clone)]
pub struct Scene {
    pub cloud: PointCloud,
    pub boxes: Vec<GtBox>,
}

/// Deterministic scene generator.
pub struct SceneGenerator {
    cfg: SceneConfig,
    rng: Rng,
}

impl SceneGenerator {
    pub fn new(cfg: SceneConfig, seed: u64) -> SceneGenerator {
        SceneGenerator {
            cfg,
            rng: Rng::new(seed),
        }
    }

    pub fn with_seed(seed: u64) -> SceneGenerator {
        Self::new(SceneConfig::default(), seed)
    }

    /// Generate the next scene in the stream.
    pub fn generate(&mut self) -> Scene {
        let cfg = self.cfg.clone();
        let rng = &mut self.rng;

        // ---- place objects on the ground, non-overlapping-ish
        let n_obj = rng.range(cfg.objects.0 as i64, cfg.objects.1 as i64) as usize;
        let mut boxes: Vec<GtBox> = Vec::with_capacity(n_obj);
        let classes = [
            ObjectClass::Car,
            ObjectClass::Car, // cars dominate KITTI
            ObjectClass::Car,
            ObjectClass::Pedestrian,
            ObjectClass::Cyclist,
        ];
        'place: for _ in 0..n_obj * 4 {
            if boxes.len() == n_obj {
                break;
            }
            let class = *rng.pick(&classes);
            let (l, w, h) = class.dims();
            let l = l * rng.uniform(0.85, 1.2);
            let w = w * rng.uniform(0.85, 1.2);
            let h = h * rng.uniform(0.9, 1.15);
            let cx = rng.uniform(cfg.x_range.0 + 4.0, cfg.x_range.1 - 2.0);
            let cy = rng.uniform(cfg.y_range.0 + 2.0, cfg.y_range.1 - 2.0);
            let ground = ground_z(cx, cy);
            let b = GtBox {
                class,
                center: [cx, cy, ground + h / 2.0],
                dims: [l, w, h],
                ry: rng.uniform(-std::f64::consts::PI, std::f64::consts::PI),
            };
            for other in &boxes {
                let dx = other.center[0] - b.center[0];
                let dy = other.center[1] - b.center[1];
                if (dx * dx + dy * dy).sqrt() < (b.dims[0] + other.dims[0]) / 2.0 + 0.5 {
                    continue 'place;
                }
            }
            boxes.push(b);
        }

        // ---- radial LiDAR sweep over ground + objects + clutter
        let mut points = Vec::with_capacity(30_000);
        let max_range = (cfg.x_range.1.powi(2) + cfg.y_range.1.powi(2)).sqrt();
        // front FoV wedge only (KITTI camera crop): azimuth in [-45°, 45°]
        let az_lo = -std::f64::consts::FRAC_PI_4;
        let az_hi = std::f64::consts::FRAC_PI_4;
        let az_steps =
            ((az_hi - az_lo) / cfg.azimuth_step_deg.to_radians()).round() as usize;

        // clutter poles/walls
        let n_clutter = rng.range(14, 30) as usize;
        let clutter: Vec<(f64, f64, f64, f64)> = (0..n_clutter)
            .map(|_| {
                (
                    rng.uniform(cfg.x_range.0 + 2.0, cfg.x_range.1),
                    rng.uniform(cfg.y_range.0, cfg.y_range.1),
                    rng.uniform(0.3, 1.2),          // radius
                    rng.uniform(0.8, 3.5),          // height
                )
            })
            .collect();

        for ring in 0..cfg.rings {
            // elevation from -24° (ground near sensor) to +2°
            let elev = -24.0 + 26.0 * (ring as f64 / cfg.rings as f64);
            let elev = elev.to_radians();
            for s in 0..az_steps {
                if rng.chance(cfg.dropout) {
                    continue;
                }
                let az = az_lo + (az_hi - az_lo) * (s as f64 / az_steps as f64);
                // cast the ray: nearest hit among ground / objects / clutter
                let dir = [az.cos() * elev.cos(), az.sin() * elev.cos(), elev.sin()];
                let mut best_t = f64::INFINITY;
                let mut best_int = 0.0f64;

                // Frames: model frame has the road at z ≈ -1.73 and the
                // sensor mounted 1.73 m above it, i.e. at the origin. Rays
                // start at (0,0,0); a hit at parameter t is simply dir·t.
                if dir[2] < -1e-6 {
                    let t = ground_z(0.0, 0.0) / dir[2]; // -1.73 / dir_z
                    if t > 0.5 && t < max_range {
                        best_t = t;
                        best_int = 0.18;
                    }
                }
                // objects: coarse ray-box via sampling along the ray
                for b in &boxes {
                    if let Some(t) = ray_box(&dir, b) {
                        if t < best_t {
                            best_t = t;
                            best_int = match b.class {
                                ObjectClass::Car => 0.55,
                                ObjectClass::Pedestrian => 0.35,
                                ObjectClass::Cyclist => 0.4,
                            };
                        }
                    }
                }
                // clutter cylinders
                for &(cx, cy, r, h) in &clutter {
                    if let Some(t) = ray_cylinder(&dir, cx, cy, r, h) {
                        if t < best_t {
                            best_t = t;
                            best_int = 0.3;
                        }
                    }
                }

                if best_t.is_finite() {
                    let t = best_t + rng.normal_scaled(0.0, cfg.range_noise);
                    let x = dir[0] * t + rng.normal_scaled(0.0, cfg.xy_noise);
                    let y = dir[1] * t + rng.normal_scaled(0.0, cfg.xy_noise);
                    let z = dir[2] * t; // sensor at the model-frame origin
                    let intensity =
                        (best_int + rng.normal_scaled(0.0, 0.05)).clamp(0.0, 1.0);
                    // clip to the model's range
                    if x >= cfg.x_range.0
                        && x < cfg.x_range.1
                        && y >= cfg.y_range.0
                        && y < cfg.y_range.1
                        && z >= cfg.z_range.0
                        && z < cfg.z_range.1
                    {
                        points.push(Point {
                            x: x as f32,
                            y: y as f32,
                            z: z as f32,
                            intensity: intensity as f32,
                        });
                    }
                }
            }
        }

        Scene {
            cloud: PointCloud { points },
            boxes,
        }
    }

}

/// [`FrameSource`] over the synthetic generator: the default session
/// input, yielding `frames` scenes from a seeded stream (or unbounded with
/// [`SceneSource::unbounded`] for long-running soak sessions).
pub struct SceneSource {
    gen: SceneGenerator,
    seed: u64,
    seq: u64,
    remaining: Option<usize>,
}

impl SceneSource {
    /// A finite stream of `frames` scenes from `seed`.
    pub fn new(seed: u64, frames: usize) -> SceneSource {
        SceneSource {
            gen: SceneGenerator::with_seed(seed),
            seed,
            seq: 0,
            remaining: Some(frames),
        }
    }

    /// An endless scene stream (bound it with the session's own limits).
    pub fn unbounded(seed: u64) -> SceneSource {
        SceneSource {
            remaining: None,
            ..SceneSource::new(seed, 0)
        }
    }
}

impl FrameSource for SceneSource {
    fn next_frame(&mut self) -> anyhow::Result<Option<Frame>> {
        if let Some(n) = self.remaining {
            if n == 0 {
                return Ok(None);
            }
            self.remaining = Some(n - 1);
        }
        let seq = self.seq;
        self.seq += 1;
        Ok(Some(Frame {
            sensor_id: 0,
            seq,
            cloud: self.gen.generate().cloud,
        }))
    }

    fn len_hint(&self) -> Option<usize> {
        self.remaining
    }

    fn describe(&self) -> String {
        match self.remaining {
            Some(_) => format!("synthetic scenes (seed {})", self.seed),
            None => format!("synthetic scenes (seed {}, unbounded)", self.seed),
        }
    }
}

/// Road height at (x, y): gentle slope away from the sensor.
fn ground_z(x: f64, _y: f64) -> f64 {
    -1.73 + 0.004 * x
}

/// Ray–(rotated box) intersection. Ray origin is the sensor at the
/// model-frame origin; boxes are given in the model frame.
fn ray_box(dir: &[f64; 3], b: &GtBox) -> Option<f64> {
    // transform the ray into the box frame: translate the sensor into box
    // coordinates, then rotate by -ry around z
    let (s, c) = (-b.ry).sin_cos();
    let ox = -b.center[0];
    let oy = -b.center[1];
    let oz = -b.center[2]; // sensor z in model frame = 0
    let o = [c * ox - s * oy, s * ox + c * oy, oz];
    let d = [c * dir[0] - s * dir[1], s * dir[0] + c * dir[1], dir[2]];

    let half = [b.dims[0] / 2.0, b.dims[1] / 2.0, b.dims[2] / 2.0];
    let mut tmin = 0.0f64;
    let mut tmax = f64::INFINITY;
    for i in 0..3 {
        if d[i].abs() < 1e-12 {
            if o[i].abs() > half[i] {
                return None;
            }
            continue;
        }
        let inv = 1.0 / d[i];
        let (t1, t2) = ((-half[i] - o[i]) * inv, (half[i] - o[i]) * inv);
        let (t1, t2) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
        tmin = tmin.max(t1);
        tmax = tmax.min(t2);
        if tmin > tmax {
            return None;
        }
    }
    (tmin > 0.3).then_some(tmin)
}

/// Ray–vertical-cylinder intersection (clutter poles).
fn ray_cylinder(dir: &[f64; 3], cx: f64, cy: f64, r: f64, h: f64) -> Option<f64> {
    let (ox, oy) = (-cx, -cy);
    let a = dir[0] * dir[0] + dir[1] * dir[1];
    if a < 1e-12 {
        return None;
    }
    let b = 2.0 * (ox * dir[0] + oy * dir[1]);
    let c = ox * ox + oy * oy - r * r;
    let disc = b * b - 4.0 * a * c;
    if disc < 0.0 {
        return None;
    }
    let t = (-b - disc.sqrt()) / (2.0 * a);
    if t <= 0.3 {
        return None;
    }
    // z extent: pole from the ground (-1.73) up h metres; sensor at z=0
    let z = dir[2] * t;
    (z >= -1.8 && z <= -1.73 + h).then_some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = SceneGenerator::with_seed(3).generate();
        let b = SceneGenerator::with_seed(3).generate();
        assert_eq!(a.cloud.points.len(), b.cloud.points.len());
        assert_eq!(a.cloud.points.first(), b.cloud.points.first());
        assert_ne!(
            a.cloud.points.len(),
            SceneGenerator::with_seed(4).generate().cloud.points.len()
        );
    }

    #[test]
    fn kitti_like_point_count() {
        let mut g = SceneGenerator::with_seed(1);
        for _ in 0..3 {
            let s = g.generate();
            let n = s.cloud.points.len();
            assert!(
                (8_000..120_000).contains(&n),
                "point count {n} out of KITTI-like range"
            );
        }
    }

    #[test]
    fn points_inside_model_range() {
        let cfg = SceneConfig::default();
        let s = SceneGenerator::with_seed(2).generate();
        for p in &s.cloud.points {
            assert!(p.x as f64 >= cfg.x_range.0 && (p.x as f64) < cfg.x_range.1);
            assert!(p.y as f64 >= cfg.y_range.0 && (p.y as f64) < cfg.y_range.1);
            assert!(p.z as f64 >= cfg.z_range.0 && (p.z as f64) < cfg.z_range.1);
            assert!((0.0..=1.0).contains(&(p.intensity as f64)));
        }
    }

    #[test]
    fn scenes_contain_objects_with_returns() {
        let s = SceneGenerator::with_seed(5).generate();
        assert!(!s.boxes.is_empty());
        // at least one object should receive returns: count points inside
        // any gt box (loose axis-aligned check)
        let mut hits = 0;
        for p in &s.cloud.points {
            for b in &s.boxes {
                let dx = (p.x as f64 - b.center[0]).abs();
                let dy = (p.y as f64 - b.center[1]).abs();
                let dz = (p.z as f64 - b.center[2]).abs();
                let r = (b.dims[0].max(b.dims[1])) / 2.0 + 0.2;
                if dx < r && dy < r && dz < b.dims[2] / 2.0 + 0.2 {
                    hits += 1;
                    break;
                }
            }
        }
        assert!(hits > 50, "objects got only {hits} returns");
    }

    #[test]
    fn stream_varies_across_frames() {
        let mut g = SceneGenerator::with_seed(9);
        let a = g.generate();
        let b = g.generate();
        assert_ne!(a.cloud.points.len(), b.cloud.points.len());
    }

    #[test]
    fn scene_source_matches_bare_generator() {
        let mut src = SceneSource::new(21, 2);
        let mut gen = SceneGenerator::with_seed(21);
        for seq in 0..2u64 {
            let f = src.next_frame().unwrap().expect("frame");
            assert_eq!(f.seq, seq);
            assert_eq!(f.cloud.points, gen.generate().cloud.points);
        }
        assert!(src.next_frame().unwrap().is_none());
        assert_eq!(src.len_hint(), Some(0));
    }
}
