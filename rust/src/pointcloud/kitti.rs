//! KITTI velodyne `.bin` I/O.
//!
//! If a user has the real dataset, frames can be fed straight from disk
//! (`--kitti-dir`); the synthetic generator is the default because this
//! environment has no dataset access. The format is the raw one KITTI
//! ships: little-endian f32 quadruples (x, y, z, reflectance).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Point, PointCloud};

/// Read one scan.
pub fn read_bin(path: &Path) -> Result<PointCloud> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 16 != 0 {
        bail!(
            "{}: length {} is not a multiple of 16 (x,y,z,i f32 records)",
            path.display(),
            bytes.len()
        );
    }
    let mut points = Vec::with_capacity(bytes.len() / 16);
    for rec in bytes.chunks_exact(16) {
        let f = |i: usize| f32::from_le_bytes(rec[i * 4..(i + 1) * 4].try_into().unwrap());
        points.push(Point {
            x: f(0),
            y: f(1),
            z: f(2),
            intensity: f(3),
        });
    }
    Ok(PointCloud { points })
}

/// Write one scan (used by tests and the dataset-export tool).
pub fn write_bin(path: &Path, cloud: &PointCloud) -> Result<()> {
    let mut f =
        fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut buf = Vec::with_capacity(cloud.points.len() * 16);
    for p in &cloud.points {
        buf.extend_from_slice(&p.x.to_le_bytes());
        buf.extend_from_slice(&p.y.to_le_bytes());
        buf.extend_from_slice(&p.z.to_le_bytes());
        buf.extend_from_slice(&p.intensity.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Enumerate `.bin` scans in a directory, sorted by name.
pub fn list_scans(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut scans: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "bin"))
        .collect();
    scans.sort();
    Ok(scans)
}

/// Crop a cloud to the model's metric range (KITTI scans cover 360°; the
/// model grid is the front FoV wedge).
pub fn crop_to_range(
    cloud: &PointCloud,
    x: (f64, f64),
    y: (f64, f64),
    z: (f64, f64),
) -> PointCloud {
    PointCloud {
        points: cloud
            .points
            .iter()
            .copied()
            .filter(|p| {
                (p.x as f64) >= x.0
                    && (p.x as f64) < x.1
                    && (p.y as f64) >= y.0
                    && (p.y as f64) < y.1
                    && (p.z as f64) >= z.0
                    && (p.z as f64) < z.1
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("000000.bin");
        let cloud = PointCloud {
            points: vec![
                Point { x: 1.5, y: -2.0, z: 0.25, intensity: 0.9 },
                Point { x: 40.0, y: 10.0, z: -1.0, intensity: 0.1 },
            ],
        };
        write_bin(&path, &cloud).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(back.points, cloud.points);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        fs::write(&path, [0u8; 17]).unwrap();
        assert!(read_bin(&path).is_err());
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn crop_filters() {
        let cloud = PointCloud {
            points: vec![
                Point { x: 5.0, y: 0.0, z: -1.0, intensity: 0.5 },
                Point { x: -5.0, y: 0.0, z: -1.0, intensity: 0.5 }, // behind
                Point { x: 5.0, y: 50.0, z: -1.0, intensity: 0.5 }, // wide
            ],
        };
        let c = crop_to_range(&cloud, (0.0, 46.08), (-23.04, 23.04), (-3.0, 1.0));
        assert_eq!(c.points.len(), 1);
    }

    #[test]
    fn list_scans_sorted() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_list");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for name in ["2.bin", "1.bin", "x.txt"] {
            fs::write(dir.join(name), []).unwrap();
        }
        let scans = list_scans(&dir).unwrap();
        let names: Vec<_> = scans
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap())
            .collect();
        assert_eq!(names, ["1.bin", "2.bin"]);
        fs::remove_dir_all(&dir).unwrap();
    }
}
