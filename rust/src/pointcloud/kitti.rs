//! KITTI velodyne `.bin` I/O.
//!
//! If a user has the real dataset, frames can be fed straight from disk
//! (`--kitti-dir`); the synthetic generator is the default because this
//! environment has no dataset access. The format is the raw one KITTI
//! ships: little-endian f32 quadruples (x, y, z, reflectance).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Frame, FrameSource, Point, PointCloud};

/// Read one scan.
pub fn read_bin(path: &Path) -> Result<PointCloud> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 16 != 0 {
        bail!(
            "{}: length {} is not a multiple of 16 (x,y,z,i f32 records)",
            path.display(),
            bytes.len()
        );
    }
    let mut points = Vec::with_capacity(bytes.len() / 16);
    for rec in bytes.chunks_exact(16) {
        let f = |i: usize| f32::from_le_bytes(rec[i * 4..(i + 1) * 4].try_into().unwrap());
        points.push(Point {
            x: f(0),
            y: f(1),
            z: f(2),
            intensity: f(3),
        });
    }
    Ok(PointCloud { points })
}

/// Write one scan (used by tests and the dataset-export tool).
pub fn write_bin(path: &Path, cloud: &PointCloud) -> Result<()> {
    let mut f =
        fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut buf = Vec::with_capacity(cloud.points.len() * 16);
    for p in &cloud.points {
        buf.extend_from_slice(&p.x.to_le_bytes());
        buf.extend_from_slice(&p.y.to_le_bytes());
        buf.extend_from_slice(&p.z.to_le_bytes());
        buf.extend_from_slice(&p.intensity.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Enumerate `.bin` scans in a directory, sorted by name.
pub fn list_scans(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut scans: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "bin"))
        .collect();
    scans.sort();
    Ok(scans)
}

/// Crop a cloud to the model's metric range (KITTI scans cover 360°; the
/// model grid is the front FoV wedge).
pub fn crop_to_range(
    cloud: &PointCloud,
    x: (f64, f64),
    y: (f64, f64),
    z: (f64, f64),
) -> PointCloud {
    PointCloud {
        points: cloud
            .points
            .iter()
            .copied()
            .filter(|p| {
                (p.x as f64) >= x.0
                    && (p.x as f64) < x.1
                    && (p.y as f64) >= y.0
                    && (p.y as f64) < y.1
                    && (p.z as f64) >= z.0
                    && (p.z as f64) < z.1
            })
            .collect(),
    }
}

/// [`FrameSource`] over a directory of KITTI velodyne `.bin` scans:
/// streams them in filename order, reading each file lazily so a bounded
/// consumer (the staged pipeline's input queue) throttles disk I/O.
///
/// Scans are fed as-is by default; [`KittiSource::with_crop`] pre-clips to
/// the model's metric range (the voxelizer drops out-of-range points
/// anyway, but cropping shrinks the raw-offload wire).
pub struct KittiSource {
    dir: PathBuf,
    scans: Vec<PathBuf>,
    next: usize,
    limit: Option<usize>,
    crop: Option<((f64, f64), (f64, f64), (f64, f64))>,
}

impl KittiSource {
    /// Open a scan directory; errors when it holds no `.bin` files.
    pub fn open(dir: &Path) -> Result<KittiSource> {
        let scans = list_scans(dir)?;
        if scans.is_empty() {
            bail!("{}: no .bin scans found", dir.display());
        }
        Ok(KittiSource {
            dir: dir.to_path_buf(),
            scans,
            next: 0,
            limit: None,
            crop: None,
        })
    }

    /// Cap the stream at `n` scans.
    pub fn limit(mut self, n: usize) -> KittiSource {
        self.limit = Some(n);
        self
    }

    /// Pre-crop every scan to a metric range (see [`crop_to_range`]).
    pub fn with_crop(
        mut self,
        x: (f64, f64),
        y: (f64, f64),
        z: (f64, f64),
    ) -> KittiSource {
        self.crop = Some((x, y, z));
        self
    }

    fn total(&self) -> usize {
        self.limit.map_or(self.scans.len(), |l| l.min(self.scans.len()))
    }
}

impl FrameSource for KittiSource {
    fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.next >= self.total() {
            return Ok(None);
        }
        let path = &self.scans[self.next];
        let mut cloud = read_bin(path)?;
        if let Some((x, y, z)) = self.crop {
            cloud = crop_to_range(&cloud, x, y, z);
        }
        let seq = self.next as u64;
        self.next += 1;
        Ok(Some(Frame {
            sensor_id: 0,
            seq,
            cloud,
        }))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.total() - self.next.min(self.total()))
    }

    fn describe(&self) -> String {
        format!("kitti:{} ({} scan(s))", self.dir.display(), self.total())
    }
}

// ------------------------------------------------------ replay corpora

/// Manifest filename of a recorded replay corpus.
pub const CORPUS_MANIFEST: &str = "manifest.json";
/// Schema tag inside the corpus manifest.
pub const CORPUS_SCHEMA: &str = "splitpoint-replay-corpus/v1";

/// Write a streamed session back to disk as a replay corpus — the inverse
/// of [`RecordedSource`]: one KITTI-format `.bin` per frame (so the
/// directory also reads back through a plain [`KittiSource`]) plus a
/// `manifest.json` preserving per-frame provenance (sensor id, source
/// sequence number, point count) that the raw filename ordering loses.
///
/// `.bin` scans are bit-exact f32 records, so record → replay is lossless
/// and detections over the replayed corpus are byte-identical to the
/// original stream (enforced by `rust/tests/session.rs` and the CI
/// `replay-corpus` lane).
pub struct RecorderSink {
    dir: PathBuf,
    entries: Vec<CorpusEntry>,
    finished: bool,
}

#[derive(Debug, Clone)]
struct CorpusEntry {
    file: String,
    sensor_id: u32,
    seq: u64,
    points: usize,
}

impl RecorderSink {
    /// Create the corpus directory. A directory holding a *previous
    /// recording* (identified by its [`CORPUS_MANIFEST`]) is cleared
    /// first — re-recording a shorter stream must not leave orphaned
    /// scans that the new manifest no longer lists, or the documented
    /// plain-[`KittiSource`] readback would silently mix recordings. A
    /// directory containing `.bin` files but **no** manifest is refused:
    /// it is someone's dataset, not a corpus, and sweeping it would
    /// destroy data (`--sink record:` pointed at a KITTI scan directory).
    pub fn create(dir: &Path) -> Result<RecorderSink> {
        fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let scans = list_scans(dir)?;
        if !scans.is_empty() {
            if !dir.join(CORPUS_MANIFEST).is_file() {
                bail!(
                    "{}: holds {} .bin file(s) but no {CORPUS_MANIFEST} — refusing to \
                     record over what looks like a dataset, not a previous recording \
                     (pick an empty directory)",
                    dir.display(),
                    scans.len()
                );
            }
            for path in scans {
                fs::remove_file(&path)
                    .with_context(|| format!("clearing stale {}", path.display()))?;
            }
            fs::remove_file(dir.join(CORPUS_MANIFEST))
                .with_context(|| format!("clearing stale manifest in {}", dir.display()))?;
        }
        Ok(RecorderSink {
            dir: dir.to_path_buf(),
            entries: Vec::new(),
            finished: false,
        })
    }

    /// Append one frame to the corpus: writes `<index>.bin` (dense
    /// record-order index, so filename order replays in stream order) and
    /// remembers its provenance for the manifest.
    pub fn record(&mut self, frame: &Frame) -> Result<()> {
        let file = format!("{:06}.bin", self.entries.len());
        write_bin(&self.dir.join(&file), &frame.cloud)?;
        self.entries.push(CorpusEntry {
            file,
            sensor_id: frame.sensor_id,
            seq: frame.seq,
            points: frame.cloud.len(),
        });
        self.finished = false;
        Ok(())
    }

    pub fn frames_recorded(&self) -> usize {
        self.entries.len()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Write the manifest. Idempotent; also invoked on drop (best-effort)
    /// so a recording session that forgets to finish still leaves a
    /// replayable corpus.
    pub fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        use crate::util::json::Value;
        let frames: Vec<Value> = self
            .entries
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("file", Value::str(&e.file)),
                    ("sensor_id", Value::num(e.sensor_id as f64)),
                    ("seq", Value::num(e.seq as f64)),
                    ("points", Value::num(e.points as f64)),
                ])
            })
            .collect();
        let manifest = Value::obj(vec![
            ("schema", Value::str(CORPUS_SCHEMA)),
            ("frames", Value::arr(frames)),
        ]);
        let path = self.dir.join(CORPUS_MANIFEST);
        fs::write(&path, manifest.pretty() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for RecorderSink {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

/// [`FrameSource`] over a recorded corpus directory (the output of
/// [`RecorderSink`]): streams the manifest's frames in record order,
/// reading each `.bin` lazily, with the original sensor ids and sequence
/// numbers restored — the `replay:<dir>` CLI spec.
pub struct RecordedSource {
    dir: PathBuf,
    entries: Vec<CorpusEntry>,
    next: usize,
    limit: Option<usize>,
}

impl RecordedSource {
    /// Open a corpus directory; errors when the manifest is missing,
    /// unparseable, or carries the wrong schema.
    pub fn open(dir: &Path) -> Result<RecordedSource> {
        use crate::util::json::{parse, Value};
        let path = dir.join(CORPUS_MANIFEST);
        let text =
            fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let doc = parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        match doc.get("schema").and_then(Value::as_str) {
            Some(CORPUS_SCHEMA) => {}
            other => bail!(
                "{}: schema {:?}, want {:?}",
                path.display(),
                other,
                CORPUS_SCHEMA
            ),
        }
        let frames = doc
            .get("frames")
            .and_then(Value::as_arr)
            .with_context(|| format!("{}: manifest has no frames array", path.display()))?;
        let entries = frames
            .iter()
            .enumerate()
            .map(|(i, f)| -> Result<CorpusEntry> {
                Ok(CorpusEntry {
                    file: f
                        .get("file")
                        .and_then(Value::as_str)
                        .with_context(|| format!("frame {i}: missing file"))?
                        .to_string(),
                    sensor_id: f
                        .get("sensor_id")
                        .and_then(Value::as_usize)
                        .unwrap_or(0) as u32,
                    seq: f.get("seq").and_then(Value::as_usize).unwrap_or(i) as u64,
                    points: f.get("points").and_then(Value::as_usize).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RecordedSource {
            dir: dir.to_path_buf(),
            entries,
            next: 0,
            limit: None,
        })
    }

    /// Cap the replay at `n` frames.
    pub fn limit(mut self, n: usize) -> RecordedSource {
        self.limit = Some(n);
        self
    }

    fn total(&self) -> usize {
        self.limit
            .map_or(self.entries.len(), |l| l.min(self.entries.len()))
    }
}

impl FrameSource for RecordedSource {
    fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.next >= self.total() {
            return Ok(None);
        }
        let e = &self.entries[self.next];
        let cloud = read_bin(&self.dir.join(&e.file))?;
        self.next += 1;
        Ok(Some(Frame {
            sensor_id: e.sensor_id,
            seq: e.seq,
            cloud,
        }))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.total() - self.next.min(self.total()))
    }

    fn describe(&self) -> String {
        format!(
            "replay:{} ({} recorded frame(s))",
            self.dir.display(),
            self.total()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("000000.bin");
        let cloud = PointCloud {
            points: vec![
                Point { x: 1.5, y: -2.0, z: 0.25, intensity: 0.9 },
                Point { x: 40.0, y: 10.0, z: -1.0, intensity: 0.1 },
            ],
        };
        write_bin(&path, &cloud).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(back.points, cloud.points);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        fs::write(&path, [0u8; 17]).unwrap();
        assert!(read_bin(&path).is_err());
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn crop_filters() {
        let cloud = PointCloud {
            points: vec![
                Point { x: 5.0, y: 0.0, z: -1.0, intensity: 0.5 },
                Point { x: -5.0, y: 0.0, z: -1.0, intensity: 0.5 }, // behind
                Point { x: 5.0, y: 50.0, z: -1.0, intensity: 0.5 }, // wide
            ],
        };
        let c = crop_to_range(&cloud, (0.0, 46.08), (-23.04, 23.04), (-3.0, 1.0));
        assert_eq!(c.points.len(), 1);
    }

    #[test]
    fn kitti_source_streams_in_name_order_with_limit() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_source");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for (name, n) in [("b.bin", 2usize), ("a.bin", 1), ("c.bin", 3)] {
            let p = Point { x: 1.0, y: 0.0, z: 0.0, intensity: 0.5 };
            let cloud = PointCloud { points: vec![p; n] };
            write_bin(&dir.join(name), &cloud).unwrap();
        }
        let mut src = KittiSource::open(&dir).unwrap();
        assert_eq!(src.len_hint(), Some(3));
        let sizes: Vec<usize> = std::iter::from_fn(|| src.next_frame().unwrap())
            .map(|f| f.cloud.len())
            .collect();
        assert_eq!(sizes, [1, 2, 3], "filename order");

        let mut limited = KittiSource::open(&dir).unwrap().limit(2);
        assert_eq!(limited.len_hint(), Some(2));
        assert!(limited.next_frame().unwrap().is_some());
        assert!(limited.next_frame().unwrap().is_some());
        assert!(limited.next_frame().unwrap().is_none());

        assert!(KittiSource::open(&dir.join("missing")).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kitti_source_crop_applies() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_source_crop");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let cloud = PointCloud {
            points: vec![
                Point { x: 5.0, y: 0.0, z: -1.0, intensity: 0.5 },
                Point { x: -5.0, y: 0.0, z: -1.0, intensity: 0.5 },
            ],
        };
        write_bin(&dir.join("0.bin"), &cloud).unwrap();
        let mut src = KittiSource::open(&dir)
            .unwrap()
            .with_crop((0.0, 46.08), (-23.04, 23.04), (-3.0, 1.0));
        let f = src.next_frame().unwrap().unwrap();
        assert_eq!(f.cloud.len(), 1, "behind-sensor point cropped");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recorder_corpus_roundtrips_with_provenance() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_corpus");
        let _ = fs::remove_dir_all(&dir);
        let clouds = [
            PointCloud {
                points: vec![Point { x: 1.5, y: -2.0, z: 0.25, intensity: 0.9 }],
            },
            PointCloud {
                points: vec![
                    Point { x: 40.0, y: 10.0, z: -1.0, intensity: 0.1 },
                    Point { x: 0.5, y: 0.0, z: 0.0, intensity: 1.0 },
                ],
            },
        ];
        let mut sink = RecorderSink::create(&dir).unwrap();
        // out-of-order sensor/seq tags must survive the roundtrip
        sink.record(&Frame { sensor_id: 2, seq: 7, cloud: clouds[0].clone() }).unwrap();
        sink.record(&Frame { sensor_id: 0, seq: 3, cloud: clouds[1].clone() }).unwrap();
        assert_eq!(sink.frames_recorded(), 2);
        sink.finish().unwrap();
        drop(sink);

        let mut src = RecordedSource::open(&dir).unwrap();
        assert_eq!(src.len_hint(), Some(2));
        let a = src.next_frame().unwrap().unwrap();
        assert_eq!((a.sensor_id, a.seq), (2, 7));
        assert_eq!(a.cloud.points, clouds[0].points, "bit-exact replay");
        let b = src.next_frame().unwrap().unwrap();
        assert_eq!((b.sensor_id, b.seq), (0, 3));
        assert_eq!(b.cloud.points, clouds[1].points);
        assert!(src.next_frame().unwrap().is_none());

        // the corpus is plain kitti .bin files too: KittiSource reads it
        // in the same (record) order, just without the provenance tags
        let mut plain = KittiSource::open(&dir).unwrap();
        assert_eq!(plain.next_frame().unwrap().unwrap().cloud.points, clouds[0].points);

        // limit caps the replay
        let mut limited = RecordedSource::open(&dir).unwrap().limit(1);
        assert_eq!(limited.len_hint(), Some(1));
        assert!(limited.next_frame().unwrap().is_some());
        assert!(limited.next_frame().unwrap().is_none());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recorder_create_clears_a_previous_recording() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_corpus_rerecord");
        let _ = fs::remove_dir_all(&dir);
        let p = Point { x: 1.0, y: 0.0, z: 0.0, intensity: 0.5 };
        let frame_of = |n: usize| Frame {
            sensor_id: 0,
            seq: n as u64,
            cloud: PointCloud { points: vec![p; n + 1] },
        };
        // first recording: 3 frames
        let mut sink = RecorderSink::create(&dir).unwrap();
        for i in 0..3 {
            sink.record(&frame_of(i)).unwrap();
        }
        sink.finish().unwrap();
        drop(sink);
        // re-record a SHORTER stream into the same directory
        let mut sink = RecorderSink::create(&dir).unwrap();
        sink.record(&frame_of(9)).unwrap();
        sink.finish().unwrap();
        drop(sink);
        // both readback paths agree: one frame, no stale scans
        let mut replay = RecordedSource::open(&dir).unwrap();
        assert_eq!(replay.len_hint(), Some(1));
        assert_eq!(replay.next_frame().unwrap().unwrap().cloud.len(), 10);
        let plain = KittiSource::open(&dir).unwrap();
        assert_eq!(plain.len_hint(), Some(1), "stale .bin scans swept");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recorder_refuses_a_bin_directory_without_a_manifest() {
        // a .bin directory with no manifest is a dataset, not a corpus —
        // recording over it must fail instead of deleting the scans
        let dir = std::env::temp_dir().join("splitpoint_kitti_recorder_guard");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let p = Point { x: 1.0, y: 0.0, z: 0.0, intensity: 0.5 };
        write_bin(&dir.join("000000.bin"), &PointCloud { points: vec![p] }).unwrap();
        let err = RecorderSink::create(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("refusing"), "got: {err:#}");
        assert!(dir.join("000000.bin").is_file(), "the dataset scan survives");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recorded_source_rejects_missing_or_bad_manifest() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_corpus_bad");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(RecordedSource::open(&dir).is_err(), "no manifest");
        fs::write(dir.join(CORPUS_MANIFEST), "{\"schema\": \"other/v9\"}").unwrap();
        assert!(RecordedSource::open(&dir).is_err(), "wrong schema");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn list_scans_sorted() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_list");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for name in ["2.bin", "1.bin", "x.txt"] {
            fs::write(dir.join(name), []).unwrap();
        }
        let scans = list_scans(&dir).unwrap();
        let names: Vec<_> = scans
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap())
            .collect();
        assert_eq!(names, ["1.bin", "2.bin"]);
        fs::remove_dir_all(&dir).unwrap();
    }
}
