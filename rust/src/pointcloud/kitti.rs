//! KITTI velodyne `.bin` I/O.
//!
//! If a user has the real dataset, frames can be fed straight from disk
//! (`--kitti-dir`); the synthetic generator is the default because this
//! environment has no dataset access. The format is the raw one KITTI
//! ships: little-endian f32 quadruples (x, y, z, reflectance).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Frame, FrameSource, Point, PointCloud};

/// Read one scan.
pub fn read_bin(path: &Path) -> Result<PointCloud> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 16 != 0 {
        bail!(
            "{}: length {} is not a multiple of 16 (x,y,z,i f32 records)",
            path.display(),
            bytes.len()
        );
    }
    let mut points = Vec::with_capacity(bytes.len() / 16);
    for rec in bytes.chunks_exact(16) {
        let f = |i: usize| f32::from_le_bytes(rec[i * 4..(i + 1) * 4].try_into().unwrap());
        points.push(Point {
            x: f(0),
            y: f(1),
            z: f(2),
            intensity: f(3),
        });
    }
    Ok(PointCloud { points })
}

/// Write one scan (used by tests and the dataset-export tool).
pub fn write_bin(path: &Path, cloud: &PointCloud) -> Result<()> {
    let mut f =
        fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut buf = Vec::with_capacity(cloud.points.len() * 16);
    for p in &cloud.points {
        buf.extend_from_slice(&p.x.to_le_bytes());
        buf.extend_from_slice(&p.y.to_le_bytes());
        buf.extend_from_slice(&p.z.to_le_bytes());
        buf.extend_from_slice(&p.intensity.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Enumerate `.bin` scans in a directory, sorted by name.
pub fn list_scans(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut scans: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "bin"))
        .collect();
    scans.sort();
    Ok(scans)
}

/// Crop a cloud to the model's metric range (KITTI scans cover 360°; the
/// model grid is the front FoV wedge).
pub fn crop_to_range(
    cloud: &PointCloud,
    x: (f64, f64),
    y: (f64, f64),
    z: (f64, f64),
) -> PointCloud {
    PointCloud {
        points: cloud
            .points
            .iter()
            .copied()
            .filter(|p| {
                (p.x as f64) >= x.0
                    && (p.x as f64) < x.1
                    && (p.y as f64) >= y.0
                    && (p.y as f64) < y.1
                    && (p.z as f64) >= z.0
                    && (p.z as f64) < z.1
            })
            .collect(),
    }
}

/// [`FrameSource`] over a directory of KITTI velodyne `.bin` scans:
/// streams them in filename order, reading each file lazily so a bounded
/// consumer (the staged pipeline's input queue) throttles disk I/O.
///
/// Scans are fed as-is by default; [`KittiSource::with_crop`] pre-clips to
/// the model's metric range (the voxelizer drops out-of-range points
/// anyway, but cropping shrinks the raw-offload wire).
pub struct KittiSource {
    dir: PathBuf,
    scans: Vec<PathBuf>,
    next: usize,
    limit: Option<usize>,
    crop: Option<((f64, f64), (f64, f64), (f64, f64))>,
}

impl KittiSource {
    /// Open a scan directory; errors when it holds no `.bin` files.
    pub fn open(dir: &Path) -> Result<KittiSource> {
        let scans = list_scans(dir)?;
        if scans.is_empty() {
            bail!("{}: no .bin scans found", dir.display());
        }
        Ok(KittiSource {
            dir: dir.to_path_buf(),
            scans,
            next: 0,
            limit: None,
            crop: None,
        })
    }

    /// Cap the stream at `n` scans.
    pub fn limit(mut self, n: usize) -> KittiSource {
        self.limit = Some(n);
        self
    }

    /// Pre-crop every scan to a metric range (see [`crop_to_range`]).
    pub fn with_crop(
        mut self,
        x: (f64, f64),
        y: (f64, f64),
        z: (f64, f64),
    ) -> KittiSource {
        self.crop = Some((x, y, z));
        self
    }

    fn total(&self) -> usize {
        self.limit.map_or(self.scans.len(), |l| l.min(self.scans.len()))
    }
}

impl FrameSource for KittiSource {
    fn next_frame(&mut self) -> Result<Option<Frame>> {
        if self.next >= self.total() {
            return Ok(None);
        }
        let path = &self.scans[self.next];
        let mut cloud = read_bin(path)?;
        if let Some((x, y, z)) = self.crop {
            cloud = crop_to_range(&cloud, x, y, z);
        }
        let seq = self.next as u64;
        self.next += 1;
        Ok(Some(Frame {
            sensor_id: 0,
            seq,
            cloud,
        }))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.total() - self.next.min(self.total()))
    }

    fn describe(&self) -> String {
        format!("kitti:{} ({} scan(s))", self.dir.display(), self.total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("000000.bin");
        let cloud = PointCloud {
            points: vec![
                Point { x: 1.5, y: -2.0, z: 0.25, intensity: 0.9 },
                Point { x: 40.0, y: 10.0, z: -1.0, intensity: 0.1 },
            ],
        };
        write_bin(&path, &cloud).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(back.points, cloud.points);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        fs::write(&path, [0u8; 17]).unwrap();
        assert!(read_bin(&path).is_err());
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn crop_filters() {
        let cloud = PointCloud {
            points: vec![
                Point { x: 5.0, y: 0.0, z: -1.0, intensity: 0.5 },
                Point { x: -5.0, y: 0.0, z: -1.0, intensity: 0.5 }, // behind
                Point { x: 5.0, y: 50.0, z: -1.0, intensity: 0.5 }, // wide
            ],
        };
        let c = crop_to_range(&cloud, (0.0, 46.08), (-23.04, 23.04), (-3.0, 1.0));
        assert_eq!(c.points.len(), 1);
    }

    #[test]
    fn kitti_source_streams_in_name_order_with_limit() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_source");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for (name, n) in [("b.bin", 2usize), ("a.bin", 1), ("c.bin", 3)] {
            let p = Point { x: 1.0, y: 0.0, z: 0.0, intensity: 0.5 };
            let cloud = PointCloud { points: vec![p; n] };
            write_bin(&dir.join(name), &cloud).unwrap();
        }
        let mut src = KittiSource::open(&dir).unwrap();
        assert_eq!(src.len_hint(), Some(3));
        let sizes: Vec<usize> = std::iter::from_fn(|| src.next_frame().unwrap())
            .map(|f| f.cloud.len())
            .collect();
        assert_eq!(sizes, [1, 2, 3], "filename order");

        let mut limited = KittiSource::open(&dir).unwrap().limit(2);
        assert_eq!(limited.len_hint(), Some(2));
        assert!(limited.next_frame().unwrap().is_some());
        assert!(limited.next_frame().unwrap().is_some());
        assert!(limited.next_frame().unwrap().is_none());

        assert!(KittiSource::open(&dir.join("missing")).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kitti_source_crop_applies() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_source_crop");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let cloud = PointCloud {
            points: vec![
                Point { x: 5.0, y: 0.0, z: -1.0, intensity: 0.5 },
                Point { x: -5.0, y: 0.0, z: -1.0, intensity: 0.5 },
            ],
        };
        write_bin(&dir.join("0.bin"), &cloud).unwrap();
        let mut src = KittiSource::open(&dir)
            .unwrap()
            .with_crop((0.0, 46.08), (-23.04, 23.04), (-3.0, 1.0));
        let f = src.next_frame().unwrap().unwrap();
        assert_eq!(f.cloud.len(), 1, "behind-sensor point cropped");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn list_scans_sorted() {
        let dir = std::env::temp_dir().join("splitpoint_kitti_list");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        for name in ["2.bin", "1.bin", "x.txt"] {
            fs::write(dir.join(name), []).unwrap();
        }
        let scans = list_scans(&dir).unwrap();
        let names: Vec<_> = scans
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap())
            .collect();
        assert_eq!(names, ["1.bin", "2.bin"]);
        fs::remove_dir_all(&dir).unwrap();
    }
}
