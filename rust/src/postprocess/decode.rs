//! Anchor-delta box decoding (SECOND/OpenPCDet residual coder) and small
//! math helpers shared by the proposal stage.

use crate::model::anchors::Anchor;

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode a 7-dof box from anchor + deltas, with direction correction from
/// the 2-way direction classifier (OpenPCDet's `dir_offset=0` simplified).
///
/// Coder: dx,dy are scaled by the anchor BEV diagonal, dz by anchor height;
/// dl,dw,dh are log-ratios (clamped for numeric safety); dry is additive.
pub fn decode_box(anchor: &Anchor, delta: &[f32], dir_logits: &[f32]) -> [f32; 7] {
    debug_assert_eq!(delta.len(), 7);
    let diag = (anchor.dims[0] * anchor.dims[0] + anchor.dims[1] * anchor.dims[1]).sqrt();
    let cx = anchor.center[0] + delta[0] * diag;
    let cy = anchor.center[1] + delta[1] * diag;
    let cz = anchor.center[2] + delta[2] * anchor.dims[2];
    let clamp = |d: f32| d.clamp(-2.0, 2.0);
    let l = anchor.dims[0] * clamp(delta[3]).exp();
    let w = anchor.dims[1] * clamp(delta[4]).exp();
    let h = anchor.dims[2] * clamp(delta[5]).exp();
    let mut ry = anchor.ry + delta[6];
    // direction classifier picks the pi-flipped orientation
    if dir_logits.len() == 2 && dir_logits[1] > dir_logits[0] {
        ry += std::f32::consts::PI;
    }
    // normalize to (-pi, pi]
    while ry > std::f32::consts::PI {
        ry -= 2.0 * std::f32::consts::PI;
    }
    while ry <= -std::f32::consts::PI {
        ry += 2.0 * std::f32::consts::PI;
    }
    [cx, cy, cz, l, w, h, ry]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn anchor() -> Anchor {
        Anchor {
            center: [10.0, -2.0, -1.0],
            dims: [3.9, 1.6, 1.56],
            ry: 0.0,
            class: 0,
        }
    }

    #[test]
    fn zero_delta_is_identity() {
        let b = decode_box(&anchor(), &[0.0; 7], &[1.0, 0.0]);
        assert_eq!(&b[..3], &[10.0, -2.0, -1.0]);
        assert!((b[3] - 3.9).abs() < 1e-6);
        assert_eq!(b[6], 0.0);
    }

    #[test]
    fn direction_flip() {
        let b = decode_box(&anchor(), &[0.0; 7], &[0.0, 1.0]);
        assert!((b[6].abs() - std::f32::consts::PI).abs() < 1e-6);
    }

    #[test]
    fn translation_scales_with_diagonal() {
        let diag = (3.9f32 * 3.9 + 1.6 * 1.6).sqrt();
        let b = decode_box(&anchor(), &[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], &[1.0, 0.0]);
        assert!((b[0] - (10.0 + diag)).abs() < 1e-5);
    }

    #[test]
    fn size_deltas_clamped() {
        let b = decode_box(&anchor(), &[0.0, 0.0, 0.0, 99.0, -99.0, 0.0, 0.0], &[1.0, 0.0]);
        assert!((b[3] - 3.9 * 2.0f32.exp()).abs() < 1e-3);
        assert!((b[4] - 1.6 * (-2.0f32).exp()).abs() < 1e-4);
        assert!(b[3].is_finite() && b[4] > 0.0);
    }

    #[test]
    fn angle_normalized() {
        let mut a = anchor();
        a.ry = 3.0;
        let b = decode_box(&a, &[0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0], &[0.0, 1.0]);
        assert!(b[6] > -std::f32::consts::PI && b[6] <= std::f32::consts::PI);
    }

    #[test]
    fn sigmoid_range() {
        assert!(sigmoid(-50.0) >= 0.0 && sigmoid(-50.0) < 1e-6);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(50.0) <= 1.0 && sigmoid(50.0) > 1.0 - 1e-6);
    }
}
