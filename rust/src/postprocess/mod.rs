//! Post-processing: the `proposal` pseudo-module (anchor decode + top-K +
//! NMS between DenseHead and RoIHead) and final-prediction assembly.
//!
//! Kept in rust rather than HLO because proposal selection is dynamic-shape
//! (top-K of a score-dependent set); the AOT'd RoI head takes a fixed
//! `num_proposals` box tensor.

pub mod compare;
pub mod decode;
pub mod eval;
pub mod nms;

use anyhow::{bail, Result};

use crate::model::anchors::Anchor;
use crate::model::manifest::ModelConfig;
use crate::tensor::Tensor;

/// A scored, decoded detection box.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub score: f32,
    /// (cx, cy, cz, l, w, h, ry)
    pub boxx: [f32; 7],
    pub class: usize,
}

/// Proposal-stage configuration.
#[derive(Debug, Clone)]
pub struct ProposalConfig {
    pub pre_nms_top_k: usize,
    pub nms_iou: f32,
    pub num_proposals: usize,
}

impl Default for ProposalConfig {
    fn default() -> Self {
        ProposalConfig {
            pre_nms_top_k: 512,
            nms_iou: 0.7,
            num_proposals: 96,
        }
    }
}

/// The `proposal` node: cls/box/dir maps → fixed-K RoI tensor.
pub struct ProposalStage {
    anchors: Vec<Anchor>,
    cfg: ProposalConfig,
}

impl ProposalStage {
    pub fn new(model_cfg: &ModelConfig, cfg: ProposalConfig) -> ProposalStage {
        ProposalStage {
            anchors: crate::model::anchors::generate(model_cfg),
            cfg: ProposalConfig {
                num_proposals: model_cfg.num_proposals,
                ..cfg
            },
        }
    }

    /// cls_logits (A,), box_preds (A, 7), dir_logits (A, 2) → fixed-K RoIs.
    pub fn run(
        &self,
        cls_logits: &Tensor,
        box_preds: &Tensor,
        dir_logits: &Tensor,
    ) -> Result<Proposals> {
        let a = self.anchors.len();
        if cls_logits.numel() != a || box_preds.shape() != [a, 7] {
            bail!(
                "proposal inputs mismatch: cls {:?} box {:?} vs {a} anchors",
                cls_logits.shape(),
                box_preds.shape()
            );
        }

        // 1. score + decode the top pre-NMS candidates
        let mut idx: Vec<usize> = (0..a).collect();
        let scores = cls_logits.data();
        // partial top-K by score (sigmoid is monotone: sort on raw logits)
        let k_pre = self.cfg.pre_nms_top_k.min(a);
        idx.select_nth_unstable_by(k_pre - 1, |&i, &j| {
            scores[j].partial_cmp(&scores[i]).unwrap()
        });
        idx.truncate(k_pre);
        idx.sort_unstable_by(|&i, &j| scores[j].partial_cmp(&scores[i]).unwrap());

        let dets: Vec<Detection> = idx
            .iter()
            .map(|&i| {
                let delta: &[f32] = &box_preds.data()[i * 7..(i + 1) * 7];
                let dir: &[f32] = &dir_logits.data()[i * 2..(i + 1) * 2];
                let anchor = &self.anchors[i];
                Detection {
                    score: decode::sigmoid(scores[i]),
                    boxx: decode::decode_box(anchor, delta, dir),
                    class: anchor.class,
                }
            })
            .collect();

        // 2. BEV rotated NMS
        let keep = nms::nms_bev(&dets, self.cfg.nms_iou, self.cfg.num_proposals);

        // 3. fixed-K roi tensor (pad with a degenerate far-away box with
        //    zero size so RoI pooling gathers nothing for padding slots)
        let k = self.cfg.num_proposals;
        let mut rois = vec![0.0f32; k * 7];
        let mut classes = vec![usize::MAX; k];
        let mut scores = vec![0.0f32; k];
        for (slot, &di) in keep.iter().enumerate().take(k) {
            rois[slot * 7..slot * 7 + 7].copy_from_slice(&dets[di].boxx);
            classes[slot] = dets[di].class;
            scores[slot] = dets[di].score;
        }
        for slot in keep.len()..k {
            rois[slot * 7..slot * 7 + 7]
                .copy_from_slice(&[-1e4, -1e4, -1e4, 0.0, 0.0, 0.0, 0.0]);
        }
        Ok(Proposals {
            rois: Tensor::from_vec(&[k, 7], rois)?,
            classes,
            scores,
        })
    }
}

/// Fixed-K proposal set: the RoI tensor plus per-slot metadata the RoI head
/// doesn't see (class labels ride on the rust side, paper-faithful:
/// OpenPCDet also carries `roi_labels` outside the pooled features).
#[derive(Debug, Clone)]
pub struct Proposals {
    pub rois: Tensor,
    /// per-slot class; `usize::MAX` marks padding slots
    pub classes: Vec<usize>,
    /// first-stage (RPN) scores per slot
    pub scores: Vec<f32>,
}

/// Final predictions from the RoI head outputs.
pub fn assemble_predictions(
    roi_scores: &Tensor,
    roi_boxes: &Tensor,
    classes: &[usize],
    score_threshold: f32,
) -> Vec<Detection> {
    let k = roi_scores.numel();
    let mut out = Vec::new();
    for i in 0..k {
        let score = decode::sigmoid(roi_scores.data()[i]);
        let class = classes.get(i).copied().unwrap_or(0);
        if class == usize::MAX || score < score_threshold {
            continue;
        }
        let b: &[f32] = &roi_boxes.data()[i * 7..(i + 1) * 7];
        out.push(Detection {
            score,
            boxx: [b[0], b[1], b[2], b[3], b[4], b[5], b[6]],
            class,
        });
    }
    out.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::test_manifest;

    fn stage() -> ProposalStage {
        ProposalStage::new(&test_manifest().config, ProposalConfig::default())
    }

    fn inputs(hot: &[usize]) -> (Tensor, Tensor, Tensor) {
        let cfg = test_manifest().config;
        let a = cfg.num_anchors;
        let mut cls = vec![-8.0f32; a];
        for &h in hot {
            cls[h] = 4.0;
        }
        (
            Tensor::from_vec(&[a], cls).unwrap(),
            Tensor::zeros(&[a, 7]),
            Tensor::zeros(&[a, 2]),
        )
    }

    #[test]
    fn output_shape_fixed_k() {
        let s = stage();
        let (cls, boxp, dir) = inputs(&[0, 100, 2000]);
        let p = s.run(&cls, &boxp, &dir).unwrap();
        assert_eq!(p.rois.shape(), &[96, 7]);
        assert_eq!(p.classes.len(), 96);
    }

    #[test]
    fn hot_anchors_become_first_proposals() {
        let s = stage();
        let (cls, boxp, dir) = inputs(&[1200]);
        let p = s.run(&cls, &boxp, &dir).unwrap();
        // the hot anchor decodes to itself under zero deltas
        let a = crate::model::anchors::generate(&test_manifest().config);
        let expect = &a[1200];
        assert!((p.rois.data()[0] - expect.center[0]).abs() < 1e-4);
        assert!((p.rois.data()[1] - expect.center[1]).abs() < 1e-4);
        assert_eq!(p.classes[0], expect.class);
        assert!(p.scores[0] > 0.9);
    }

    #[test]
    fn padding_is_degenerate() {
        // a pre-NMS pool smaller than K forces padding slots
        let s = ProposalStage::new(
            &test_manifest().config,
            ProposalConfig {
                pre_nms_top_k: 10,
                ..ProposalConfig::default()
            },
        );
        let (cls, boxp, dir) = inputs(&[5]);
        let p = s.run(&cls, &boxp, &dir).unwrap();
        // padding slots must be far away with zero size
        let last = &p.rois.data()[95 * 7..96 * 7];
        assert_eq!(last[3], 0.0);
        assert!(last[0] < -9e3);
        assert_eq!(p.classes[95], usize::MAX);
    }

    #[test]
    fn shape_validation() {
        let s = stage();
        let bad = Tensor::zeros(&[7]);
        assert!(s.run(&bad, &Tensor::zeros(&[7, 7]), &Tensor::zeros(&[7, 2])).is_err());
    }

    #[test]
    fn assemble_filters_and_sorts() {
        let scores = Tensor::from_vec(&[3], vec![4.0, -6.0, 1.0]).unwrap();
        let boxes = Tensor::zeros(&[3, 7]);
        let dets = assemble_predictions(&scores, &boxes, &[0, 1, 2], 0.3);
        assert_eq!(dets.len(), 2);
        assert!(dets[0].score >= dets[1].score);
    }
}
