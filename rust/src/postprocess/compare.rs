//! Tolerance-based detection comparison — the eval harness for lossy wire
//! precisions (codec v3) and every lossy direction after them.
//!
//! Bitwise `cmp` on `--dets-out` files is the right gate for exact paths
//! (f32 wire, SIMD, threading, transports), but quantization changes bits
//! by design. This module defines what "the same detections" means under a
//! [`Tolerance`]: per-frame, per-class greedy BEV-IoU matching with score
//! and center epsilons. Every box must find a partner — a missing or extra
//! box is a failure, never a statistic — and NaN anywhere in the inputs is
//! a loud error, not a silent non-match.
//!
//! The CI `codec-accuracy` lane drives this through the `compare-dets`
//! subcommand on serve-edge/serve-server `--dets-out` pairs; the report is
//! machine-readable JSON ([`CompareReport::to_json`]) so lanes can table
//! accuracy against uplink bytes.

use anyhow::{bail, Context, Result};

use super::nms::bev_iou;
use super::Detection;
use crate::util::json::Value;

/// Matching tolerances. [`Tolerance::exact`] (all zero, IoU 1) accepts
/// only bit-identical detection sets — useful as a self-check that the
/// comparator agrees with `cmp` on exact paths.
#[derive(Debug, Clone, Copy)]
pub struct Tolerance {
    /// minimum BEV IoU for two boxes to pair (bitwise-identical boxes
    /// always pair, so `1.0` means "identical")
    pub iou_min: f64,
    /// maximum absolute score difference within a pair
    pub score_eps: f32,
    /// maximum Euclidean center distance (meters) within a pair
    pub center_eps: f64,
    /// drop detections below this score on *both* sides before matching —
    /// quantization legitimately moves near-threshold detections across
    /// the session's score cut, and this is how the comparator ignores
    /// that boundary churn instead of failing on it
    pub drop_below: f32,
}

impl Tolerance {
    /// Accept only bit-identical detection sets.
    pub fn exact() -> Tolerance {
        Tolerance {
            iou_min: 1.0,
            score_eps: 0.0,
            center_eps: 0.0,
            drop_below: 0.0,
        }
    }
}

impl Default for Tolerance {
    /// Defaults sized for f16/int8 wire quantization of this model's
    /// intermediates (see EXPERIMENTS.md §Quantization sweep).
    fn default() -> Tolerance {
        Tolerance {
            iou_min: 0.7,
            score_eps: 0.05,
            center_eps: 0.1,
            drop_below: 0.0,
        }
    }
}

/// One frame of a parsed `--dets-out` file.
#[derive(Debug, Clone)]
pub struct FrameDets {
    pub seq: u64,
    pub sensor: u32,
    pub source_seq: u64,
    pub points: usize,
    pub dets: Vec<Detection>,
}

/// Outcome of matching one frame pair.
#[derive(Debug, Clone, Copy, Default)]
struct FrameOutcome {
    matched: usize,
    missing: usize,
    extra: usize,
    max_score_delta: f32,
    max_center_delta: f64,
    /// minimum IoU over matched pairs (1.0 when nothing matched)
    min_iou: f64,
}

/// Whole-run comparison result.
#[derive(Debug, Clone, Default)]
pub struct CompareReport {
    pub frames: usize,
    pub dets_a: usize,
    pub dets_b: usize,
    pub matched: usize,
    pub missing: usize,
    pub extra: usize,
    pub max_score_delta: f32,
    pub max_center_delta: f64,
    pub min_matched_iou: f64,
    /// human-readable description of each failing frame
    pub mismatched_frames: Vec<String>,
}

impl CompareReport {
    /// A comparison passes iff every (post-filter) box on either side
    /// found a partner within tolerance.
    pub fn pass(&self) -> bool {
        self.missing == 0 && self.extra == 0
    }

    /// Machine-readable report for `compare-dets --out` and the CI
    /// accuracy lane.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("pass", Value::Bool(self.pass())),
            ("frames", Value::num(self.frames as f64)),
            ("dets_a", Value::num(self.dets_a as f64)),
            ("dets_b", Value::num(self.dets_b as f64)),
            ("matched", Value::num(self.matched as f64)),
            ("missing", Value::num(self.missing as f64)),
            ("extra", Value::num(self.extra as f64)),
            ("max_score_delta", Value::num(self.max_score_delta as f64)),
            ("max_center_delta", Value::num(self.max_center_delta)),
            ("min_matched_iou", Value::num(self.min_matched_iou)),
            (
                "mismatched_frames",
                Value::arr(self.mismatched_frames.iter().map(|s| Value::str(s))),
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} frame(s), {}/{} det(s) matched ({} missing, {} extra); \
             max Δscore {:.4}, max Δcenter {:.4} m, min IoU {:.4}",
            if self.pass() { "PASS" } else { "FAIL" },
            self.frames,
            self.matched,
            self.dets_a.max(self.dets_b),
            self.missing,
            self.extra,
            self.max_score_delta,
            self.max_center_delta,
            self.min_matched_iou,
        )
    }
}

fn check_finite(side: &str, dets: &[Detection]) -> Result<()> {
    for (i, d) in dets.iter().enumerate() {
        if d.score.is_nan() {
            bail!("NaN score in {side} detection {i} (class {})", d.class);
        }
        if d.boxx.iter().any(|v| v.is_nan()) {
            bail!("NaN box coordinate in {side} detection {i} (class {})", d.class);
        }
    }
    Ok(())
}

fn center_dist(a: &Detection, b: &Detection) -> f64 {
    let dx = a.boxx[0] as f64 - b.boxx[0] as f64;
    let dy = a.boxx[1] as f64 - b.boxx[1] as f64;
    let dz = a.boxx[2] as f64 - b.boxx[2] as f64;
    (dx * dx + dy * dy + dz * dz).sqrt()
}

fn bits_equal(a: &Detection, b: &Detection) -> bool {
    a.boxx
        .iter()
        .zip(&b.boxx)
        .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Match one frame's detection sets under `tol`. Greedy, highest-score
/// first, class-aware: the standard KITTI-style assignment (see
/// `eval::match_frame`), specialized to det-vs-det with both-side
/// unmatched counting. Errors on NaN anywhere in either side.
fn compare_sets(a: &[Detection], b: &[Detection], tol: &Tolerance) -> Result<FrameOutcome> {
    check_finite("lhs", a)?;
    check_finite("rhs", b)?;
    let a: Vec<&Detection> = a.iter().filter(|d| d.score >= tol.drop_below).collect();
    let b: Vec<&Detection> = b.iter().filter(|d| d.score >= tol.drop_below).collect();

    // highest-score-first gives the deterministic greedy assignment
    let mut order: Vec<usize> = (0..a.len()).collect();
    order.sort_by(|&i, &j| {
        a[j].score
            .partial_cmp(&a[i].score)
            .expect("scores checked finite")
            .then(i.cmp(&j))
    });

    let mut used = vec![false; b.len()];
    let mut out = FrameOutcome {
        min_iou: 1.0,
        ..FrameOutcome::default()
    };
    for &i in &order {
        let da = a[i];
        let mut best: Option<(usize, f64)> = None;
        for (j, db) in b.iter().enumerate() {
            if used[j] || db.class != da.class {
                continue;
            }
            if (da.score - db.score).abs() > tol.score_eps
                || center_dist(da, db) > tol.center_eps
            {
                continue;
            }
            // bit-identical boxes always pair — IoU of a degenerate
            // (zero-size) box is 0/0, and exact comparison must not
            // depend on polygon-clipping round-off
            let iou = if bits_equal(da, db) {
                1.0
            } else {
                bev_iou(&da.boxx, &db.boxx)
            };
            if iou < tol.iou_min {
                continue;
            }
            if best.is_none_or(|(_, bi)| iou > bi) {
                best = Some((j, iou));
            }
        }
        match best {
            Some((j, iou)) => {
                used[j] = true;
                out.matched += 1;
                out.max_score_delta = out.max_score_delta.max((da.score - b[j].score).abs());
                out.max_center_delta = out.max_center_delta.max(center_dist(da, b[j]));
                out.min_iou = out.min_iou.min(iou);
            }
            None => out.missing += 1,
        }
    }
    out.extra = used.iter().filter(|u| !**u).count();
    Ok(out)
}

/// Compare two runs frame by frame. Frames pair by position and must
/// agree on `seq`/`sensor` — two recordings of different streams are a
/// hard error, not a diff.
pub fn compare_runs(
    a: &[FrameDets],
    b: &[FrameDets],
    tol: &Tolerance,
) -> Result<CompareReport> {
    if a.len() != b.len() {
        bail!("frame count mismatch: {} vs {}", a.len(), b.len());
    }
    let mut report = CompareReport {
        frames: a.len(),
        min_matched_iou: 1.0,
        ..CompareReport::default()
    };
    for (fa, fb) in a.iter().zip(b) {
        if fa.seq != fb.seq || fa.sensor != fb.sensor {
            bail!(
                "frame identity mismatch: seq {} sensor {} vs seq {} sensor {}",
                fa.seq,
                fa.sensor,
                fb.seq,
                fb.sensor
            );
        }
        let o = compare_sets(&fa.dets, &fb.dets, tol)
            .with_context(|| format!("frame seq {}", fa.seq))?;
        report.dets_a += fa.dets.len();
        report.dets_b += fb.dets.len();
        report.matched += o.matched;
        report.missing += o.missing;
        report.extra += o.extra;
        report.max_score_delta = report.max_score_delta.max(o.max_score_delta);
        report.max_center_delta = report.max_center_delta.max(o.max_center_delta);
        report.min_matched_iou = report.min_matched_iou.min(o.min_iou);
        if o.missing > 0 || o.extra > 0 {
            report.mismatched_frames.push(format!(
                "seq {} sensor {}: {} matched, {} missing, {} extra",
                fa.seq, fa.sensor, o.matched, o.missing, o.extra
            ));
        }
    }
    Ok(report)
}

fn field<'a>(tokens: &'a [&str], key: &str) -> Result<&'a str> {
    tokens
        .iter()
        .find_map(|t| t.strip_prefix(key).and_then(|t| t.strip_prefix('=')))
        .with_context(|| format!("missing field '{key}'"))
}

fn f32_from_hex(s: &str) -> Result<f32> {
    let bits = u32::from_str_radix(s, 16).with_context(|| format!("bad f32 hex '{s}'"))?;
    Ok(f32::from_bits(bits))
}

/// Parse a `--dets-out` file (the bit-exact hex rendering `run` and
/// `serve-edge` write) back into frames of [`Detection`]s.
pub fn parse_dets(text: &str) -> Result<Vec<FrameDets>> {
    let mut frames: Vec<FrameDets> = Vec::new();
    let mut declared: Vec<usize> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let err = || format!("--dets-out line {}", lineno + 1);
        if let Some(rest) = line.strip_prefix("frame ") {
            let t: Vec<&str> = rest.split_whitespace().collect();
            declared.push(field(&t, "dets")?.parse().with_context(err)?);
            frames.push(FrameDets {
                seq: field(&t, "seq")?.parse().with_context(err)?,
                sensor: field(&t, "sensor")?.parse().with_context(err)?,
                source_seq: field(&t, "src")?.parse().with_context(err)?,
                points: field(&t, "pts")?.parse().with_context(err)?,
                dets: Vec::new(),
            });
        } else if let Some(rest) = line.trim_start().strip_prefix("det ") {
            let frame = frames.last_mut().with_context(|| {
                format!("{}: det line before any frame header", err())
            })?;
            let t: Vec<&str> = rest.split_whitespace().collect();
            let box_hex = field(&t, "box")?;
            let mut boxx = [0.0f32; 7];
            let parts: Vec<&str> = box_hex.split(',').collect();
            if parts.len() != 7 {
                bail!("{}: box wants 7 values, got {}", err(), parts.len());
            }
            for (slot, p) in boxx.iter_mut().zip(parts) {
                *slot = f32_from_hex(p).with_context(err)?;
            }
            frame.dets.push(Detection {
                class: field(&t, "class")?.parse().with_context(err)?,
                score: f32_from_hex(field(&t, "score")?).with_context(err)?,
                boxx,
            });
        } else if !line.trim().is_empty() {
            bail!("{}: unrecognized line '{line}'", err());
        }
    }
    // the headers promise a count — hold the file (truncated copies,
    // interleaved writers) to it
    for (f, want) in frames.iter().zip(declared) {
        if f.dets.len() != want {
            bail!(
                "frame seq {}: header declares {} det(s), file has {}",
                f.seq,
                want,
                f.dets.len()
            );
        }
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(class: usize, score: f32, cx: f32, cy: f32) -> Detection {
        Detection {
            score,
            boxx: [cx, cy, 0.5, 4.0, 1.8, 1.6, 0.3],
            class,
        }
    }

    fn frame(seq: u64, dets: Vec<Detection>) -> FrameDets {
        FrameDets {
            seq,
            sensor: 0,
            source_seq: seq,
            points: 1000,
            dets,
        }
    }

    #[test]
    fn identical_dets_pass_at_zero_tolerance() {
        let dets = vec![det(0, 0.9, 10.0, 2.0), det(1, 0.7, -5.0, 8.0)];
        let a = vec![frame(0, dets.clone())];
        let b = vec![frame(0, dets)];
        let r = compare_runs(&a, &b, &Tolerance::exact()).unwrap();
        assert!(r.pass(), "{}", r.summary());
        assert_eq!(r.matched, 2);
        assert_eq!(r.max_score_delta, 0.0);
        assert_eq!(r.max_center_delta, 0.0);
    }

    #[test]
    fn permuted_box_order_passes() {
        let a = vec![frame(
            0,
            vec![det(0, 0.9, 10.0, 2.0), det(1, 0.7, -5.0, 8.0), det(0, 0.5, 0.0, 0.0)],
        )];
        let b = vec![frame(
            0,
            vec![det(0, 0.5, 0.0, 0.0), det(0, 0.9, 10.0, 2.0), det(1, 0.7, -5.0, 8.0)],
        )];
        let r = compare_runs(&a, &b, &Tolerance::exact()).unwrap();
        assert!(r.pass(), "{}", r.summary());
        assert_eq!(r.matched, 3);
    }

    #[test]
    fn missing_and_extra_boxes_fail() {
        let full = vec![det(0, 0.9, 10.0, 2.0), det(1, 0.7, -5.0, 8.0)];
        let short = vec![det(0, 0.9, 10.0, 2.0)];
        // b missing one box
        let r = compare_runs(
            &[frame(0, full.clone())],
            &[frame(0, short.clone())],
            &Tolerance::default(),
        )
        .unwrap();
        assert!(!r.pass());
        assert_eq!(r.missing, 1);
        assert_eq!(r.mismatched_frames.len(), 1);
        // b has one extra box
        let r = compare_runs(&[frame(0, short)], &[frame(0, full)], &Tolerance::default())
            .unwrap();
        assert!(!r.pass());
        assert_eq!(r.extra, 1);
    }

    #[test]
    fn nan_scores_fail_loudly() {
        let good = vec![frame(0, vec![det(0, 0.9, 10.0, 2.0)])];
        let bad = vec![frame(0, vec![det(0, f32::NAN, 10.0, 2.0)])];
        let err = compare_runs(&good, &bad, &Tolerance::default()).unwrap_err();
        assert!(err.to_string().contains("frame seq 0"), "{err:#}");
        assert!(format!("{err:#}").contains("NaN score"), "{err:#}");
        // NaN in a box coordinate is equally loud
        let mut d = det(0, 0.9, 10.0, 2.0);
        d.boxx[3] = f32::NAN;
        let bad_box = vec![frame(0, vec![d])];
        assert!(compare_runs(&good, &bad_box, &Tolerance::default()).is_err());
    }

    #[test]
    fn tolerance_accepts_small_perturbations_only() {
        let a = vec![frame(0, vec![det(0, 0.90, 10.0, 2.0)])];
        let nudged = vec![frame(0, vec![det(0, 0.91, 10.02, 2.01)])];
        let tol = Tolerance {
            iou_min: 0.8,
            score_eps: 0.05,
            center_eps: 0.1,
            drop_below: 0.0,
        };
        assert!(compare_runs(&a, &nudged, &tol).unwrap().pass());
        // the same nudge fails a tighter score epsilon
        let tight = Tolerance { score_eps: 0.001, ..tol };
        assert!(!compare_runs(&a, &nudged, &tight).unwrap().pass());
        // and a moved box fails the center epsilon
        let moved = vec![frame(0, vec![det(0, 0.90, 10.5, 2.0)])];
        assert!(!compare_runs(&a, &moved, &tol).unwrap().pass());
    }

    #[test]
    fn drop_below_ignores_threshold_churn() {
        // a near-threshold det present on one side only is forgiven once
        // both sides are cut at drop_below
        let a = vec![frame(
            0,
            vec![det(0, 0.9, 10.0, 2.0), det(1, 0.31, -5.0, 8.0)],
        )];
        let b = vec![frame(0, vec![det(0, 0.9, 10.0, 2.0)])];
        let tol = Tolerance {
            drop_below: 0.35,
            ..Tolerance::default()
        };
        assert!(compare_runs(&a, &b, &tol).unwrap().pass());
        assert!(!compare_runs(&a, &b, &Tolerance::default()).unwrap().pass());
    }

    #[test]
    fn class_mismatch_never_pairs() {
        let a = vec![frame(0, vec![det(0, 0.9, 10.0, 2.0)])];
        let b = vec![frame(0, vec![det(1, 0.9, 10.0, 2.0)])];
        let r = compare_runs(&a, &b, &Tolerance::default()).unwrap();
        assert!(!r.pass());
        assert_eq!(r.missing, 1);
        assert_eq!(r.extra, 1);
    }

    #[test]
    fn parses_dets_out_format() {
        // exactly what main.rs's DetsOut writes
        let d = det(2, 0.75, 1.5, -3.25);
        let mut text = String::from("frame seq=0 sensor=1 src=4 pts=1200 dets=1\n");
        let boxx: Vec<String> = d.boxx.iter().map(|v| format!("{:08x}", v.to_bits())).collect();
        text.push_str(&format!(
            "  det class={} score={:08x} box={}\n",
            d.class,
            d.score.to_bits(),
            boxx.join(",")
        ));
        text.push_str("frame seq=1 sensor=1 src=5 pts=900 dets=0\n");
        let frames = parse_dets(&text).unwrap();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].sensor, 1);
        assert_eq!(frames[0].source_seq, 4);
        assert_eq!(frames[0].dets.len(), 1);
        let back = frames[0].dets[0];
        assert_eq!(back.class, 2);
        assert_eq!(back.score.to_bits(), d.score.to_bits());
        assert_eq!(back.boxx, d.boxx);
        assert!(frames[1].dets.is_empty());
        // self-comparison through the parser is exact
        assert!(compare_runs(&frames, &frames, &Tolerance::exact()).unwrap().pass());
        // garbage is an error, not a skip
        assert!(parse_dets("what is this\n").is_err());
    }
}
