//! BEV rotated-box IoU and non-maximum suppression.
//!
//! Exact rotated-rectangle intersection via Sutherland–Hodgman polygon
//! clipping (the same geometry OpenPCDet's CUDA `iou3d_nms` computes),
//! implemented as a portable rust substrate.

use super::Detection;

/// A BEV rectangle as its 4 corners, counter-clockwise.
fn corners(b: &[f32; 7]) -> [[f64; 2]; 4] {
    let (cx, cy, l, w, ry) = (b[0] as f64, b[1] as f64, b[3] as f64, b[4] as f64, b[6] as f64);
    let (s, c) = ry.sin_cos();
    let (hl, hw) = (l / 2.0, w / 2.0);
    let rot = |x: f64, y: f64| [cx + c * x - s * y, cy + s * x + c * y];
    [rot(hl, hw), rot(-hl, hw), rot(-hl, -hw), rot(hl, -hw)]
}

fn polygon_area(poly: &[[f64; 2]]) -> f64 {
    if poly.len() < 3 {
        return 0.0;
    }
    let mut a = 0.0;
    for i in 0..poly.len() {
        let j = (i + 1) % poly.len();
        a += poly[i][0] * poly[j][1] - poly[j][0] * poly[i][1];
    }
    a.abs() / 2.0
}

/// Clip polygon `subject` by the half-plane left of edge (a→b).
fn clip_edge(subject: &[[f64; 2]], a: [f64; 2], b: [f64; 2]) -> Vec<[f64; 2]> {
    let inside = |p: [f64; 2]| (b[0] - a[0]) * (p[1] - a[1]) - (b[1] - a[1]) * (p[0] - a[0]) >= -1e-12;
    let intersect = |p: [f64; 2], q: [f64; 2]| -> [f64; 2] {
        let (x1, y1, x2, y2) = (a[0], a[1], b[0], b[1]);
        let (x3, y3, x4, y4) = (p[0], p[1], q[0], q[1]);
        let den = (x1 - x2) * (y3 - y4) - (y1 - y2) * (x3 - x4);
        if den.abs() < 1e-12 {
            return q;
        }
        let t = ((x1 - x3) * (y3 - y4) - (y1 - y3) * (x3 - x4)) / den;
        [x1 + t * (x2 - x1), y1 + t * (y2 - y1)]
    };
    let mut out = Vec::with_capacity(subject.len() + 2);
    for i in 0..subject.len() {
        let cur = subject[i];
        let prev = subject[(i + subject.len() - 1) % subject.len()];
        match (inside(cur), inside(prev)) {
            (true, true) => out.push(cur),
            (true, false) => {
                out.push(intersect(prev, cur));
                out.push(cur);
            }
            (false, true) => out.push(intersect(prev, cur)),
            (false, false) => {}
        }
    }
    out
}

/// Exact BEV intersection area of two rotated boxes.
pub fn bev_intersection(a: &[f32; 7], b: &[f32; 7]) -> f64 {
    let ca = corners(a);
    let cb = corners(b);
    let mut poly: Vec<[f64; 2]> = ca.to_vec();
    for i in 0..4 {
        if poly.is_empty() {
            return 0.0;
        }
        poly = clip_edge(&poly, cb[i], cb[(i + 1) % 4]);
    }
    polygon_area(&poly)
}

/// BEV IoU of two rotated boxes.
pub fn bev_iou(a: &[f32; 7], b: &[f32; 7]) -> f64 {
    let inter = bev_intersection(a, b);
    let area_a = (a[3] as f64) * (a[4] as f64);
    let area_b = (b[3] as f64) * (b[4] as f64);
    let union = area_a + area_b - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// 3D IoU (BEV intersection × z overlap / volume union).
pub fn iou_3d(a: &[f32; 7], b: &[f32; 7]) -> f64 {
    let inter_bev = bev_intersection(a, b);
    let (za0, za1) = (a[2] as f64 - a[5] as f64 / 2.0, a[2] as f64 + a[5] as f64 / 2.0);
    let (zb0, zb1) = (b[2] as f64 - b[5] as f64 / 2.0, b[2] as f64 + b[5] as f64 / 2.0);
    let zi = (za1.min(zb1) - za0.max(zb0)).max(0.0);
    let inter = inter_bev * zi;
    let vol = |x: &[f32; 7]| x[3] as f64 * x[4] as f64 * x[5] as f64;
    let union = vol(a) + vol(b) - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// Greedy NMS over score-sorted detections. Returns kept indices (into
/// `dets`), at most `max_keep`. `dets` must already be sorted by score desc.
pub fn nms_bev(dets: &[Detection], iou_threshold: f32, max_keep: usize) -> Vec<usize> {
    let mut keep: Vec<usize> = Vec::new();
    'cand: for (i, d) in dets.iter().enumerate() {
        if keep.len() == max_keep {
            break;
        }
        for &k in &keep {
            if bev_iou(&d.boxx, &dets[k].boxx) > iou_threshold as f64 {
                continue 'cand;
            }
        }
        keep.push(i);
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxx(cx: f32, cy: f32, l: f32, w: f32, ry: f32) -> [f32; 7] {
        [cx, cy, 0.0, l, w, 1.5, ry]
    }

    #[test]
    fn identical_boxes_iou_one() {
        let b = boxx(5.0, 5.0, 4.0, 2.0, 0.7);
        assert!((bev_iou(&b, &b) - 1.0).abs() < 1e-9);
        assert!((iou_3d(&b, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_boxes_iou_zero() {
        let a = boxx(0.0, 0.0, 2.0, 2.0, 0.0);
        let b = boxx(10.0, 0.0, 2.0, 2.0, 1.0);
        assert_eq!(bev_iou(&a, &b), 0.0);
    }

    #[test]
    fn axis_aligned_half_overlap() {
        // 2x2 squares offset by 1 in x: intersection 2, union 6 -> 1/3
        let a = boxx(0.0, 0.0, 2.0, 2.0, 0.0);
        let b = boxx(1.0, 0.0, 2.0, 2.0, 0.0);
        assert!((bev_iou(&a, &b) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn rotated_45_degrees_known_area() {
        // unit square vs itself rotated 45°: intersection is a regular
        // octagon with area 2(√2−1) ≈ 0.8284
        let a = boxx(0.0, 0.0, 1.0, 1.0, 0.0);
        let b = boxx(0.0, 0.0, 1.0, 1.0, std::f32::consts::FRAC_PI_4);
        let inter = bev_intersection(&a, &b);
        assert!((inter - 2.0 * (2.0f64.sqrt() - 1.0)).abs() < 1e-6, "{inter}");
    }

    #[test]
    fn rotation_by_pi_is_same_box() {
        let a = boxx(3.0, -2.0, 4.0, 1.8, 0.4);
        let b = boxx(3.0, -2.0, 4.0, 1.8, 0.4 + std::f32::consts::PI);
        assert!((bev_iou(&a, &b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn containment() {
        let big = boxx(0.0, 0.0, 4.0, 4.0, 0.3);
        let small = boxx(0.0, 0.0, 2.0, 2.0, 0.3);
        let iou = bev_iou(&big, &small);
        assert!((iou - 4.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn z_overlap_matters() {
        let mut a = boxx(0.0, 0.0, 2.0, 2.0, 0.0);
        let mut b = a;
        a[2] = 0.0;
        b[2] = 10.0; // far apart in z
        assert_eq!(iou_3d(&a, &b), 0.0);
        assert!((bev_iou(&a, &b) - 1.0).abs() < 1e-9);
    }

    fn det(cx: f32, score: f32) -> Detection {
        Detection {
            score,
            boxx: boxx(cx, 0.0, 4.0, 2.0, 0.0),
            class: 0,
        }
    }

    #[test]
    fn nms_suppresses_overlaps() {
        let dets = vec![det(0.0, 0.9), det(0.5, 0.8), det(10.0, 0.7)];
        let keep = nms_bev(&dets, 0.3, 10);
        assert_eq!(keep, vec![0, 2]);
    }

    #[test]
    fn nms_respects_max_keep() {
        let dets: Vec<Detection> = (0..20).map(|i| det(i as f32 * 100.0, 1.0 - i as f32 * 0.01)).collect();
        assert_eq!(nms_bev(&dets, 0.5, 5).len(), 5);
    }

    #[test]
    fn nms_keeps_all_disjoint() {
        let dets: Vec<Detection> = (0..8).map(|i| det(i as f32 * 50.0, 0.5)).collect();
        assert_eq!(nms_bev(&dets, 0.1, 100).len(), 8);
    }
}
