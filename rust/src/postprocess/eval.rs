//! Detection evaluation: greedy IoU matching and average precision.
//!
//! The paper reports no accuracy metrics (its evaluation is time/bytes),
//! but a deployable reproduction needs the measurement capability; the
//! split==unsplit equivalence tests also use the matcher to compare
//! detection sets structurally.

use super::nms::{bev_iou, iou_3d};
use super::Detection;

/// Ground-truth box for evaluation.
#[derive(Debug, Clone, Copy)]
pub struct GroundTruth {
    pub boxx: [f32; 7],
    pub class: usize,
}

/// Matching result for one frame.
#[derive(Debug, Clone, Default)]
pub struct FrameMatch {
    /// (detection idx, gt idx, iou) pairs
    pub matches: Vec<(usize, usize, f64)>,
    pub unmatched_dets: Vec<usize>,
    pub unmatched_gts: Vec<usize>,
}

/// Greedy match detections (score-sorted) to ground truth at an IoU
/// threshold, class-aware, BEV or 3D IoU.
pub fn match_frame(
    dets: &[Detection],
    gts: &[GroundTruth],
    iou_threshold: f64,
    use_3d: bool,
) -> FrameMatch {
    let mut order: Vec<usize> = (0..dets.len()).collect();
    order.sort_by(|&a, &b| dets[b].score.partial_cmp(&dets[a].score).unwrap());

    let mut gt_taken = vec![false; gts.len()];
    let mut result = FrameMatch::default();
    for &di in &order {
        let mut best: Option<(usize, f64)> = None;
        for (gi, gt) in gts.iter().enumerate() {
            if gt_taken[gi] || gt.class != dets[di].class {
                continue;
            }
            let iou = if use_3d {
                iou_3d(&dets[di].boxx, &gt.boxx)
            } else {
                bev_iou(&dets[di].boxx, &gt.boxx)
            };
            if iou >= iou_threshold && best.is_none_or(|(_, b)| iou > b) {
                best = Some((gi, iou));
            }
        }
        match best {
            Some((gi, iou)) => {
                gt_taken[gi] = true;
                result.matches.push((di, gi, iou));
            }
            None => result.unmatched_dets.push(di),
        }
    }
    result.unmatched_gts = gt_taken
        .iter()
        .enumerate()
        .filter(|(_, &t)| !t)
        .map(|(i, _)| i)
        .collect();
    result
}

/// 11-point interpolated average precision over a set of frames
/// (KITTI-style, simplified to a single difficulty bucket).
pub fn average_precision(
    frames: &[(Vec<Detection>, Vec<GroundTruth>)],
    class: usize,
    iou_threshold: f64,
    use_3d: bool,
) -> f64 {
    // gather (score, is_tp) over all frames for this class
    let mut scored: Vec<(f32, bool)> = Vec::new();
    let mut total_gt = 0usize;
    for (dets, gts) in frames {
        let class_dets: Vec<Detection> =
            dets.iter().copied().filter(|d| d.class == class).collect();
        let class_gts: Vec<GroundTruth> =
            gts.iter().copied().filter(|g| g.class == class).collect();
        total_gt += class_gts.len();
        let m = match_frame(&class_dets, &class_gts, iou_threshold, use_3d);
        let matched: std::collections::HashSet<usize> =
            m.matches.iter().map(|&(d, _, _)| d).collect();
        for (i, d) in class_dets.iter().enumerate() {
            scored.push((d.score, matched.contains(&i)));
        }
    }
    if total_gt == 0 {
        return 0.0;
    }
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    // precision/recall curve
    let mut tp = 0usize;
    let mut curve: Vec<(f64, f64)> = Vec::with_capacity(scored.len()); // (recall, precision)
    for (i, &(_, is_tp)) in scored.iter().enumerate() {
        if is_tp {
            tp += 1;
        }
        curve.push((tp as f64 / total_gt as f64, tp as f64 / (i + 1) as f64));
    }

    // 11-point interpolation
    let mut ap = 0.0;
    for i in 0..11 {
        let r = i as f64 / 10.0;
        let p = curve
            .iter()
            .filter(|&&(rec, _)| rec >= r)
            .map(|&(_, prec)| prec)
            .fold(0.0f64, f64::max);
        ap += p / 11.0;
    }
    ap
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, score: f32, class: usize) -> Detection {
        Detection {
            score,
            boxx: [cx, 0.0, 0.0, 4.0, 2.0, 1.5, 0.0],
            class,
        }
    }

    fn gt(cx: f32, class: usize) -> GroundTruth {
        GroundTruth {
            boxx: [cx, 0.0, 0.0, 4.0, 2.0, 1.5, 0.0],
            class,
        }
    }

    #[test]
    fn perfect_match() {
        let dets = vec![det(0.0, 0.9, 0), det(20.0, 0.8, 0)];
        let gts = vec![gt(0.0, 0), gt(20.0, 0)];
        let m = match_frame(&dets, &gts, 0.5, true);
        assert_eq!(m.matches.len(), 2);
        assert!(m.unmatched_dets.is_empty() && m.unmatched_gts.is_empty());
    }

    #[test]
    fn class_aware() {
        let dets = vec![det(0.0, 0.9, 1)];
        let gts = vec![gt(0.0, 0)];
        let m = match_frame(&dets, &gts, 0.5, false);
        assert!(m.matches.is_empty());
        assert_eq!(m.unmatched_dets, vec![0]);
        assert_eq!(m.unmatched_gts, vec![0]);
    }

    #[test]
    fn one_gt_one_match() {
        // two detections on the same gt: only the higher-scored matches
        let dets = vec![det(0.1, 0.7, 0), det(0.0, 0.9, 0)];
        let gts = vec![gt(0.0, 0)];
        let m = match_frame(&dets, &gts, 0.5, false);
        assert_eq!(m.matches.len(), 1);
        assert_eq!(m.matches[0].0, 1); // index of the 0.9 det
        assert_eq!(m.unmatched_dets, vec![0]);
    }

    #[test]
    fn ap_perfect_is_one() {
        let frames = vec![(
            vec![det(0.0, 0.9, 0), det(20.0, 0.8, 0)],
            vec![gt(0.0, 0), gt(20.0, 0)],
        )];
        let ap = average_precision(&frames, 0, 0.5, true);
        assert!((ap - 1.0).abs() < 1e-9, "{ap}");
    }

    #[test]
    fn ap_no_dets_is_zero() {
        let frames = vec![(vec![], vec![gt(0.0, 0)])];
        assert_eq!(average_precision(&frames, 0, 0.5, true), 0.0);
    }

    #[test]
    fn ap_false_positives_reduce_precision() {
        let frames = vec![(
            vec![det(0.0, 0.9, 0), det(100.0, 0.95, 0)], // higher-scored FP
            vec![gt(0.0, 0)],
        )];
        let ap = average_precision(&frames, 0, 0.5, true);
        assert!(ap < 0.75, "{ap}");
        assert!(ap > 0.0);
    }
}
