//! Paper-evaluation benches: regenerates every table and figure of the
//! paper's §IV against this stack, plus the ablations DESIGN.md calls out.
//!
//!   cargo bench                            # full suite
//!   cargo bench -- table1 fig6 ablation    # subset by keyword
//!
//! Environment: SPLITPOINT_BENCH_FRAMES (default 5) controls the workload;
//! the committed EXPERIMENTS.md numbers used 10.
//!
//! Backend note: under the default (offline) build the modules run on the
//! in-crate reference executor; with `--features pjrt` they run the AOT'd
//! HLO artifacts. Virtual-clock numbers are comparable either way because
//! the device profiles scale measured host time (see config::SystemConfig).

use std::sync::Arc;

use splitpoint::bench::paper::{self, reference};
use splitpoint::config::SystemConfig;
use splitpoint::coordinator::Engine;
use splitpoint::pointcloud::scene::SceneGenerator;
use splitpoint::tensor::codec::Policy;
use splitpoint::Manifest;

fn frames() -> usize {
    std::env::var("SPLITPOINT_BENCH_FRAMES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5)
}

fn want(filters: &[String], key: &str) -> bool {
    filters.is_empty() || filters.iter().any(|f| key.contains(f.as_str()))
}

fn main() -> anyhow::Result<()> {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    // bench entry routes through the session builder like the CLI; config
    // sweeps below share its runtime via Engine::with_runtime
    let engine = splitpoint::SplitSession::builder().build_engine()?;
    let n = frames();

    // ---- the core sweep behind Table I and Figs 6–9
    if ["table1", "table2", "fig6", "fig7", "fig8", "fig9"]
        .iter()
        .any(|k| want(&filters, k))
    {
        eprintln!("[paper] sweeping splits x {n} frames…");
        let splits = paper::paper_splits(&engine)?;
        let sweep = paper::run_sweep(&engine, &splits, n, 1)?;
        if want(&filters, "table1") {
            println!("{}", paper::table1_report(&sweep));
        }
        if want(&filters, "table2") {
            println!("{}", paper::table2_report(&engine));
        }
        if want(&filters, "fig6") || want(&filters, "fig7") || want(&filters, "fig8")
            || want(&filters, "fig9")
        {
            println!("{}", paper::figures_report(&sweep));
        }
    }

    // ---- ablation: wire codec policy (paper §VI quantization future work)
    if want(&filters, "ablation_codec") {
        eprintln!("[paper] codec ablation…");
        println!("\n## Ablation — wire codec policy (split after conv1)\n");
        println!("| codec | wire MB | transfer ms | inference ms |");
        println!("|---|---|---|---|");
        let runtime = engine.runtime().clone();
        for (name, policy) in [
            ("dense f32 (paper's implementation)", Policy::Dense),
            ("sparse auto (ours)", Policy::Auto),
            ("sparse + int8 (paper §VI extension)", Policy::AutoQuantized),
        ] {
            let mut cfg = SystemConfig::paper();
            cfg.codec = policy;
            let e = Engine::with_runtime(&manifest, cfg, runtime.clone())?;
            let sp = e.graph().split_after("conv1")?;
            let mut gen = SceneGenerator::with_seed(1);
            let (mut mb, mut tms, mut ims) = (0.0, 0.0, 0.0);
            for _ in 0..n {
                let r = e.run_frame(&gen.generate().cloud, sp)?;
                mb += r.timing.uplink_bytes as f64 / 1e6;
                tms += r.timing.uplink_time.as_millis_f64();
                ims += r.timing.inference_time.as_millis_f64();
            }
            let k = n as f64;
            println!(
                "| {name} | {:.2} | {:.1} | {:.1} |",
                mb / k,
                tms / k,
                ims / k
            );
        }
    }

    // ---- ablation: bandwidth sweep with adaptive split selection.
    // "privacy-constrained" restricts the selector to in-network splits
    // (conv1 or deeper): the paper's §IV argues raw clouds AND voxel/VFE
    // data leak privacy, so only post-conv cuts are acceptable.
    if want(&filters, "ablation_bandwidth") {
        eprintln!("[paper] bandwidth ablation…");
        println!("\n## Ablation — link bandwidth vs best split (adaptive selector)\n");
        println!("| bandwidth MB/s | best split | ms | best privacy-constrained | ms | edge-only ms |");
        println!("|---|---|---|---|---|---|");
        let runtime = engine.runtime().clone();
        let scene = SceneGenerator::with_seed(2).generate();
        let conv1_idx = engine.graph().split_after("conv1")?.head_len;
        for mbps in [0.05, 0.2, 0.5, 2.0, 8.0, 32.0] {
            let mut cfg = SystemConfig::paper();
            cfg.link.bandwidth_bps = mbps * 1e6;
            let e = Engine::with_runtime(&manifest, cfg, runtime.clone())?;
            let ests = splitpoint::coordinator::adaptive::estimate_splits(
                &e,
                &scene.cloud,
            )?;
            let best = ests
                .iter()
                .min_by_key(|x| x.inference_time)
                .unwrap();
            let private = ests
                .iter()
                .filter(|x| x.split.head_len >= conv1_idx)
                .min_by_key(|x| x.inference_time)
                .unwrap();
            let edge_only = ests.last().unwrap();
            println!(
                "| {mbps} | {} | {:.0} | {} | {:.0} | {:.0} |",
                best.label,
                best.inference_time.as_millis_f64(),
                private.label,
                private.inference_time.as_millis_f64(),
                edge_only.inference_time.as_millis_f64()
            );
        }
    }

    // ---- ablation: multi-LiDAR batching throughput (paper §VI)
    if want(&filters, "ablation_multilidar") {
        eprintln!("[paper] multi-LiDAR ablation…");
        println!("\n## Ablation — multi-LiDAR worker scaling (split after vfe)\n");
        println!("| xla workers | frames | wall s | frames/s |");
        println!("|---|---|---|---|");
        let total = n.max(4);
        for workers in [1usize, 2] {
            let runtime = Arc::new(splitpoint::runtime::XlaRuntime::load_pooled(
                &manifest, workers,
            )?);
            let e = Arc::new(Engine::with_runtime(
                &manifest,
                SystemConfig::paper(),
                runtime,
            )?);
            let sp = e.graph().split_after("vfe")?;
            let clouds: Vec<_> = {
                let mut gen = SceneGenerator::with_seed(3);
                (0..total).map(|_| gen.generate().cloud).collect()
            };
            let t0 = std::time::Instant::now();
            std::thread::scope(|s| {
                for chunk in clouds.chunks(total.div_ceil(workers)) {
                    let e = e.clone();
                    s.spawn(move || {
                        for c in chunk {
                            e.run_frame(c, sp).unwrap();
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "| {workers} | {total} | {wall:.1} | {:.2} |",
                total as f64 / wall
            );
        }
    }

    // ---- sanity: print the paper's reference numbers alongside
    if want(&filters, "reference") {
        println!("\n## Paper reference values (for the tables above)\n");
        println!("Fig 6 {:?}", reference::FIG6);
        println!("Fig 7 {:?}", reference::FIG7);
        println!("Fig 8 {:?}", reference::FIG8);
        println!("Fig 9 {:?}", reference::FIG9);
    }

    eprintln!("[paper] done");
    Ok(())
}
